"""repro.serve — continuous-batching serving engine (DESIGN.md §7).

    from repro.serve import Request, SamplingParams, ServeEngine

    engine = ServeEngine(params, cfg, max_batch=4, max_len=256)
    engine.submit(Request(prompt, max_new_tokens=32,
                          sampling=SamplingParams(method="topk", top_k=40,
                                                  temperature=0.8, seed=1)))
    completions = engine.run()
    engine.stats()["tokens_per_s"]

`lockstep_generate` is the fixed-batch barriered baseline the engine
replaces, kept for benchmarks and parity tests.
"""
from repro.serve.engine import (  # noqa: F401
    Completion,
    Request,
    ServeEngine,
    lockstep_generate,
)
from repro.serve.sampling import SAMPLING_METHODS, SamplingParams, sample_tokens  # noqa: F401
