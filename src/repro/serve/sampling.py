"""Per-request token sampling for the serve engine.

One jit-able `sample_tokens` handles the whole slot pool in a single call:
every row carries its own (temperature, top_k, PRNG key), so a greedy request,
a temperature request and a top-k request can share one decode step. Greedy is
temperature == 0 (selected with `jnp.where`, so the categorical draw for those
rows is computed-and-discarded rather than branched — B is small at serve
time and branches would break the single-compile property).

Key protocol: each request starts from `PRNGKey(seed)`; every sampled token
splits the row's key once and draws with the split half. The lockstep baseline
follows the same protocol, so continuous-vs-lockstep parity holds for
stochastic sampling too, not just greedy (tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SAMPLING_METHODS = ("greedy", "temperature", "topk")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    method: "greedy" | "temperature" | "topk". temperature applies to both
    stochastic methods; top_k > 0 restricts the draw to the k highest logits
    (required for method="topk"). seed is the per-request PRNG seed.
    """

    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.method not in SAMPLING_METHODS:
            raise ValueError(
                f"unknown sampling method {self.method!r}; known: {SAMPLING_METHODS}")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.method == "topk" and self.top_k <= 0:
            raise ValueError(f"method='topk' needs top_k > 0, got {self.top_k}")

    @property
    def eff_temperature(self) -> float:
        """Temperature as the kernel sees it: 0 selects the greedy branch."""
        return 0.0 if self.method == "greedy" else self.temperature

    @property
    def eff_top_k(self) -> int:
        """top_k as the kernel sees it: 0 = full vocabulary."""
        return self.top_k if self.method == "topk" else 0


def _sample_one(logits, key, temperature, top_k):
    """One row: logits (V,) -> token. temperature <= 0 is greedy; top_k <= 0
    draws from the full vocabulary."""
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg).astype(jnp.int32)
    # top-k: mask everything strictly below the k-th largest logit. k is a
    # traced per-row value, so the threshold is a dynamic gather on the sorted
    # logits rather than lax.top_k with a static k.
    kth = jnp.sort(lg)[::-1][jnp.clip(top_k - 1, 0, V - 1)]
    masked = jnp.where((top_k <= 0) | (lg >= kth), lg, -jnp.inf)
    scaled = masked / jnp.maximum(temperature, 1e-6)
    drawn = jax.random.categorical(key, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, drawn)


def sample_tokens(logits, keys, temperature, top_k):
    """Sample one token per pool slot.

    logits (B, V); keys (B, 2) uint32; temperature (B,) f32; top_k (B,) int32.
    Returns (tokens (B,) int32, new_keys (B, 2)): each row's key is split once
    per call, the draw uses the subkey and the fresh key is handed back.
    """
    split = jax.vmap(jax.random.split)(keys)  # (B, 2, 2)
    next_keys, use = split[:, 0], split[:, 1]
    tokens = jax.vmap(_sample_one)(logits, use, temperature, top_k)
    return tokens, next_keys
