"""Continuous-batching serve engine (DESIGN.md §7).

The lockstep loop this replaces barriers every request on the slowest member
of a fixed batch: one shared prompt length, one shared decode position, no
slot reuse. The paper's core point — never let the slowest participant
serialize everyone; treat heterogeneous arrival delays as first-class — maps
onto serving directly: requests arrive staggered and should be admitted and
retired continuously.

`ServeEngine` owns a queue and a fixed pool of `max_batch` slots backed by ONE
persistent cache allocation (`T.init_caches(cfg, max_batch, max_len)`):

* every engine step advances ALL active slots with one jitted `decode_step`
  carrying a per-slot position vector `t: (B,)` (models/transformer.py) — a
  request at position 70 and one at position 9 share the same call;
* a finished slot (EOS / max_new_tokens) is freed immediately and the next
  queued request's prefill is interleaved into the loop: a single-row prefill
  (prompt right-padded to a power-of-two bucket where the arch allows it, so
  compiles are shared across lengths) writes the slot's rows of the pool
  caches in place (`dynamic_update_slice` on the batch axis);
* sampling is per-request (greedy / temperature / top-k, own PRNG seed) in one
  vmapped call over the pool (serve.sampling), with `on_token` streaming
  callbacks and per-request latency + aggregate throughput metrics.

`lockstep_generate` is the barriered baseline, kept as the measurable
counterfactual (benchmarks/serve_bench.py) and the parity oracle for
equal-length requests (tests/test_serve.py).

Cross-slot isolation: attention, norms and dense/SwiGLU FFNs are row-
independent, so a slot's tokens are unaffected by its neighbors (locked in by
tests/test_serve.py::test_per_slot_decode_matches_sequential). MoE capacity
routing is the one documented exception — expert capacity is computed over
the whole pool, so under capacity pressure co-resident requests can perturb
each other's routing (same property the lockstep loop had).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.sharding.rules import LOCAL_CTX, ShardCtx


@dataclasses.dataclass
class Request:
    """One generation request. `on_token(request_id, token)` streams tokens as
    they are accepted (prefill's first token included). `patches` carries a
    VLM request's precomputed image-patch embeddings ((n_patches, d_model)
    f32, spliced over prompt positions 1..1+P at prefill — vlm archs only)."""

    prompt: Sequence[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    on_token: Optional[Callable[[int, int], None]] = None
    patches: Optional[np.ndarray] = None
    request_id: Optional[int] = None  # assigned at submit() if None


@dataclasses.dataclass
class Completion:
    """Result + latency record of one request."""

    request_id: int
    prompt_len: int
    tokens: List[int]              # generated tokens (EOS included if hit)
    finish_reason: str             # "eos" | "length"
    slot: int
    submitted_s: float             # perf_counter stamps
    admitted_s: float
    first_token_s: float
    finished_s: float

    @property
    def new_tokens(self) -> int:
        return len(self.tokens)

    @property
    def ttft_s(self) -> float:
        """Submit -> first token (queue wait + prefill)."""
        return self.first_token_s - self.submitted_s

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s


@dataclasses.dataclass
class _Active:
    req: Request
    slot: int
    prompt_len: int
    tokens: List[int]
    submitted_s: float
    admitted_s: float
    first_token_s: float


def _padded_prefill_ok(cfg) -> bool:
    """Right-padded prompts are exact only when every layer is full causal
    attention: recurrent state (ssm/xlstm/hybrid) integrates pad junk, sliding
    windows let pads displace real tail tokens in the ring, and MoE capacity
    counts pad tokens. Those archs prefill at exact length instead (one
    compile per distinct prompt length)."""
    return cfg.arch_type in ("dense", "vlm") and not cfg.sliding_window


class ServeEngine:
    """Continuous-batching serving over the prefill/decode + ring-buffer cache
    machinery. See module docstring; typical use:

        engine = ServeEngine(params, cfg, max_batch=4, max_len=256)
        engine.submit(Request(prompt, max_new_tokens=32))
        completions = engine.run()          # or step() under your own loop
        engine.stats()["tokens_per_s"]
    """

    def __init__(self, params, cfg, ctx: ShardCtx = LOCAL_CTX, *,
                 max_batch: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, min_prefill_bucket: int = 8):
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only; nothing to serve")
        if max_batch < 1 or max_len < 2:
            raise ValueError(f"need max_batch >= 1 and max_len >= 2, "
                             f"got {max_batch}, {max_len}")
        self.params, self.cfg, self.ctx = params, cfg, ctx
        self.max_batch, self.max_len, self.eos_id = max_batch, max_len, eos_id
        self.min_prefill_bucket = min_prefill_bucket
        self._padded = _padded_prefill_ok(cfg)

        self.caches = T.init_caches(cfg, max_batch, max_len)

        def step_impl(p, c, tok, t, keys, temp, topk):
            # decode + sample fused into ONE dispatch per engine step: only the
            # (B,) sampled tokens cross to host, never the (B, V) logits
            logits, c = T.decode_step(p, c, tok, t, cfg, ctx)
            toks, keys = sample_tokens(logits, keys, temp, topk)
            return toks, keys, c

        self._step = jax.jit(step_impl, donate_argnums=(1,))
        self._prefills: dict = {}  # (batch, seq) -> jitted prefill+sample
        self._admits: dict = {}    # seq -> jitted prefill+sample+pool-insert

        B = max_batch
        self.queue: "collections.deque[Request]" = collections.deque()
        self.completions: List[Completion] = []
        self._active: List[Optional[_Active]] = [None] * B
        self._n_active = 0
        self._tokens = np.zeros((B, 1), np.int32)
        self._t = np.zeros((B,), np.int32)
        self._keys = np.zeros((B, 2), np.uint32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._next_id = 0
        self.reset_stats()

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg=None, ctx: ShardCtx = LOCAL_CTX, *,
                        step: Optional[int] = None, **engine_kw) -> "ServeEngine":
        """Warm-start serving from a training snapshot: restore the `params`
        subtree of the full-state checkpoint (repro.checkpoint, DESIGN.md §8)
        and build an engine around it — the guided/optimizer state stays on
        disk for the training job that owns it.

        `step=None` takes the latest manifest entry; `cfg=None` rebuilds the
        ModelConfig from the manifest metadata the trainer records
        (arch/reduced/model_overrides), so serving a checkpoint dir needs no
        out-of-band config. On a distributed `ctx` the restore re-places the
        params onto the serving mesh via the logical sharding rules —
        train-on-prod, serve-on-host works without a resharding script."""
        from repro import checkpoint as C
        from repro.models.module import split_params
        from repro.sharding.rules import shardings_for

        if step is None:
            step = C.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint manifest (or v1 LATEST) in {ckpt_dir}")
        if cfg is None:
            cfg = C.model_config_from_manifest(ckpt_dir, step)
        # a freshly initialized model is the restore template (treedef+dtypes)
        template, logical = split_params(T.model_init(jax.random.PRNGKey(0), cfg))
        shardings = (shardings_for(logical, template, ctx.mesh, ctx.rules)
                     if ctx.distributed else None)
        params = C.restore_subtree(ckpt_dir, step, "params", template, shardings)
        if shardings is None:
            params = jax.tree.map(jnp.asarray, params)
        return cls(params, cfg, ctx, **engine_kw)

    # ------------------------------------------------------------- plumbing

    @staticmethod
    def _insert_impl(pool, one, slot):
        """Write a single-request cache tree into batch row `slot` of the pool
        (every cache leaf is (n_super, batch, ...))."""
        def ins(p, o):
            idx = (0, slot.astype(jnp.int32)) + (0,) * (p.ndim - 2)
            return jax.lax.dynamic_update_slice(p, o.astype(p.dtype), idx)

        return jax.tree.map(ins, pool, one)

    def bucket_len(self, prompt_len: int) -> int:
        """Prefill compile bucket for a prompt length: next power of two where
        padding is exact for the arch, the exact length otherwise."""
        if not self._padded:
            return prompt_len
        b = max(self.min_prefill_bucket, 1 << (prompt_len - 1).bit_length())
        return min(b, self.max_len)

    def prefill_fn(self, batch: int, seq: int):
        """Jitted prefill+first-token-sample for a (batch, seq) shape, cached
        per engine; caches come back sized for the pool's max_len so rows slot
        straight in. Returns (tokens (batch,), new_keys, caches)."""
        key = (batch, seq)
        if key not in self._prefills:
            cfg, ctx, total = self.cfg, self.ctx, self.max_len

            def fn(p, toks, lens, keys, temp, topk):
                logits, caches = T.prefill(p, {"tokens": toks}, cfg, ctx,
                                           total_len=total, prompt_lens=lens)
                tok, keys = sample_tokens(logits, keys, temp, topk)
                return tok, keys, caches

            # lint: allow[missing-donate] lockstep/parity path: caches are fresh outputs, no carry to donate
            self._prefills[key] = jax.jit(fn)
        return self._prefills[key]

    def admit_fn(self, seq: int, n_patches: int = 0):
        """Jitted single-request admission: prefill + first-token sample +
        in-place pool-cache row insert, ONE dispatch per admitted request.
        Returns (token (1,), new_keys (1,2), new pool caches). n_patches > 0
        adds a VLM patch-embedding operand spliced by the prefill."""
        key = (seq, n_patches)
        if key not in self._admits:
            cfg, ctx, total = self.cfg, self.ctx, self.max_len
            insert = self._insert_impl

            def fn(p, pool, toks, lens, keys, temp, topk, slot, patches=None):
                batch = {"tokens": toks}
                if patches is not None:
                    batch["patches"] = patches
                logits, one = T.prefill(p, batch, cfg, ctx,
                                        total_len=total, prompt_lens=lens)
                tok, keys = sample_tokens(logits, keys, temp, topk)
                return tok, keys, insert(pool, one, slot)

            self._admits[key] = jax.jit(fn, donate_argnums=(1,))
        return self._admits[key]

    # -------------------------------------------------------------- public

    @property
    def num_active(self) -> int:
        return self._n_active

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def has_work(self) -> bool:
        return self._n_active > 0 or bool(self.queue)

    def submit(self, req: Request) -> int:
        """Queue a request; returns its request_id."""
        L = len(req.prompt)
        if L < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        if L + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt_len {L} + max_new_tokens {req.max_new_tokens} exceeds "
                f"engine max_len {self.max_len}")
        if req.patches is not None:
            if self.cfg.arch_type != "vlm":
                raise ValueError(
                    f"patches passed to a {self.cfg.arch_type} arch "
                    f"({self.cfg.name}); only vlm archs splice patch embeddings")
            P = np.asarray(req.patches).shape[0]
            if L < P + 2:
                raise ValueError(
                    f"vlm prompt_len {L} too short to splice {P} patches "
                    f"(needs >= {P + 2}: BOS + patches + >=1 text token)")
        if req.request_id is None:
            req.request_id = self._next_id
        self._next_id = max(self._next_id, req.request_id) + 1
        req._submitted_s = time.perf_counter()
        self.queue.append(req)
        return req.request_id

    def step(self) -> bool:
        """One engine iteration: admit queued requests into free slots, then
        advance every active slot one token. Returns False once drained.
        Busy time accumulates into run_wall_s, so stats() is meaningful for
        callers driving step() under their own loop (idle time between steps —
        e.g. waiting for arrivals — is the caller's to account)."""
        t0 = time.perf_counter()
        self._admit()
        if self._n_active == 0:
            self.run_wall_s += time.perf_counter() - t0
            return False
        toks, keys, self.caches = self._step(
            self.params, self.caches, jnp.asarray(self._tokens), jnp.asarray(self._t),
            jnp.asarray(self._keys), jnp.asarray(self._temp), jnp.asarray(self._topk))
        # ONE batched host transfer per engine step (tokens + rng keys)
        toks, keys = jax.device_get((toks, keys))  # lint: allow[host-sync-in-hot-loop] the single per-step sync point
        self._keys = keys.copy()  # jax->np views are read-only
        self.decode_steps += 1
        self.slot_steps += self._n_active
        for slot in range(self.max_batch):
            st = self._active[slot]
            if st is None:
                continue
            self._t[slot] += 1
            self._accept(st, int(toks[slot]))
        self.run_wall_s += time.perf_counter() - t0
        return True

    def run(self, requests: Optional[Sequence[Request]] = None) -> List[Completion]:
        """Submit `requests` (if given) and drain the engine. Returns the
        completions produced by this call, in finish order."""
        for r in requests or ():
            self.submit(r)
        n0 = len(self.completions)
        while self.step():
            pass
        return self.completions[n0:]

    def reset_stats(self):
        """Zero the aggregate counters (bench warmup); requires an idle engine."""
        if self._n_active or self.queue:
            raise ValueError("reset_stats on a busy engine")
        self.completions = []
        self.decode_steps = 0
        self.prefill_calls = 0
        self.slot_steps = 0
        self.run_wall_s = 0.0

    def stats(self) -> dict:
        """Aggregate throughput/latency over the completions so far."""
        new_tokens = sum(c.new_tokens for c in self.completions)
        out = {
            "n_completed": len(self.completions),
            "new_tokens": new_tokens,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "wall_s": self.run_wall_s,
            "tokens_per_s": new_tokens / self.run_wall_s if self.run_wall_s else 0.0,
            # useful fraction of the decode grid (active slots / B per step)
            "occupancy": (self.slot_steps / (self.decode_steps * self.max_batch)
                          if self.decode_steps else 0.0),
        }
        if self.completions:
            out["mean_ttft_s"] = float(np.mean([c.ttft_s for c in self.completions]))
            out["mean_latency_s"] = float(np.mean([c.latency_s for c in self.completions]))
        return out

    # ------------------------------------------------------------ internals

    def _admit(self):
        slot = 0
        while self.queue:
            while slot < self.max_batch and self._active[slot] is not None:
                slot += 1
            if slot == self.max_batch:
                return
            self._prefill_into(slot, self.queue.popleft())

    def _prefill_into(self, slot: int, req: Request):
        L = len(req.prompt)
        Sb = self.bucket_len(L)
        toks = np.zeros((1, Sb), np.int32)
        toks[0, :L] = np.asarray(req.prompt, np.int32)  # lint: allow[host-sync-in-hot-loop] host list -> np, no device involved
        sp = req.sampling
        key0 = jnp.asarray(jax.random.PRNGKey(sp.seed), jnp.uint32)
        kw = {}
        n_patches = 0
        if req.patches is not None:
            patches = np.asarray(req.patches, np.float32)  # lint: allow[host-sync-in-hot-loop] host ndarray coercion, no device involved
            n_patches = patches.shape[0]
            kw["patches"] = jnp.asarray(patches[None])
        tok, k1, self.caches = self.admit_fn(Sb, n_patches)(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray([L], np.int32),
            key0[None], jnp.asarray([sp.eff_temperature], np.float32),
            jnp.asarray([sp.eff_top_k], np.int32), jnp.asarray(slot, jnp.int32),
            **kw)
        self.prefill_calls += 1
        # ONE batched host transfer per admission (first token + rng key)
        tok, k1 = jax.device_get((tok, k1))  # lint: allow[host-sync-in-hot-loop] the single per-admission sync point
        now = time.perf_counter()
        st = _Active(req=req, slot=slot, prompt_len=L, tokens=[],
                     submitted_s=getattr(req, "_submitted_s", now),
                     admitted_s=now, first_token_s=now)
        self._active[slot] = st
        self._n_active += 1
        self._t[slot] = L            # position of the first generated token
        self._keys[slot] = k1[0]
        self._temp[slot] = sp.eff_temperature
        self._topk[slot] = sp.eff_top_k
        self._accept(st, int(tok[0]))

    def _accept(self, st: _Active, tok: int):
        if not st.tokens:
            st.first_token_s = time.perf_counter()
        st.tokens.append(tok)
        self._tokens[st.slot, 0] = tok
        if st.req.on_token is not None:
            st.req.on_token(st.req.request_id, tok)
        if self.eos_id is not None and tok == self.eos_id:
            self._finish(st, "eos")
        elif len(st.tokens) >= st.req.max_new_tokens:
            self._finish(st, "length")

    def _finish(self, st: _Active, reason: str):
        self.completions.append(Completion(
            request_id=st.req.request_id, prompt_len=st.prompt_len,
            tokens=st.tokens, finish_reason=reason, slot=st.slot,
            submitted_s=st.submitted_s, admitted_s=st.admitted_s,
            first_token_s=st.first_token_s, finished_s=time.perf_counter()))
        self._active[st.slot] = None
        self._n_active -= 1
        self._t[st.slot] = 0
        self._tokens[st.slot, 0] = 0


# ----------------------------------------------------------------- baseline


def lockstep_generate(engine: ServeEngine, requests: Sequence[Request],
                      arrival_s: Optional[Sequence[float]] = None,
                      start_s: Optional[float] = None):
    """The barriered baseline the engine replaces, kept measurable: requests
    are grouped in submission order into fixed batches of `engine.max_batch`;
    each batch waits for its SLOWEST member to arrive (`arrival_s`, seconds
    relative to `start_s`), prefills together with prompts right-padded to the
    batch max, then decodes with one shared position until the longest member
    finishes — early-finished slots keep burning decode steps (tokens
    discarded), and no slot is recycled mid-batch.

    Reuses the engine's jitted decode/sampler (identical compiles and token
    streams for equal-length greedy batches — the parity oracle in
    tests/test_serve.py); the engine's own pool state is untouched.
    Returns (completions, stats_dict).
    """
    if any(r.patches is not None for r in requests):
        raise ValueError("lockstep_generate is token-only; vlm patch requests "
                         "go through ServeEngine")
    B = engine.max_batch
    t0 = start_s if start_s is not None else time.perf_counter()
    completions: List[Completion] = []
    decode_steps = 0
    slot_steps = 0
    for g0 in range(0, len(requests), B):
        group = list(requests[g0:g0 + B])
        if arrival_s is not None:
            barrier = max(arrival_s[g0:g0 + len(group)])
            wait = barrier - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
        sub_s = [
            (t0 + arrival_s[g0 + i]) if arrival_s is not None
            else getattr(r, "_submitted_s", t0)
            for i, r in enumerate(group)
        ]
        admit_s = time.perf_counter()

        Lmax = max(len(r.prompt) for r in group)
        Sb = engine.bucket_len(Lmax)
        toks = np.zeros((B, Sb), np.int32)
        lens = np.ones((B,), np.int32)
        temp = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for i, r in enumerate(group):
            toks[i, :len(r.prompt)] = np.asarray(r.prompt, np.int32)
            lens[i] = len(r.prompt)
            temp[i] = r.sampling.eff_temperature
            topk[i] = r.sampling.eff_top_k
            keys[i] = np.asarray(jax.random.PRNGKey(r.sampling.seed), np.uint32)

        tok, keys_d, caches = engine.prefill_fn(B, Sb)(
            engine.params, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(keys), jnp.asarray(temp), jnp.asarray(topk))
        tok = np.asarray(tok)
        out = [[] for _ in group]
        done = [False] * len(group)
        first_s = [0.0] * len(group)
        finish_s = [0.0] * len(group)
        reason = ["length"] * len(group)

        def accept(i, tk):
            if done[i]:
                return
            if not out[i]:
                first_s[i] = time.perf_counter()
            out[i].append(tk)
            r = group[i]
            if r.on_token is not None:
                r.on_token(r.request_id if r.request_id is not None else g0 + i, tk)
            if engine.eos_id is not None and tk == engine.eos_id:
                done[i], reason[i] = True, "eos"
            elif len(out[i]) >= r.max_new_tokens:
                done[i] = True
            if done[i]:
                finish_s[i] = time.perf_counter()

        for i in range(len(group)):
            accept(i, int(tok[i]))
        cur = np.zeros((B, 1), np.int32)
        cur[:len(group), 0] = tok[:len(group)]
        # one SHARED position for the whole batch: everyone decodes from the
        # padded Lmax, and the batch runs until its last member finishes
        t = Lmax
        while not all(done):
            slot_steps += sum(1 for d in done if not d)  # still-useful slots
            tok, keys_d, caches = engine._step(
                engine.params, caches, jnp.asarray(cur),
                jnp.asarray(np.full((B,), t, np.int32)),
                keys_d, jnp.asarray(temp), jnp.asarray(topk))
            tok = np.asarray(tok)
            decode_steps += 1
            t += 1
            for i in range(len(group)):
                accept(i, int(tok[i]))
            cur[:, 0] = tok

        for i, r in enumerate(group):
            completions.append(Completion(
                request_id=r.request_id if r.request_id is not None else g0 + i,
                prompt_len=len(r.prompt), tokens=out[i], finish_reason=reason[i],
                slot=i, submitted_s=sub_s[i], admitted_s=admit_s,
                first_token_s=first_s[i], finished_s=finish_s[i]))

    wall = time.perf_counter() - t0
    new_tokens = sum(c.new_tokens for c in completions)
    stats = {
        "n_completed": len(completions),
        "new_tokens": new_tokens,
        "decode_steps": decode_steps,
        "wall_s": wall,
        "tokens_per_s": new_tokens / wall if wall else 0.0,
        "occupancy": slot_steps / (decode_steps * B) if decode_steps else 0.0,
    }
    if completions:
        stats["mean_ttft_s"] = float(np.mean([c.ttft_s for c in completions]))
        stats["mean_latency_s"] = float(np.mean([c.latency_s for c in completions]))
    return completions, stats
