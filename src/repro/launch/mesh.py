"""Production mesh definitions.

make_production_mesh is a FUNCTION (never a module-level constant) so importing
this module does not touch jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

import jax

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_small_mesh():
    """4x2 = 8 placeholder chips (data, model): the --small dry-run mesh the
    roofline benchmark self-generates records on (REPRO_DRYRUN_DEVICES=8)."""
    return make_mesh((4, 2), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over real local devices (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))
