"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) lowers,
compiles, fits, and extract the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]

Per combination this:
  1. builds the production mesh (16x16, or 2x16x16 with --multi-pod),
  2. lowers + compiles the right step (train/prefill/decode) with full
     shardings and the guided-SSGD optimizer in-graph (for train),
  3. records memory_analysis() (proves it fits), cost_analysis() FLOPs/bytes,
     and the collective schedule parsed from the compiled HLO,
  4. separately lowers ONE layer super-block to get per-layer FLOPs/bytes/
     collective bytes: XLA's cost analysis counts a lax.scan body ONCE
     regardless of trip count, so whole-step numbers must be corrected by
     n_super x block terms (see EXPERIMENTS.md §Roofline for the arithmetic),
  5. writes results/dryrun/<arch>__<shape>__<mesh>[__<rules>].json.
"""
# The placeholder devices MUST be configured before jax initializes. 512
# covers the production meshes (16x16 and 2x16x16); REPRO_DRYRUN_DEVICES
# overrides it so small-mesh self-generation (--small, used by the roofline
# benchmark on CI) doesn't pay 512 threadpools for an 8-device mesh.
import os

_FORCED_DEVICES = int(os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_FORCED_DEVICES} "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, ARCH_IDS, get_config
from repro.core.guided import GuidedConfig
from repro.launch.mesh import make_production_mesh, make_small_mesh
from repro.models import transformer as T
from repro.models.module import split_params
from repro.optim import constant, get_optimizer
from repro.sharding.rules import DEFAULT_RULES, MULTIPOD_RULES, SERVE_TP_ONLY_RULES, ShardCtx
from repro.train import steps as S

# ----------------------------------------------------------------- hardware
# TPU v5e-class chip constants (targets; this host only compiles).
PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / ICI link

DTYPE_BYTES = {"bf16": 2, "f32": 4, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

RULE_SETS = {
    "default": (DEFAULT_RULES, MULTIPOD_RULES),
    "serve_tp": (SERVE_TP_ONLY_RULES, SERVE_TP_ONLY_RULES.replace(batch=("pod", "data"))),
    "no_seqkv": (DEFAULT_RULES.replace(seq_kv=()), MULTIPOD_RULES.replace(seq_kv=())),
    "fsdp_pods": (DEFAULT_RULES, MULTIPOD_RULES.replace(fsdp=("pod", "data"))),
    # sequence parallelism: inter-block activations sharded over `model`
    "seqpar": (DEFAULT_RULES.replace(seq=("model",)), MULTIPOD_RULES.replace(seq=("model",))),
    "seqpar_tp": (SERVE_TP_ONLY_RULES.replace(seq=("model",)),
                  SERVE_TP_ONLY_RULES.replace(batch=("pod", "data"), seq=("model",))),
}


# ----------------------------------------------------------------- planning


def plan(arch: str, shape_name: str):
    """Returns (cfg, kind, note) or (None, None, skip_reason)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    note = ""
    if shape.kind == "decode" and not cfg.supports_decode:
        return None, None, f"{cfg.name} is encoder-only: no decode step (DESIGN.md §5)"
    if shape_name == "long_500k":
        if not cfg.supports_long_context():
            if cfg.arch_type in ("dense", "moe", "vlm"):
                cfg = cfg.replace(sliding_window=8192)
                note = "sliding-window-8192 variant (sub-quadratic requirement)"
            else:
                return None, None, f"{cfg.name}: no sub-quadratic attention path"
    return cfg, shape.kind, note


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg, seq_len: int, global_batch: int):
    if cfg.audio_frontend:
        return {
            "frames": _sds((global_batch, seq_len, cfg.d_model), jnp.bfloat16),
            "mask_positions": _sds((global_batch, seq_len), jnp.bool_),
            "labels": _sds((global_batch, seq_len), jnp.int32),
            "mask": _sds((global_batch, seq_len), jnp.float32),
        }
    b = {
        "tokens": _sds((global_batch, seq_len), jnp.int32),
        "labels": _sds((global_batch, seq_len), jnp.int32),
    }
    if cfg.arch_type == "vlm" and cfg.n_patches:
        b["patches"] = _sds((global_batch, cfg.n_patches, cfg.d_model), cfg.dtype)
    return b


# ----------------------------------------------------------- HLO collectives


def collective_bytes_from_hlo(txt: str) -> dict:
    """Sum result-shape bytes of every collective op in the per-device module.
    all-reduce counts 2x (ring reduce-scatter + all-gather equivalent)."""
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    # result shapes: `bf16[8,128,2048]{...} all-gather(` and tuple variants
    pat = re.compile(
        r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)(?:-start|-done)?\(")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(txt):
        shapes, op = m.group(1), m.group(2)
        base = None
        for k in COLLECTIVES:
            if op == k or op == k + "-start":
                base = k
        if base is None:
            continue
        nbytes = 0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        mult = 2.0 if base == "all-reduce" else 1.0
        out[base] += mult * nbytes
        counts[base] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def _dedup_start_done(txt: str) -> str:
    # drop `-done` lines so async collectives are not double counted
    return "\n".join(l for l in txt.splitlines() if "-done(" not in l and "-done.(" not in l)


# ----------------------------------------------------------------- analysis


def analyze_compiled(compiled):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    txt = _dedup_start_done(compiled.as_text())
    coll = collective_bytes_from_hlo(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "collectives": coll,
    }


def model_flops_analytic(cfg, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    # active params: replace expert count with topk in MoE ffn weights
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    per_layer = 0.0
    for i in range(T.period(cfg)):
        mk = T.mixer_kind(cfg, i)
        if mk == "attn":
            per_layer += d * (H * dh + 2 * K * dh) + H * dh * d
        elif mk == "mamba":
            ed = cfg.ssm.expand * d
            r = max(1, int(np.ceil(d / 16)))
            per_layer += d * 2 * ed + ed * (r + 2 * cfg.ssm.d_state) + r * ed + ed * d
        elif mk in ("mlstm", "slstm"):
            di = int((cfg.xlstm.mlstm_proj_factor if mk == "mlstm" else 1.0) * d)
            per_layer += 2 * d * di + 3 * di * di + di * d
            if mk == "slstm":
                per_layer += d * int(cfg.xlstm.slstm_proj_factor * d) * 3
        fk = T.ffn_kind(cfg, i)
        if fk == "dense":
            per_layer += (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        elif fk == "moe":
            per_layer += cfg.moe.topk * 3 * d * cfg.d_ff + d * cfg.moe.n_experts
    n_active = (L // T.period(cfg)) * per_layer + 2 * V * d
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens


# ------------------------------------------------------------------ lowering


def build_ctx(mesh, multi_pod: bool, rules_name: str, moe_impl: str = "gather") -> ShardCtx:
    single, multi = RULE_SETS[rules_name]
    return ShardCtx(
        mesh=mesh,
        rules=multi if multi_pod else single,
        data_axes=("pod", "data") if multi_pod else ("data",),
        moe_impl=moe_impl,
    )


def lower_train(cfg, ctx, gcfg, opt_name, n_micro: int = 1):
    from repro.core.guided import guided_init

    opt = get_optimizer(opt_name)
    key = jax.random.PRNGKey(0)
    p_struct_boxed = jax.eval_shape(lambda: T.model_init(key, cfg))
    params_struct, logical = split_params(p_struct_boxed)
    p_sh = S.param_shardings(cfg, ctx, logical)(params_struct)
    gstate_struct = jax.eval_shape(
        lambda ps: guided_init(gcfg, ps, opt, ctx.n_workers), params_struct
    )
    g_sh = S.state_shardings(gcfg, opt, p_sh, ctx.mesh)
    step = S.build_train_step(cfg, gcfg, opt, ctx, constant(1e-2), n_micro=n_micro)
    return step, (params_struct, p_sh), (gstate_struct, g_sh)


def run_one(arch, shape_name, multi_pod, rules_name="default", opt_name="sgd",
            correction="fused", out_dir="results/dryrun", block_too=True,
            moe_impl="gather", micro_override=0, attn_impl="", kv_cache="",
            small=False):
    t0 = time.time()
    mesh_name = "mesh4x2" if small else ("pod2x16x16" if multi_pod else "pod16x16")
    variant = "" if rules_name == "default" else f"__{rules_name}"
    if moe_impl != "gather":
        variant += f"__moe-{moe_impl}"
    if micro_override:
        variant += f"__micro{micro_override}"
    if attn_impl:
        variant += f"__attn-{attn_impl}"
    if kv_cache:
        variant += f"__kv-{kv_cache}"
    tag = f"{arch}__{shape_name}__{mesh_name}" + variant
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")

    cfg, kind, note = plan(arch, shape_name)
    if cfg is not None and attn_impl:
        cfg = cfg.replace(attn_impl=attn_impl)
    if cfg is not None and kv_cache:
        cfg = cfg.replace(kv_cache_dtype=kv_cache)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "rules": rules_name,
              "moe_impl": moe_impl, "micro_override": micro_override,
              "attn_impl": attn_impl or "xla",
              "kind": kind, "note": note, "ok": False}
    if cfg is None:
        record.update({"skipped": True, "ok": True})
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] {tag}: SKIP ({note})")
        return record

    shape = INPUT_SHAPES[shape_name]
    if small:
        # --small: compile the reduced config on an 8-chip (4x2) mesh with a
        # shrunk shape so the whole dry-run finishes in seconds on a CPU host
        # (REPRO_DRYRUN_DEVICES=8). Same lowering path, same record format —
        # only mesh_name/"mesh4x2" distinguishes these from production runs.
        cfg = cfg.reduced()
        shape = dataclasses.replace(
            shape,
            seq_len=min(shape.seq_len, 128 if kind == "train" else 256),
            global_batch=min(shape.global_batch, 8))
        mesh = make_small_mesh()
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = build_ctx(mesh, multi_pod, rules_name, moe_impl)
    chips = int(np.prod(list(mesh.shape.values())))

    try:
        from jax.sharding import NamedSharding, PartitionSpec as P

        repl = NamedSharding(mesh, P())
        if kind == "train":
            gcfg = GuidedConfig(mode="ssgd", guided=True, correction=correction)
            # microbatch to per-worker rows of 1: remat-saved activations per
            # layer then hold a single example row per device (DESIGN.md §4)
            n_micro = micro_override or max(1, shape.global_batch // max(ctx.n_workers, 1))
            record["n_micro"] = n_micro
            step, (ps, p_sh), (gs, g_sh) = lower_train(cfg, ctx, gcfg, opt_name, n_micro)
            bs = batch_struct(cfg, shape.seq_len, shape.global_batch)
            b_sh = S.batch_shardings(cfg, ctx, bs)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, g_sh, b_sh),
                out_shardings=(p_sh, g_sh, jax.tree.map(lambda _: repl, {"loss": 0, "worker_loss_var": 0, "corr_weight_sum": 0, "lr": 0, "step": 0})),
                donate_argnums=(0, 1),
            ).lower(ps, gs, bs)
            n_tokens = shape.global_batch * shape.seq_len
        elif kind == "prefill":
            step = S.build_prefill_step(cfg, ctx)
            key = jax.random.PRNGKey(0)
            p_struct_boxed = jax.eval_shape(lambda: T.model_init(key, cfg))
            ps, logical = split_params(p_struct_boxed)
            p_sh = S.param_shardings(cfg, ctx, logical)(ps)
            bs = batch_struct(cfg, shape.seq_len, shape.global_batch)
            bs.pop("labels", None)
            bs.pop("mask", None)
            b_sh = S.batch_shardings(cfg, ctx, bs)
            cache_struct = jax.eval_shape(lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))
            c_sh = S.cache_shardings(cfg, ctx, cache_struct)
            logits_sh = S.batch_shardings(cfg, ctx, {"x": _sds((shape.global_batch, 8), jnp.float32)})["x"]
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh), out_shardings=(logits_sh, c_sh)
            ).lower(ps, bs)
            n_tokens = shape.global_batch * shape.seq_len
        else:  # decode
            step = S.build_decode_step(cfg, ctx)
            key = jax.random.PRNGKey(0)
            p_struct_boxed = jax.eval_shape(lambda: T.model_init(key, cfg))
            ps, logical = split_params(p_struct_boxed)
            p_sh = S.param_shardings(cfg, ctx, logical)(ps)
            cache_struct = jax.eval_shape(lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))
            c_sh = S.cache_shardings(cfg, ctx, cache_struct)
            toks = _sds((shape.global_batch, 1), jnp.int32)
            tok_sh = S.batch_shardings(cfg, ctx, {"t": toks})["t"]
            t_struct = _sds((), jnp.int32)
            logits_sh = S.batch_shardings(cfg, ctx, {"x": _sds((shape.global_batch, 8), jnp.float32)})["x"]
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, repl),
                out_shardings=(logits_sh, c_sh),
                donate_argnums=(1,),
            ).lower(ps, cache_struct, toks, t_struct)
            n_tokens = shape.global_batch  # one new token per sequence

        compiled = lowered.compile()
        full = analyze_compiled(compiled)
        record["full_step"] = full

        # ---- per-super-block lowering (scan-body trip-count correction).
        # For train the block is lowered at the MICRO batch and scaled by
        # n_super * n_micro: weight-proportional collectives (FSDP gathers,
        # grad reductions) repeat per microbatch, token-proportional ones
        # scale with tokens — lowering at micro scale gets both right.
        n_sup = T.n_super(cfg)
        record["n_super"] = n_sup
        n_micro_eff = record.get("n_micro", 1) if kind == "train" else 1
        if block_too:
            record["block"] = lower_block(cfg, ctx, kind, shape, n_micro_eff)

        # ---- roofline terms (per-chip seconds; see EXPERIMENTS.md §Roofline)
        blk = record.get("block") or {}
        n_bodies = n_sup * n_micro_eff
        flops_c = full["flops"] + max(n_bodies - 1, 0) * blk.get("flops", 0.0)
        bytes_c = full["bytes_accessed"] + max(n_bodies - 1, 0) * blk.get("bytes_accessed", 0.0)
        coll_c = full["collectives"]["total_bytes"] + max(n_bodies - 1, 0) * blk.get("coll_bytes", 0.0)
        terms = {
            "compute_s": flops_c / PEAK_FLOPS,
            "memory_s": bytes_c / HBM_BW,
            "collective_s": coll_c / LINK_BW,
            "flops_corrected": flops_c,
            "bytes_corrected": bytes_c,
            "collective_bytes_corrected": coll_c,
        }
        terms["dominant"] = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
        mf = model_flops_analytic(cfg, n_tokens, kind)
        terms["model_flops_total"] = mf
        terms["model_flops_per_chip"] = mf / chips
        terms["useful_ratio"] = (mf / chips) / max(flops_c, 1.0)
        record["roofline"] = terms
        record["ok"] = True
        record["compile_s"] = round(time.time() - t0, 1)
        mem_gb = full["memory"]["peak_estimate_bytes"] / 2**30
        print(f"[dryrun] {tag}: OK mem/dev={mem_gb:.2f}GiB "
              f"compute={terms['compute_s']*1e3:.2f}ms memory={terms['memory_s']*1e3:.2f}ms "
              f"coll={terms['collective_s']*1e3:.2f}ms dom={terms['dominant']} "
              f"useful={terms['useful_ratio']:.2f} ({record['compile_s']}s)")
    except Exception as e:  # noqa
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: FAIL {record['error'][:300]}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def lower_block(cfg, ctx, kind, shape, n_micro: int = 1):
    """Lower one layer super-block standalone for per-layer roofline terms.
    For train, B is the microbatch (see run_one)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    key = jax.random.PRNGKey(0)
    bp_boxed = jax.eval_shape(lambda: T.block_init(key, cfg))
    bp_struct, logical = split_params(bp_boxed)
    bp_sh = S.param_shardings(cfg, ctx, logical)(bp_struct)
    B = max(shape.global_batch // n_micro, 1)
    Sq = 1 if kind == "decode" else shape.seq_len
    x = _sds((B, Sq, cfg.d_model), cfg.dtype)
    x_sh = S.batch_shardings(cfg, ctx, {"x": x})["x"]
    pos = _sds((B, Sq), jnp.int32)
    repl = NamedSharding(ctx.mesh, P())

    if kind == "train":
        def f(bp, xv, p):
            y, aux, _ = T.block_apply(bp, xv, cfg, ctx, p)
            return jnp.sum(y.astype(jnp.float32)) + aux

        g = jax.jit(jax.grad(f), in_shardings=(bp_sh, x_sh, S.batch_shardings(cfg, ctx, {"p": pos})["p"]),
                    out_shardings=bp_sh)
        lowered = g.lower(bp_struct, x, pos)
    else:
        caches = None
        if kind == "decode":
            one = {f"l{i}": T.layer_cache_init(cfg, i, B, T.cache_len_for(cfg, shape.seq_len)) for i in range(T.period(cfg))}
            cache_struct = jax.eval_shape(lambda: one)
            c_log = {k: v for k, v in T.cache_logical(cfg).items()}
            c_log = jax.tree.map(lambda t: tuple(t[1:]), c_log,
                                 is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v))
            c_sh = jax.tree.map(
                lambda log, leaf: NamedSharding(ctx.mesh, __import__("repro.sharding.rules", fromlist=["logical_to_spec"]).logical_to_spec(log, ctx.rules, ctx.mesh, leaf.shape)),
                c_log, cache_struct,
                is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v))

            def f(bp, xv, p, c):
                y, aux, nc = T.block_apply(bp, xv, cfg, ctx, p, caches=c, t=jnp.asarray(17, jnp.int32))
                return y, nc

            lowered = jax.jit(f, in_shardings=(bp_sh, x_sh, repl, c_sh),
                              out_shardings=(x_sh, c_sh)).lower(bp_struct, x, pos, cache_struct)
        else:
            def f(bp, xv, p):
                y, aux, _ = T.block_apply(bp, xv, cfg, ctx, p)
                return y

            lowered = jax.jit(f, in_shardings=(bp_sh, x_sh, S.batch_shardings(cfg, ctx, {"p": pos})["p"]),
                              out_shardings=x_sh).lower(bp_struct, x, pos)
    compiled = lowered.compile()
    a = analyze_compiled(compiled)
    return {"flops": a["flops"], "bytes_accessed": a["bytes_accessed"],
            "coll_bytes": a["collectives"]["total_bytes"],
            "collectives": a["collectives"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--rules", default="default", choices=list(RULE_SETS))
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--correction", default="fused", choices=["fused", "two_pass"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--moe-impl", default="gather", choices=["gather", "alltoall"])
    ap.add_argument("--micro", type=int, default=0, help="override n_micro for train")
    ap.add_argument("--attn-impl", default="", choices=["", "xla", "xla_chunked"])
    ap.add_argument("--kv", default="", choices=["", "native", "int8"])
    ap.add_argument("--no-block", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--small", action="store_true",
                    help="reduced config on a 4x2 mesh with shrunk shapes "
                         "(set REPRO_DRYRUN_DEVICES=8; used by bench_roofline)")
    args = ap.parse_args()

    archs = [a for a in ARCH_IDS if a != "paper_logreg"] if args.all or not args.arch else [args.arch.replace("-", "_")]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    if args.small:
        pods = [False]  # the small mesh has no pod axis

    failures = 0
    for mp in pods:
        for arch in archs:
            for shp in shapes:
                mesh_name = "mesh4x2" if args.small else ("pod2x16x16" if mp else "pod16x16")
                variant = "" if args.rules == "default" else f"__{args.rules}"
                if args.moe_impl != "gather":
                    variant += f"__moe-{args.moe_impl}"
                if args.micro:
                    variant += f"__micro{args.micro}"
                if args.attn_impl:
                    variant += f"__attn-{args.attn_impl}"
                if args.kv:
                    variant += f"__kv-{args.kv}"
                tag = f"{arch}__{shp}__{mesh_name}" + variant
                if args.skip_existing and os.path.exists(os.path.join(args.out, tag + ".json")):
                    with open(os.path.join(args.out, tag + ".json")) as f:
                        if json.load(f).get("ok"):
                            print(f"[dryrun] {tag}: cached")
                            continue
                rec = run_one(arch, shp, mp, args.rules, args.optimizer, args.correction,
                              args.out, block_too=not args.no_block,
                              moe_impl=args.moe_impl, micro_override=args.micro,
                              attn_impl=args.attn_impl, kv_cache=args.kv,
                              small=args.small)
                failures += 0 if rec.get("ok") else 1
    print(f"[dryrun] done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
