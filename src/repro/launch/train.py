"""Training launcher over the unified engine API.

Runs any assigned architecture (full or --reduced) with a pluggable
delay-compensation strategy (repro.engine.strategies registry). On this CPU
host the practical entry points are the reduced configs (examples/, smoke
tests); on a real TPU slice the same driver runs the production mesh via
--mesh prod / prod-multipod.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 200 --mode ssgd --strategy guided_fused --rho 10 --log-every 10 \
      --ckpt-dir /tmp/run1 --ckpt-every 50

Preempted? The same command plus --resume restarts bit-exactly from the
latest manifest entry (full state: params AND the guided compensation state —
see DESIGN.md §8). Checkpointing is owned by the Trainer, which snapshots
asynchronously off the hot path and installs a SIGTERM-safe final save; this
launcher only sets the knobs. (It used to save `{"params": params}` itself
from inside on_step — buffers that the next jit dispatch donates, and a
snapshot that silently dropped the entire GuidedState.)

Any strategy registered with @register_compensator is selectable here by name
without touching this file or the train step.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.engine import ExperimentSpec, Trainer, build_ctx, compensator_names  # noqa: F401
from repro.engine.spec import SCHEDULES

# build_ctx re-exported for back-compat (serve and older scripts imported it here)


def spec_from_args(args) -> ExperimentSpec:
    strategy = args.strategy
    mode = args.mode
    if mode == "dc_asgd":  # legacy spelling: execution mode asgd + Taylor strategy
        mode = "asgd"
        strategy = strategy or ("dc_asgd_guided" if args.guided else "dc_asgd")
    if not strategy:
        strategy = "guided_fused" if args.guided else "none"
    overrides = []
    if args.layers:
        overrides.append(("n_layers", args.layers))
    if args.d_model:
        overrides.append(("d_model", args.d_model))
    if args.d_ff:
        overrides.append(("d_ff", args.d_ff))
    return ExperimentSpec(
        backend="mesh",
        arch=args.arch,
        reduced=args.reduced,
        model_overrides=tuple(overrides),
        mode=mode,
        strategy=strategy,
        rho=args.rho,
        optimizer=args.optimizer,
        lr=args.lr,
        schedule=args.schedule,
        steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        mesh=args.mesh,
        workers=args.workers,
        micro=args.micro,
        chunk_steps=args.chunk_steps,
        prefetch=args.prefetch,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        keep_last=args.keep_last,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mode", default="ssgd", choices=["seq", "ssgd", "asgd", "dc_asgd"])
    ap.add_argument("--guided", action="store_true",
                    help="shorthand for --strategy guided_fused")
    ap.add_argument("--strategy", default="",
                    help=f"delay-compensation strategy; registered: {', '.join(compensator_names())}")
    ap.add_argument("--rho", type=int, default=10)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    # choices come from the spec's canonical tuple: cosine was supported by
    # ExperimentSpec/Trainer all along but rejected here by a stale hardcoded list
    ap.add_argument("--schedule", default="constant", choices=list(SCHEDULES))
    ap.add_argument("--mesh", default="local", choices=["local", "host", "prod", "prod-multipod"])
    ap.add_argument("--workers", type=int, default=0, help="logical worker count c (local mesh)")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="fuse K train steps into ONE jitted lax.scan dispatch "
                         "(bit-exact with K=1; big win when per-step compute "
                         "is small — see BENCH_train.json)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered async host->device batch staging "
                         "(overlaps generation + H2D with the in-flight chunk)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoint retention (manifest prunes older snapshots; 0 keeps all)")
    ap.add_argument("--resume", action="store_true",
                    help="resume bit-exactly from the latest manifest entry in --ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    spec = spec_from_args(args)
    trainer = Trainer.from_spec(spec)

    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume needs --ckpt-dir")
        from repro.checkpoint import latest_step

        at = latest_step(args.ckpt_dir)
        print(f"resuming from step {at} in {args.ckpt_dir}" if at is not None
              else f"no checkpoint in {args.ckpt_dir}; starting fresh")

    history = []
    t0 = time.time()

    def on_step(step, m, params):
        # m holds raw device metrics — per-step scalars (chunk_steps=1) or
        # stacked (k,) chunk arrays with step = the chunk's LAST step index;
        # step_records only forces the host sync when a log step falls
        # inside the window (empty selection -> no transfer)
        from repro.engine.trainloop import step_records

        shape = getattr(m["loss"], "shape", ())
        k = shape[0] if shape else 1
        first = step - k + 1
        logged = [i for i in range(k)
                  if (first + i) % args.log_every == 0 or first + i == args.steps - 1]
        for rec in step_records(m, first, logged):
            history.append(rec)
            print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                  f"worker_var {rec['worker_var']:.2e} "
                  f"corr_w {rec['corr_w']:.2f} ({time.time()-t0:.1f}s)")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            print(f"checkpoint enqueued at step {step + 1}")

    # the launcher keeps its own log-step history; don't retain per-step
    # metrics. Checkpointing (periodic async snapshots + the final/SIGTERM
    # full-state save) is the Trainer's: spec.ckpt_dir/ckpt_every/keep_last.
    report = trainer.fit(on_step=on_step, keep_history=False, resume=args.resume)

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    if report.interrupted:
        print(f"interrupted by SIGTERM at step {report.start_step + report.n_steps}; "
              f"full state saved to {args.ckpt_dir} — rerun with --resume")
    if report.warm_steps:
        print(f"throughput: {report.steps_per_s:.1f} steps/s warm "
              f"(first dispatch incl. jit compile: {report.compile_time_s:.2f}s)")
    if history:
        print(f"done: final loss {history[-1]['loss']:.4f}")
    else:  # resumed at (or past) the final step: nothing left to run
        print(f"done: no steps to run (resumed at step {report.start_step})")
    return history


if __name__ == "__main__":
    main()
