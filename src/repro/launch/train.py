"""Training launcher over the unified engine API.

Runs any assigned architecture (full or --reduced) with a pluggable
delay-compensation strategy (repro.engine.strategies registry). On this CPU
host the practical entry points are the reduced configs (examples/, smoke
tests); on a real TPU slice the same driver runs the production mesh via
--mesh prod / prod-multipod.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 200 --mode ssgd --strategy guided_fused --rho 10 --log-every 10 \
      --ckpt-dir /tmp/run1 --ckpt-every 50

Preempted? The same command plus --resume restarts bit-exactly from the
latest manifest entry (full state: params AND the guided compensation state —
see DESIGN.md §8). Checkpointing is owned by the Trainer, which snapshots
asynchronously off the hot path and installs a SIGTERM-safe final save; this
launcher only sets the knobs. (It used to save `{"params": params}` itself
from inside on_step — buffers that the next jit dispatch donates, and a
snapshot that silently dropped the entire GuidedState.)

Any strategy registered with @register_compensator is selectable here by name
without touching this file or the train step.

Multi-process async training (repro.dist, DESIGN.md §10): --backend dist runs
a REAL parameter server — a chief process owning the versioned store plus
--dist-workers gradient-pushing worker processes — on the paper's tabular
datasets:

  PYTHONPATH=src python -m repro.launch.train --backend dist --dataset pima \
      --mode asgd --strategy dc_asgd --dist-mode live --dist-workers 4 \
      --epochs 20 --dist-events restart:0@50

--role splits the same run across terminals/hosts: `--role chief` starts only
the store+listener (printing the address), `--role worker --addr host:port`
runs one worker process (equivalent to `python -m repro.dist.worker`).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.engine import ExperimentSpec, Trainer, build_ctx, compensator_names  # noqa: F401
from repro.engine.spec import SCHEDULES

# build_ctx re-exported for back-compat (serve and older scripts imported it here)


def parse_dist_events(text: str) -> tuple:
    """'op:wid@version,...' -> ((op, wid, version), ...); e.g.
    'restart:0@50,join:0@80' kills+respawns worker 0 at store version 50 and
    joins an elastic worker at 80."""
    events = []
    for part in filter(None, (p.strip() for p in text.split(","))):
        try:
            op, rest = part.split(":", 1)
            wid, at = rest.split("@", 1)
            events.append((op, int(wid), int(at)))
        except ValueError:
            raise SystemExit(
                f"bad --dist-events entry {part!r}; want op:wid@version "
                f"(e.g. restart:0@50)") from None
    return tuple(events)


def _resolve_strategy_mode(args):
    strategy = args.strategy
    mode = args.mode
    if mode == "dc_asgd":  # legacy spelling: execution mode asgd + Taylor strategy
        mode = "asgd"
        strategy = strategy or ("dc_asgd_guided" if args.guided else "dc_asgd")
    if not strategy:
        strategy = "guided_fused" if args.guided else "none"
    return strategy, mode


def dist_spec_from_args(args) -> ExperimentSpec:
    strategy, mode = _resolve_strategy_mode(args)
    return ExperimentSpec(
        backend="dist",
        mode=mode,
        strategy=strategy,
        rho=args.rho,
        optimizer=args.optimizer,
        lr=args.lr,
        seed=args.seed,
        epochs=args.epochs,
        batch_size=args.batch_size,
        topology=args.topology,
        workers=args.dist_workers,
        dist_mode=args.dist_mode,
        delayed_avg=args.delayed_avg,
        dist_drop_rate=args.drop_rate,
        dist_time_scale=args.time_scale,
        dist_events=parse_dist_events(args.dist_events),
        dist_timeout=args.dist_timeout,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        keep_last=args.keep_last,
    )


def spec_from_args(args) -> ExperimentSpec:
    strategy, mode = _resolve_strategy_mode(args)
    overrides = []
    if args.layers:
        overrides.append(("n_layers", args.layers))
    if args.d_model:
        overrides.append(("d_model", args.d_model))
    if args.d_ff:
        overrides.append(("d_ff", args.d_ff))
    return ExperimentSpec(
        backend="mesh",
        arch=args.arch,
        reduced=args.reduced,
        model_overrides=tuple(overrides),
        mode=mode,
        strategy=strategy,
        rho=args.rho,
        optimizer=args.optimizer,
        lr=args.lr,
        schedule=args.schedule,
        steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        mesh=args.mesh,
        workers=args.workers,
        micro=args.micro,
        chunk_steps=args.chunk_steps,
        prefetch=args.prefetch,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        keep_last=args.keep_last,
    )


def run_dist(args):
    """The --backend dist path: real multi-process async training on the
    paper's tabular datasets. Returns the launcher's result dict."""
    from repro.data import load_dataset, train_test_split
    from repro.dist import launcher

    spec = dist_spec_from_args(args)
    X, y, n_classes = load_dataset(args.dataset, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=spec.seed)
    t0 = time.time()
    res = launcher.run_local(spec, Xtr, ytr, n_classes, Xte, yte,
                             spawn=args.role == "auto", port=args.port)
    dt = time.time() - t0
    d = res["dist"]
    print(f"dist[{spec.dist_mode}] {args.dataset}: {res['n_steps']} server steps "
          f"in {dt:.1f}s ({res['n_steps'] / max(dt, 1e-9):.1f} steps/s), "
          f"val_loss {res['val_loss']:.4f}, test_acc "
          f"{res.get('test_accuracy', float('nan')):.4f}")
    print(f"observed staleness histogram: {res['staleness_hist']}")
    print(f"workers {d['n_workers']}, drops {d['drops']}, late {d['late']}, "
          f"exits {d['worker_exits']}, joins {d['joins']}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"n_steps": res["n_steps"], "val_loss": res["val_loss"],
                       "test_accuracy": res.get("test_accuracy"),
                       "staleness_hist": {str(k): v for k, v in res["staleness_hist"].items()},
                       "dist": d, "wall_time_s": dt}, f, indent=1)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="mesh", choices=["mesh", "dist"],
                    help="mesh: jitted SPMD trainer (default); dist: real "
                         "multi-process async parameter server (repro.dist)")
    ap.add_argument("--arch", default="",
                    help="model architecture (required for --backend mesh)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mode", default="ssgd", choices=["seq", "ssgd", "asgd", "dc_asgd"])
    ap.add_argument("--guided", action="store_true",
                    help="shorthand for --strategy guided_fused")
    ap.add_argument("--strategy", default="",
                    help=f"delay-compensation strategy; registered: {', '.join(compensator_names())}")
    ap.add_argument("--rho", type=int, default=10)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    # choices come from the spec's canonical tuple: cosine was supported by
    # ExperimentSpec/Trainer all along but rejected here by a stale hardcoded list
    ap.add_argument("--schedule", default="constant", choices=list(SCHEDULES))
    ap.add_argument("--mesh", default="local", choices=["local", "host", "prod", "prod-multipod"])
    ap.add_argument("--workers", type=int, default=0, help="logical worker count c (local mesh)")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="fuse K train steps into ONE jitted lax.scan dispatch "
                         "(bit-exact with K=1; big win when per-step compute "
                         "is small — see BENCH_train.json)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered async host->device batch staging "
                         "(overlaps generation + H2D with the in-flight chunk)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoint retention (manifest prunes older snapshots; 0 keeps all)")
    ap.add_argument("--resume", action="store_true",
                    help="resume bit-exactly from the latest manifest entry in --ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    # ------------------------------------------------ dist backend (repro.dist)
    ap.add_argument("--role", default="auto", choices=["auto", "chief", "worker"],
                    help="auto: chief spawns its own workers; chief: listen "
                         "only (workers launched separately); worker: run one "
                         "worker against --addr")
    ap.add_argument("--addr", default="",
                    help="chief address host:port (--role worker)")
    ap.add_argument("--port", type=int, default=0,
                    help="chief listen port (0 = ephemeral)")
    ap.add_argument("--dataset", default="pima",
                    help="tabular dataset for --backend dist (repro.data)")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--topology", default="",
                    help="delay/worker-speed topology ('' = mode default)")
    ap.add_argument("--dist-mode", default="replay", choices=["replay", "live"],
                    help="replay: deterministic schedule-granted interleaving "
                         "(parity oracle); live: free-running asynchrony with "
                         "observed staleness + fault injection")
    ap.add_argument("--dist-workers", type=int, default=0,
                    help="worker processes (0 = the schedule's c = rho)")
    ap.add_argument("--delayed-avg", action="store_true",
                    help="DaSGD-style delayed averaging: overlap push/pull "
                         "with the next local step, merge on reply (live)")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="fraction of pushes the chief drops (live)")
    ap.add_argument("--time-scale", type=float, default=0.0,
                    help="seconds per sampled compute-time unit (live; 0 = "
                         "full speed)")
    ap.add_argument("--dist-events", default="",
                    help="fault plan op:wid@version,... with op in "
                         "kill|restart|join (live), e.g. restart:0@50")
    ap.add_argument("--dist-timeout", type=float, default=120.0,
                    help="watchdog: max seconds without store progress")
    args = ap.parse_args(argv)

    if args.role == "worker":
        from repro.dist.worker import main as worker_main

        if not args.addr:
            raise SystemExit("--role worker needs --addr host:port")
        return worker_main(["--addr", args.addr])
    if args.backend == "dist":
        return run_dist(args)
    if not args.arch:
        raise SystemExit("--backend mesh needs --arch")

    spec = spec_from_args(args)
    trainer = Trainer.from_spec(spec)

    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume needs --ckpt-dir")
        from repro.checkpoint import latest_step

        at = latest_step(args.ckpt_dir)
        print(f"resuming from step {at} in {args.ckpt_dir}" if at is not None
              else f"no checkpoint in {args.ckpt_dir}; starting fresh")

    history = []
    t0 = time.time()

    def on_step(step, m, params):
        # m holds raw device metrics — per-step scalars (chunk_steps=1) or
        # stacked (k,) chunk arrays with step = the chunk's LAST step index;
        # step_records only forces the host sync when a log step falls
        # inside the window (empty selection -> no transfer)
        from repro.engine.trainloop import step_records

        shape = getattr(m["loss"], "shape", ())
        k = shape[0] if shape else 1
        first = step - k + 1
        logged = [i for i in range(k)
                  if (first + i) % args.log_every == 0 or first + i == args.steps - 1]
        for rec in step_records(m, first, logged):
            history.append(rec)
            print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                  f"worker_var {rec['worker_var']:.2e} "
                  f"corr_w {rec['corr_w']:.2f} ({time.time()-t0:.1f}s)")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            print(f"checkpoint enqueued at step {step + 1}")

    # the launcher keeps its own log-step history; don't retain per-step
    # metrics. Checkpointing (periodic async snapshots + the final/SIGTERM
    # full-state save) is the Trainer's: spec.ckpt_dir/ckpt_every/keep_last.
    report = trainer.fit(on_step=on_step, keep_history=False, resume=args.resume)

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    if report.interrupted:
        print(f"interrupted by SIGTERM at step {report.start_step + report.n_steps}; "
              f"full state saved to {args.ckpt_dir} — rerun with --resume")
    if report.warm_steps:
        print(f"throughput: {report.steps_per_s:.1f} steps/s warm "
              f"(first dispatch incl. jit compile: {report.compile_time_s:.2f}s)")
    if history:
        print(f"done: final loss {history[-1]['loss']:.4f}")
    else:  # resumed at (or past) the final step: nothing left to run
        print(f"done: no steps to run (resumed at step {report.start_step})")
    return history


if __name__ == "__main__":
    main()
