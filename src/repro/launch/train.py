"""Training launcher.

Runs any assigned architecture (full or --reduced) with the guided delay-
compensated data-parallel optimizer. On this CPU host the practical entry
points are the reduced configs (examples/, smoke tests); on a real TPU slice
the same driver runs the production mesh via --mesh prod / prod-multipod.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
      --steps 200 --mode ssgd --guided --rho 10 --log-every 10
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.core.guided import GuidedConfig
from repro.data import synthetic_lm_batches, make_batch_for
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import constant, get_optimizer, wsd
from repro.sharding.rules import DEFAULT_RULES, MULTIPOD_RULES, LOCAL_CTX, ShardCtx
from repro.train import steps as S


def build_ctx(mesh_kind: str) -> ShardCtx:
    if mesh_kind == "local":
        return LOCAL_CTX
    if mesh_kind == "host":
        mesh = make_host_mesh(data=len(jax.devices()), model=1)
        return ShardCtx(mesh=mesh, rules=DEFAULT_RULES)
    if mesh_kind == "prod":
        return ShardCtx(mesh=make_production_mesh(), rules=DEFAULT_RULES)
    if mesh_kind == "prod-multipod":
        return ShardCtx(mesh=make_production_mesh(multi_pod=True), rules=MULTIPOD_RULES,
                        data_axes=("pod", "data"))
    raise ValueError(mesh_kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mode", default="ssgd", choices=["seq", "ssgd", "asgd", "dc_asgd"])
    ap.add_argument("--guided", action="store_true")
    ap.add_argument("--rho", type=int, default=10)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--schedule", default="constant", choices=["constant", "wsd"])
    ap.add_argument("--mesh", default="local", choices=["local", "host", "prod", "prod-multipod"])
    ap.add_argument("--workers", type=int, default=0, help="logical worker count c (local mesh)")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model)
    if args.d_ff:
        cfg = cfg.replace(d_ff=args.d_ff)

    ctx = build_ctx(args.mesh)
    gcfg = GuidedConfig(mode=args.mode, guided=args.guided, rho=args.rho)
    opt = get_optimizer(args.optimizer)
    lr = constant(args.lr) if args.schedule == "constant" else wsd(args.lr, 10, args.steps // 2, args.steps // 2)

    # logical worker count: on a local mesh the paper's c is emulated by
    # slicing the batch into c chunks (n_workers), matching the SPMD layout
    c = args.workers or max(ctx.n_workers, 1)
    assert args.batch % c == 0, (args.batch, c)
    ctx_workers = ctx if ctx.distributed else ShardCtx(mesh=None)
    key = jax.random.PRNGKey(args.seed)
    params, logical, gstate = S.make_train_state(key, cfg, gcfg, opt, n_workers=c)

    step_fn = S.build_train_step(cfg, gcfg, opt, ctx, lr, n_micro=args.micro, n_workers=c)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    if cfg.audio_frontend or cfg.arch_type == "vlm":
        def _gen():
            i = 0
            while True:
                yield make_batch_for(cfg, args.seq, args.batch, seed=args.seed + i)
                i += 1

        batches = _gen()
    else:
        batches = synthetic_lm_batches(cfg.vocab_size, args.seq, args.batch, seed=args.seed, n_corpora=c)

    history = []
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, gstate, m = step_fn(params, gstate, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            history.append({"step": step, "loss": loss,
                            "worker_var": float(m["worker_loss_var"]),
                            "corr_w": float(m["corr_weight_sum"])})
            print(f"step {step:5d} loss {loss:.4f} worker_var {history[-1]['worker_var']:.2e} "
                  f"corr_w {history[-1]['corr_w']:.2f} ({time.time()-t0:.1f}s)")
        if args.ckpt_every and args.ckpt_dir and step and step % args.ckpt_every == 0:
            save(args.ckpt_dir, step, {"params": params})
            print(f"checkpointed step {step}")
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, {"params": params})
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    print(f"done: final loss {history[-1]['loss']:.4f}")
    return history


if __name__ == "__main__":
    main()
