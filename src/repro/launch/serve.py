"""Serving launcher: thin client over the continuous-batching ServeEngine.

Submits a batch of synthetic requests to `repro.serve.ServeEngine` (slot pool
+ persistent ring-buffer KV caches + per-slot decode positions, DESIGN.md §7)
and prints per-request streams plus aggregate throughput. `--stagger` varies
prompt and generation lengths across requests so slot recycling is visible;
`--lockstep` runs the fixed-batch barriered baseline instead.

Sampling is real now: `--sampling greedy|temperature|topk` (+ `--temperature`,
`--top-k`) replaces the old dead `--greedy` flag (which was
action="store_true" with default=True — impossible to disable, and no sampler
existed behind it).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --batch 4 --requests 8 --prompt-len 64 --gen 32 --stagger \
      --sampling topk --top-k 40 --temperature 0.8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.engine import build_ctx  # shared mesh-kind -> ShardCtx resolution
from repro.models import transformer as T
from repro.models.module import split_params
from repro.serve import Request, SamplingParams, ServeEngine, lockstep_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="engine slot-pool size")
    ap.add_argument("--requests", type=int, default=0,
                    help="number of requests (default: --batch)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--stagger", action="store_true",
                    help="heterogeneous prompt/gen lengths across requests")
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--sampling", choices=("greedy", "temperature", "topk"),
                    default="greedy")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--lockstep", action="store_true",
                    help="run the fixed-batch barriered baseline instead")
    ap.add_argument("--ckpt-dir", default="",
                    help="warm-start from a training checkpoint (full-state "
                         "snapshot; only the params subtree is restored)")
    ap.add_argument("--ckpt-step", type=int, default=0,
                    help="checkpoint step to serve (default: latest manifest entry)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ckpt_dir:
        # the manifest's recorded config is authoritative for its snapshot —
        # serving a reduced-trained checkpoint must not silently build the
        # full-size model because a flag was forgotten
        from repro.checkpoint import model_config_from_manifest

        try:
            ckpt_cfg = model_config_from_manifest(args.ckpt_dir,
                                                  args.ckpt_step or None)
        except (FileNotFoundError, ValueError):
            ckpt_cfg = None  # v1 dir / no metadata: trust the flags
        if ckpt_cfg is not None:
            if (ckpt_cfg.name, ckpt_cfg.n_layers, ckpt_cfg.d_model) != (
                    cfg.name, cfg.n_layers, cfg.d_model):
                print(f"using checkpoint config {ckpt_cfg.name} "
                      f"(layers={ckpt_cfg.n_layers}, d_model={ckpt_cfg.d_model}) "
                      f"from the manifest over the CLI flags")
            cfg = ckpt_cfg
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode (see DESIGN.md §5)")
    ctx = build_ctx(args.mesh)

    params = (None if args.ckpt_dir else
              split_params(T.model_init(jax.random.PRNGKey(args.seed), cfg))[0])

    n_req = args.requests or args.batch
    rng = np.random.default_rng(args.seed)
    # vlm archs splice per-request image-patch embeddings into the prompt
    # (lockstep baseline is token-only, like the engine's decode path)
    n_patches = cfg.n_patches if cfg.arch_type == "vlm" and not args.lockstep else 0
    min_len = max(1, n_patches + 2)
    reqs = []
    max_prompt = 0
    for i in range(n_req):
        if args.stagger:
            L = int(rng.integers(max(1, args.prompt_len // 4), args.prompt_len + 1))
            gen = int(rng.integers(max(1, args.gen // 4), args.gen + 1))
        else:
            L, gen = args.prompt_len, args.gen
        L = max(L, min_len)
        max_prompt = max(max_prompt, L)
        sp = SamplingParams(method=args.sampling, temperature=args.temperature,
                            top_k=args.top_k, seed=args.seed + i)
        prompt = rng.integers(0, cfg.vocab_size, (L,)).tolist()
        patches = (rng.standard_normal((n_patches, cfg.d_model)).astype(np.float32)
                   if n_patches else None)
        reqs.append(Request(prompt, max_new_tokens=gen, sampling=sp, patches=patches))

    max_len = max(args.prompt_len, max_prompt) + args.gen
    if args.ckpt_dir:
        # one restore path for API and CLI: ServeEngine.from_checkpoint owns
        # the manifest lookup, params-subtree restore and mesh placement
        engine = ServeEngine.from_checkpoint(
            args.ckpt_dir, cfg, ctx, step=args.ckpt_step or None,
            max_batch=args.batch, max_len=max_len)
        from repro.checkpoint import latest_step

        print(f"serving training snapshot step "
              f"{args.ckpt_step or latest_step(args.ckpt_dir)} from {args.ckpt_dir}")
    else:
        engine = ServeEngine(params, cfg, ctx, max_batch=args.batch, max_len=max_len)

    if args.lockstep:
        comps, stats = lockstep_generate(engine, reqs)
    else:
        comps = engine.run(reqs)
        stats = engine.stats()

    print(f"prefill: {stats.get('prefill_calls', len(comps))} calls, "
          f"pool={args.batch} slots, max_len={max_len}")
    print(f"decode:  {stats['decode_steps']} steps in {stats['wall_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s, occupancy {stats['occupancy']:.2f})")
    for c in sorted(comps, key=lambda c: c.request_id)[:2]:
        print(f"  request {c.request_id} ({c.prompt_len}+{c.new_tokens}, "
              f"{c.finish_reason}): {c.tokens[:16]}...")
    return comps


if __name__ == "__main__":
    main()
