"""Serving launcher: batched prefill + decode loop.

Serves a (reduced or full) model with a batch of synthetic requests:
prefill the prompts, then decode N tokens autoregressively with the
(ring-buffer / recurrent-state) caches. On TPU meshes the KV cache sequence
dim is sharded over `model` and attention uses the distributed flash-decode.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.engine import build_ctx  # shared mesh-kind -> ShardCtx resolution
from repro.models import transformer as T
from repro.models.module import split_params
from repro.data import make_batch_for
from repro.train import steps as S


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode (see DESIGN.md §5)")
    ctx = build_ctx(args.mesh)

    key = jax.random.PRNGKey(args.seed)
    params = jax.tree.map(lambda p: p, split_params(T.model_init(key, cfg))[0])

    total = args.prompt_len + args.gen
    batch = make_batch_for(cfg, args.prompt_len, args.batch, seed=args.seed)
    batch = {k: jnp.asarray(v) for k, v in batch.items() if k in ("tokens", "patches")}

    prefill = jax.jit(lambda p, b: T.prefill(p, b, cfg, ctx, total_len=total))
    decode = jax.jit(S.build_decode_step(cfg, ctx), donate_argnums=(1,))

    t0 = time.time()
    # prefill fills caches sized for the whole conversation (prompt + gen)
    last_logits, caches = prefill(params, batch)
    t_prefill = time.time() - t0

    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, tok, jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
    print(f"decode:  {args.gen-1} steps in {t_decode:.2f}s ({tps:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {gen[b][:16].tolist()}...")
    return gen


if __name__ == "__main__":
    main()
