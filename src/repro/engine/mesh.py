"""The strategy-driven SPMD train step (mesh backend of `repro.engine`).

This is the single train-step implementation both the legacy
`repro.train.steps.build_train_step` shim and `Trainer` dispatch to. The
paper's technique meets the mesh here (DESIGN.md §3):

  * per-worker losses E_i come free from the per-example loss vector (each
    data shard of the batch is one of the paper's c workers);
  * the active `DelayCompensator` strategy plugs into four seams —
    correction weights folded into the SAME backward pass
    (grad(sum w_i L_i) = sum w_i g_i; zero extra collectives), gradient
    compensation after the backward, a post-optimizer parameter correction,
    and the consistency-score update;
  * ASGD staleness is simulated through gstate.w_stale exactly as before.

Nothing here hard-codes a compensation scheme: new strategies registered in
`repro.engine.strategies` run through this step unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common import tree_add
from repro.core import guided as G
from repro.engine.strategies import DelayCompensator, get_compensator, strategy_name_for
from repro.models import transformer as T
from repro.models.module import split_params
from repro.optim import Optimizer
from repro.sharding.rules import DEFAULT_RULES, LOCAL_CTX, MULTIPOD_RULES, ShardCtx


def build_ctx(mesh_kind: str) -> ShardCtx:
    """Shared mesh-kind -> ShardCtx resolution (train and serve launchers)."""
    if mesh_kind == "local":
        return LOCAL_CTX
    if mesh_kind == "host":
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=len(jax.devices()), model=1)
        return ShardCtx(mesh=mesh, rules=DEFAULT_RULES)
    if mesh_kind == "prod":
        from repro.launch.mesh import make_production_mesh

        return ShardCtx(mesh=make_production_mesh(), rules=DEFAULT_RULES)
    if mesh_kind == "prod-multipod":
        from repro.launch.mesh import make_production_mesh

        return ShardCtx(mesh=make_production_mesh(multi_pod=True), rules=MULTIPOD_RULES,
                        data_axes=("pod", "data"))
    raise ValueError(mesh_kind)


def resolve_strategy(gcfg: G.GuidedConfig, strategy=None) -> DelayCompensator:
    """Accept a DelayCompensator instance, a registry name, or None (derive
    the strategy the legacy GuidedConfig flags imply)."""
    if isinstance(strategy, DelayCompensator):
        return strategy
    return get_compensator(strategy or strategy_name_for(gcfg), gcfg)


def init_train_state(key, cfg, gcfg: G.GuidedConfig, opt: Optimizer, n_workers: int,
                     strategy=None):
    """Model params + logical annotations + GuidedState (incl. strategy extra)."""
    strategy = resolve_strategy(gcfg, strategy)
    boxed = T.model_init(key, cfg)
    params, logical = split_params(boxed)
    gstate = G.guided_init(gcfg, params, opt, n_workers)
    return params, logical, gstate._replace(extra=strategy.init(params, n_workers))


def _microbatches(batch, n_micro: int, c: int):
    """Split (B, ...) -> (n_micro, B/n_micro, ...) preserving the worker
    (data-shard) structure: every microbatch contains an equal slice of every
    worker's rows, so per-worker losses stay well-defined and no cross-shard
    traffic is introduced (the leading c-blocking is untouched per shard)."""

    def one(x):
        B = x.shape[0]
        b = B // c
        xr = x.reshape(c, n_micro, b // n_micro, *x.shape[1:])
        xr = jnp.moveaxis(xr, 1, 0)
        return xr.reshape(n_micro, B // n_micro, *x.shape[1:])

    return jax.tree.map(one, batch)


def build_train_step(cfg, gcfg: G.GuidedConfig, opt: Optimizer, ctx: ShardCtx, lr_schedule,
                     n_micro: int = 1, n_workers: int = 0, strategy=None):
    """Returns train_step(params, gstate, batch) -> (params, gstate, metrics).

    n_micro > 1 enables microbatched gradient accumulation: the remat-saved
    per-layer activation stack scales with the microbatch, which is what lets
    train_4k (global 256 x 4096) fit a 16 GiB chip at 9B-123B scale.
    n_workers overrides the paper's worker count c (defaults to the number of
    data shards; on a single device it emulates c workers by batch slicing).
    `strategy` is a DelayCompensator instance or registry name; None derives
    it from the GuidedConfig flags (legacy behaviour)."""
    strategy = resolve_strategy(gcfg, strategy)
    c = n_workers or max(ctx.n_workers, 1)

    # Whole-update fusion (DESIGN.md §11): when the strategy's compensation is
    # the kernel's lam fold and the optimizer has a fused kernel, ONE fused
    # dispatch per leaf (compensate → accumulator → apply) replaces
    # compensate_grads + opt.update + tree_add. sim_kernel returns None for
    # bespoke-compensation strategies (gap_aware); hypers must be known and
    # weight_decay-free for the fused closure to match opt.update bit-for-bit.
    # On interpret backends sim_kernel resolves to the pure-jnp reference
    # (impl="auto"), so the cpu mesh never pays per-leaf emulated Pallas calls.
    fused = None
    fused_lam = 0.0
    if opt.hypers is not None and opt.name in ("sgd", "momentum", "adam"):
        hy = dict(opt.hypers)
        if not hy.pop("weight_decay", 0.0):
            fused = strategy.sim_kernel(opt.name, **hy)
            fused_lam = float(strategy.sim_kernel_lambda())
    if fused is not None:
        from repro.kernels.guided_update.ops import tree_fused_update

    def loss_fn(p, batch, corr_w):
        per_ex, aux, _ = T.forward_train(p, batch, cfg, ctx)
        B = per_ex.shape[0]
        E_i = per_ex.reshape(c, B // c).mean(axis=1)
        mean_loss = E_i.mean()
        total = mean_loss + aux + (jax.lax.stop_gradient(corr_w) * E_i).sum() * gcfg.correction_scale
        return total, (E_i, mean_loss)

    def grads_and_losses(grad_at, batch, corr_w):
        if n_micro == 1:
            (_, (E_i, mean_loss)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                grad_at, batch, corr_w
            )
            return grads, E_i, mean_loss

        mbs = _microbatches(batch, n_micro, c)

        def body(acc, mb):
            g_acc, e_acc, l_acc = acc
            (_, (E_i, ml)), g = jax.value_and_grad(loss_fn, has_aux=True)(grad_at, mb, corr_w)
            g_acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
            return (g_acc, e_acc + E_i, l_acc + ml), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), grad_at)
        (g_sum, e_sum, l_sum), _ = jax.lax.scan(
            body, (g0, jnp.zeros((c,), jnp.float32), jnp.zeros((), jnp.float32)), mbs
        )
        grads = jax.tree.map(lambda g, p: (g / n_micro).astype(p.dtype), g_sum, grad_at)
        return grads, e_sum / n_micro, l_sum / n_micro

    def weighted_grad_fn(batch):
        """grad of the consistency-weighted per-worker loss (uniform term off) —
        handed to strategy.correct for the paper's literal second update."""

        def at(p, w):
            def wl(q):
                per_ex, _, _ = T.forward_train(q, batch, cfg, ctx)
                return (w * per_ex.reshape(c, -1).mean(1)).sum()

            return jax.grad(wl)(p)

        return at

    def train_step(params, gstate: G.GuidedState, batch):
        corr_w = strategy.correction_weights(gstate, c)

        grad_at = gstate.w_stale if gcfg.needs_stale else params
        grads, E_i, mean_loss = grads_and_losses(grad_at, batch, corr_w)

        lr = lr_schedule(gstate.step)
        lr_eff = lr * c if gcfg.mode != "seq" else lr
        if fused is not None:
            # compensation rides inside the fused update as the lam fold
            # (identity for non-dc strategies: lam == 0); w_stale only matters
            # when lam != 0, which implies gcfg.needs_stale
            w_ref = gstate.w_stale if gcfg.needs_stale else params
            params, opt_state = tree_fused_update(
                fused, opt.name, params, grads, w_ref, gstate.opt_state,
                lr_eff, fused_lam)
        else:
            grads = strategy.compensate_grads(grads, params, gstate)
            updates, opt_state = opt.update(grads, gstate.opt_state, params, lr_eff)
            params = tree_add(params, updates)
        if strategy.needs_correction:
            # only correcting strategies trace the second weighted
            # forward+backward; for the rest (guided_fused folds its replay
            # into THIS backward) the closure never enters the HLO
            params = strategy.correct(params, gstate, lr, weighted_grad_fn(batch))

        gstate = G.advance(
            gstate, gcfg, opt_state, params, E_i, mean_loss,
            extra=strategy.update_extra(gstate, grads),
            score=strategy.score(gstate, E_i, mean_loss),
        )
        metrics = {
            "loss": mean_loss,
            "worker_loss_var": jnp.var(E_i),
            "corr_weight_sum": jnp.sum(corr_w),
            "lr": lr,
            "step": gstate.step,
        }
        return params, gstate, metrics

    return train_step
