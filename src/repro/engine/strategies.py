"""Pluggable delay-compensation strategies (the `DelayCompensator` registry).

The paper's guided correction, its two-pass literal variant, DC-ASGD's Taylor
compensation (Zheng et al. 2017) and Gap-Aware dampening (Barkai et al. 2019)
are all the same shape: a small set of hooks around one SPMD train step.
A strategy never owns the training loop — it plugs into the four seams the
generic step in `repro.engine.mesh` exposes:

  init(params, n_workers)        -> strategy-owned extra state (a pytree; ())
  correction_weights(state, c)   -> (c,) weights folded into THIS backward
                                    pass as sum_i w_i * L_i ("fused" replay)
  compensate_grads(grads, params, state) -> adjusted gradients (post-backward)
  correct(params, state, lr, weighted_grad_fn) -> params after the optimizer
                                    step (the paper's literal second update)
  score(state, worker_loss, avg_loss) -> new (c,) consistency scores
  update_extra(state, grads)     -> next extra state (window bookkeeping)

Register new schemes with `@register_compensator("name")`; they become
selectable from `ExperimentSpec(strategy="name")` and the `--strategy` flag of
`repro.launch.train` without touching the train step. See DESIGN.md §2 for the
protocol contract and a migration table from the legacy APIs.
"""
from __future__ import annotations

from typing import Callable, Dict, Type

import jax
import jax.numpy as jnp

from repro.core import guided as G
from repro.engine.spec import needs_stale_message


class DelayCompensator:
    """Base strategy: no compensation, paper-faithful consistency scoring.

    Subclasses override only the hooks they need. All hooks are traced inside
    the jitted train step, so they must be pure and shape-stable; anything
    data-dependent goes through `state` (a `GuidedState`, whose `extra` field
    belongs to the strategy).
    """

    name = "none"

    def __init__(self, gcfg: G.GuidedConfig):
        self.gcfg = gcfg

    # ------------------------------------------------------------- lifecycle
    def init(self, params, n_workers: int):
        """Initial strategy-owned state, stored in GuidedState.extra."""
        return ()

    # ---------------------------------------------------------------- hooks
    @property
    def needs_correction(self) -> bool:
        """False when `correct` is the identity: the train step then never
        builds the second weighted forward+backward closure, so strategies
        like guided_fused don't pay HLO size / compile time for a replay path
        they never take. A subclass that overrides `correct` is assumed to
        need it unless it also overrides this property (DcAsgdGuided: only
        its two_pass flavour corrects)."""
        return type(self).correct is not DelayCompensator.correct

    def correction_weights(self, state: G.GuidedState, c: int):
        """(c,) weights for the consistency-weighted loss term of THIS step's
        backward pass (zero except at window end for fused guided replay)."""
        return jnp.zeros((c,), jnp.float32)

    def compensate_grads(self, grads, params, state: G.GuidedState):
        """Adjust freshly computed gradients (e.g. staleness Taylor terms)."""
        return grads

    def correct(self, params, state: G.GuidedState, lr, weighted_grad_fn: Callable):
        """Post-optimizer-step parameter correction. `weighted_grad_fn(p, w)`
        returns the gradient of the w-weighted per-worker loss at p."""
        return params

    def score(self, state: G.GuidedState, worker_loss, avg_loss):
        """New accumulated consistency scores (pre window-reset)."""
        return G.update_scores(state, self.gcfg, worker_loss, avg_loss)

    def update_extra(self, state: G.GuidedState, grads):
        """Next value of the strategy-owned extra state."""
        return state.extra

    # ------------------------------------------------------- scan-sim hooks
    # The jitted delay-simulation backend (repro.engine.delaysim) drives the
    # same registry through these three seams instead of reimplementing the
    # paper's guided logic in its scan body (DESIGN.md §6). They trace inside
    # lax.scan, so the same purity/shape rules apply as for the mesh hooks.

    #: True -> the scan body tracks per-arrival consistency (loss-before /
    #: loss-after of the applied batch + verification loss) and calls
    #: sim_score / sim_replay; False skips that bookkeeping entirely.
    sim_guided = False

    def sim_kernel_lambda(self) -> float:
        """DC-ASGD Taylor coefficient folded directly into the fused Pallas
        apply kernel (g~ = g + lam*g*g*(W - W_stale)). Non-zero means the
        kernel performs the compensation and compensate_grads is skipped."""
        return 0.0

    def sim_kernel(self, optimizer: str, *, impl: str = "auto", **hypers):
        """The fused whole-update callable (gradient → compensation →
        accumulator → weight, one dispatch) for this strategy × `optimizer`,
        or None when the hot loop must fall back to the two-phase path
        (compensate_grads, then a plain lam=0 apply).

        Fusion is sound exactly when this strategy's compensation is the
        kernel's lam fold: either compensate_grads is not overridden (the
        identity — guided/none strategies), or sim_kernel_lambda() is
        non-zero (DC-ASGD family, whose Taylor term IS the fold). Strategies
        with bespoke gradient math (gap_aware) get None regardless of the
        optimizer; so do optimizers without a fused kernel (adagrad). The
        fallback matrix is tabulated in DESIGN.md §11. `hypers` are the
        optimizer's python-float hyperparameters, baked into the closure."""
        overridden = (type(self).compensate_grads
                      is not DelayCompensator.compensate_grads)
        if overridden and not self.sim_kernel_lambda():
            return None
        from repro.kernels.guided_update.ops import FUSED_OPTIMIZERS, fused_update_for

        if optimizer not in FUSED_OPTIMIZERS:
            return None
        return fused_update_for(optimizer, impl=impl, **hypers)

    def sim_score(self, d_own, d_avg, prev_avg_err):
        """Paper Fig. 7 consistency score of ONE arrival: the applied batch is
        consistent when the step moved both its own loss (d_own) and the
        verification-average loss (d_avg) downward; ranked by the relative
        average-error drop. Returns 0 for inconsistent arrivals (never stored).
        """
        ok = jnp.isfinite(prev_avg_err) & (d_own < 0) & (d_avg < 0)
        return jnp.where(ok, -d_avg / (jnp.abs(prev_avg_err) + 1e-12), 0.0)

    def sim_replay(self, W, window_scores, window_grads, lr):
        """Window-end replay (Fig. 7 line 8): re-apply the stored gradients of
        the <=max_consistent most consistent arrivals of the closing window,
        plain SGD style (W -= lr * g), exactly as printed in the paper.
        top_k breaks ties by lowest index = arrival order, matching the
        reference loop's stable sort over psi insertion order."""
        k = min(self.gcfg.max_consistent, window_scores.shape[0])
        top_v, top_i = jax.lax.top_k(window_scores, k)
        sel = (top_v > 0).astype(W.dtype)
        return W - lr * jnp.tensordot(sel, window_grads[top_i], axes=1)


def sim_shim_state(i, Wf, prev_avg, c: int) -> G.GuidedState:
    """Minimal GuidedState for the mesh-hook signatures on the single-matrix
    backends (scan sim, dist chief): only w_stale is guaranteed (what
    compensate_grads reads); window bookkeeping lives in the caller's carry."""
    z = jnp.zeros((c,), Wf.dtype)
    return G.GuidedState(step=i, score=z, prev_worker_loss=z,
                         prev_avg_loss=prev_avg, w_stale=Wf, opt_state=(), extra=())


def _fused_weights(state: G.GuidedState, gcfg: G.GuidedConfig, c: int):
    """(c,) top-k consistency weights at window end, zeros otherwise."""
    return jnp.where(
        G.is_window_end(state.step, gcfg),
        G.correction_weights(state.score, gcfg),
        jnp.zeros((c,), jnp.float32),
    )


def _two_pass_correct(params, state: G.GuidedState, gcfg: G.GuidedConfig, lr,
                      weighted_grad_fn):
    """The paper's literal Fig. 7 second sequential update at window end."""

    def replay(p):
        w = G.correction_weights(state.score, gcfg)
        g2 = weighted_grad_fn(p, w)
        return jax.tree.map(lambda pi, gi: pi - lr * gi.astype(pi.dtype), p, g2)

    return jax.lax.cond(G.is_window_end(state.step, gcfg), replay, lambda p: p, params)


class GuidedFused(DelayCompensator):
    """The paper's guided replay, fused into the main backward pass:
    grad(sum_i w_i L_i) = sum_i w_i g_i, so replaying the <=max_consistent
    most consistent workers' gradients costs one weighted loss term — no
    stored gradients, no extra collective (DESIGN.md §3). Selecting this
    strategy by name is authoritative: it corrects regardless of the
    GuidedConfig.guided/correction flags."""

    name = "guided_fused"
    sim_guided = True

    def correction_weights(self, state: G.GuidedState, c: int):
        return _fused_weights(state, self.gcfg, c)


class GuidedTwoPass(DelayCompensator):
    """The paper's literal Fig. 7 second sequential update: every rho steps,
    a lax.cond'd second backward of the consistency-weighted loss at the
    already-moved iterate. Like guided_fused, the name is authoritative."""

    name = "guided_two_pass"
    sim_guided = True  # the sim has exactly one guided path (the literal replay)

    def correct(self, params, state: G.GuidedState, lr, weighted_grad_fn):
        return _two_pass_correct(params, state, self.gcfg, lr, weighted_grad_fn)


class DcAsgd(DelayCompensator):
    """DC-ASGD (Zheng et al. 2017): g~ = g + lambda * g ⊙ g ⊙ (W_t - W_stale).
    Pure Taylor compensation; no guided replay (see DcAsgdGuided)."""

    name = "dc_asgd"

    def sim_kernel_lambda(self) -> float:
        return self.gcfg.dc_lambda

    def compensate_grads(self, grads, params, state: G.GuidedState):
        return G.compensate_dc_asgd(grads, params, state.w_stale, self.gcfg.dc_lambda)


class DcAsgdGuided(DcAsgd):
    """DC-ASGD composed with the paper's guided replay — the legacy
    GuidedConfig(mode="dc_asgd", guided=True) combinations as one named
    strategy. The replay flavour follows gcfg.correction ("fused" folds the
    weights into the backward pass, "two_pass" runs the literal second
    update), preserving every legacy combination bit-for-bit."""

    name = "dc_asgd_guided"
    sim_guided = True

    @property
    def needs_correction(self) -> bool:
        return self.gcfg.correction == "two_pass"

    def correction_weights(self, state: G.GuidedState, c: int):
        if self.gcfg.correction != "fused":
            return jnp.zeros((c,), jnp.float32)
        return _fused_weights(state, self.gcfg, c)

    def correct(self, params, state: G.GuidedState, lr, weighted_grad_fn):
        if self.gcfg.correction != "two_pass":
            return params
        return _two_pass_correct(params, state, self.gcfg, lr, weighted_grad_fn)


class GapAware(DelayCompensator):
    """Gap-Aware staleness dampening (Barkai et al. 2019, arXiv:1909.10802):
    each gradient coordinate is divided by 1 + |W_t - W_stale| / rms(g) — the
    further the parameter has already moved since the gradient was computed,
    the less that stale coordinate is trusted. Needs mode="asgd" (w_stale).

    This is the ~30-line "new scheme as a plugin" exemplar: it was added
    without touching the train step or `train/steps.py`.
    """

    name = "gap_aware"

    def __init__(self, gcfg: G.GuidedConfig):
        if not gcfg.needs_stale:
            raise ValueError(
                needs_stale_message("gap_aware", "dampens by |W - w_stale|", gcfg.mode)
            )
        super().__init__(gcfg)

    def compensate_grads(self, grads, params, state: G.GuidedState):
        def one(g, p, ps):
            # compute dtype follows the gradients (>= f32): bf16 mesh grads
            # upcast as before, the scan backend's f64 regime stays f64
            ct = jnp.promote_types(g.dtype, jnp.float32)
            gc = g.astype(ct)
            gap = jnp.abs(p.astype(ct) - ps.astype(ct))
            rms = jnp.sqrt(jnp.mean(jnp.square(gc)) + 1e-12)
            return (gc / (1.0 + gap / jnp.maximum(rms, 1e-12))).astype(g.dtype)

        return jax.tree.map(one, grads, params, state.w_stale)


# ----------------------------------------------------------------- registry

_REGISTRY: Dict[str, Type[DelayCompensator]] = {}


def register_compensator(name: str):
    """Class decorator: `@register_compensator("my_scheme")` makes the scheme
    selectable by name from ExperimentSpec / the train CLI."""

    def deco(cls: Type[DelayCompensator]):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


for _cls in (DelayCompensator, GuidedFused, GuidedTwoPass, DcAsgd, DcAsgdGuided, GapAware):
    _REGISTRY[_cls.name] = _cls


def compensator_names() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_compensator(name: str, gcfg: G.GuidedConfig) -> DelayCompensator:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown delay-compensation strategy {name!r}; "
            f"registered: {', '.join(compensator_names())}"
        ) from None
    return cls(gcfg)


def strategy_name_for(gcfg: G.GuidedConfig) -> str:
    """Legacy GuidedConfig -> registry name (the shim `train.steps` uses)."""
    if gcfg.mode == "dc_asgd":
        return "dc_asgd_guided" if gcfg.guided else "dc_asgd"
    if gcfg.guided:
        return "guided_two_pass" if gcfg.correction == "two_pass" else "guided_fused"
    return "none"
