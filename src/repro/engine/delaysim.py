"""Jitted vectorized delay-simulation backend (`ExperimentSpec(backend="scan")`).

The numpy reference (`core.parameter_server.train_ps`) is an event-driven
Python loop: per arrival it recomputes the verification loss, applies the
update, and runs the guided bookkeeping — sequential by construction, which is
exactly the bottleneck the paper tells us to parallelize. This module replaces
it with three orthogonal pieces:

  1. **DelaySchedule** (core.parameter_server): the delay topology — which
     mini-batch arrives at each server step and how stale the weights its
     gradient was computed at are — is *precomputed* by replaying the
     reference loop's rng protocol with the gradient math elided. seq/ssgd/
     asgd become pure schedule generators, and because the schedule is data
     (not control flow), new topologies are one sampler each: constant-delay,
     heavy-tail (Pareto), straggler, heterogeneous-worker (TOPOLOGY_SAMPLERS).
  2. **One jitted lax.scan** over the arrival table. A ring buffer of the last
     `max_staleness+1` weight states serves stale fetches; the fused Pallas
     `guided_update` kernel is the apply path (compiled on gpu/tpu, interpret
     on cpu); the guided consistency scoring and window replay run through the
     `DelayCompensator` registry's scan-sim hooks (sim_score / sim_replay /
     compensate_grads) — the same strategy objects the mesh backend plugs in,
     so dc_asgd and gap_aware now run at paper scale too.
  3. **jax.vmap over the seed axis**: `n_seeds=k` sweeps seeds
     spec.seed..spec.seed+k-1 in ONE compile, the way the paper's 30-run
     protocol is meant to be executed (see benchmarks/run.py BENCH_delaysim).

Parity: with the default topologies the scan trajectory reproduces train_ps
to float64 round-off, locked in by tests/test_delaysim.py (the numpy loop
stays as the reference). Everything runs in float64 via a scoped enable_x64
(f32 on TPU, where x64 is unsupported — parity is a CPU/GPU property).
"""
from __future__ import annotations

from collections import OrderedDict
from contextlib import nullcontext

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.parameter_server import (  # noqa: F401  (DelaySchedule re-export)
    DelaySchedule,
    LogisticRegression,
    prepare_run,
)
from repro.engine.spec import ExperimentSpec
from repro.engine.strategies import DelayCompensator, get_compensator
from repro.kernels.guided_update.ops import FUSED_ACC_ARITY, fused_update_for

# ------------------------------------------------------------- topologies
# Hoisted to repro.common.topologies (one source of truth shared with the
# dist fault injector); re-exported here for compat.
from repro.common.topologies import TOPOLOGY_SAMPLERS  # noqa: F401, E402


def _x64():
    """Scoped float64: the paper-scale sim matches the numpy reference to
    round-off. TPUs have no f64 — there the scan runs in f32 (no parity
    guarantee, same algorithm)."""
    return enable_x64() if jax.default_backend() != "tpu" else nullcontext()


# ------------------------------------------------------- model math (jax)
# Literal transcriptions of core.parameter_server.LogisticRegression so the
# float64 scan reproduces the reference arithmetic. Labels arrive as one-hot
# masks precomputed outside the scan: `(z * y_oh).sum(1)` selects the own
# logit exactly (the masked terms are exact float zeros) without the
# per-step gathers XLA CPU scalarizes.


def _loss(W, Xa, y_oh):
    z = Xa @ W
    z = z - z.max(axis=1, keepdims=True)
    lse = jnp.log(jnp.exp(z).sum(axis=1))
    own = (z * y_oh).sum(axis=1)
    return jnp.mean(lse - own)


def _grad(W, Xa, y_oh):
    z = Xa @ W
    z = z - z.max(axis=1, keepdims=True)
    p = jnp.exp(z)
    p = p / p.sum(axis=1, keepdims=True)
    p = p - y_oh
    return Xa.T @ p / Xa.shape[0]


def _aug(X):
    return np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)


# ------------------------------------------------------------ scan runner


# _shim_state moved to repro.engine.strategies.sim_shim_state: the dist
# chief drives the same hooks on live pushes and needs the identical shim.
from repro.engine.strategies import sim_shim_state as _shim_state  # noqa: E402


# Bounded LRU of jitted runners. Every distinct (shapes, strategy, config)
# key pins a compiled executable; an unbounded dict made long parameter
# sweeps (rho/k ablations, multi-dataset tables) leak one compile per
# configuration forever. 8 keeps the warm-reuse benefit within a sweep while
# bounding the pinned-compile footprint; benchmarks additionally call
# clear_runners() between sweeps.
_RUNNERS: OrderedDict = OrderedDict()
_RUNNERS_MAX = 8


def clear_runners() -> None:
    """Drop every cached jitted runner (and its pinned compiled executable).
    Benchmarks call this between sweeps so one workload's compiles don't stay
    resident through the next."""
    _RUNNERS.clear()


def _build_runner(key, strategy: DelayCompensator, T: int, n_classes: int,
                  R: int, rho: int, c: int, optimizer: str, fused_dc: bool,
                  beta: float, eps: float):
    """Compile (LRU-cached) the vmapped scan for one static configuration.
    `beta`/`eps` are python floats, baked into the trace (same values the
    reference loop uses, so the f64 parity regime is unchanged)."""
    if key in _RUNNERS:
        _RUNNERS.move_to_end(key)
        return _RUNNERS[key]
    guided = strategy.sim_guided

    # Whole-update apply path (DESIGN.md §11): the strategy registry selects
    # the optimizer-fused kernel via sim_kernel — compensation (lam), the
    # accumulator recurrence and the weight apply in ONE dispatch. None means
    # two-phase: compensate_grads already ran in the scan body (fused_dc is
    # False there), so the same kernel applies plain with the traced lam=0.
    # adagrad keeps its 3-op inline XLA form (no fused kernel; the lam fold
    # stays inline exactly as before, preserving the dc_asgd f64 ordering).
    hypers = {"rmsprop": dict(beta=beta, eps=eps),
              "momentum": dict(beta=0.9),
              "adam": dict(b1=0.9, b2=0.999, eps=eps)}.get(optimizer, {})
    kern = None
    if optimizer != "adagrad":
        kern = strategy.sim_kernel(optimizer, impl="kernel", **hypers)
        if kern is None:
            kern = fused_update_for(optimizer, impl="kernel", **hypers)
    n_acc = 1 if optimizer == "adagrad" else FUSED_ACC_ARITY[optimizer]

    def apply_update(W, g, Wf, acc, i, lr, lam):
        if optimizer == "adagrad":
            (r,) = acc
            gt = g + lam * g * g * (W - Wf)
            r = r + gt * gt
            return W - lr * gt / jnp.sqrt(r + eps), (r,)
        # i+1 = the already-incremented adam step; ignored by the others
        return kern(W, g, Wf, acc, i + 1, lr, lam)

    def one_seed(W0, Xa_all, rows, yb, Xv, yv, stale, lr, lam):
        P, k = W0.shape
        rho_w = max(rho, 1)
        # hoisted out of the scan: batch gather (T*bs rows) + one-hot labels
        Xb = jnp.take(Xa_all, rows.reshape(-1), axis=0).reshape(*rows.shape, P)
        yb_oh = jax.nn.one_hot(yb, k, dtype=W0.dtype)
        yv_oh = jax.nn.one_hot(yv, k, dtype=W0.dtype)

        def step(carry, xs):
            W, ring, acc, prev_avg, wscore, wgrads = carry
            i, Xa, yoh, s = xs
            Wf = jnp.take(ring, jnp.mod(i - s, R), axis=0)
            g = _grad(Wf, Xa, yoh)
            if not fused_dc:
                g = strategy.compensate_grads(g, W, _shim_state(i, Wf, prev_avg, c))
            loss_before = _loss(W, Xa, yoh) if guided else 0.0
            W2, acc2 = apply_update(W, g, Wf, acc, i, lr, lam)
            avg = _loss(W2, Xv, yv_oh)
            if guided:
                d_avg = avg - prev_avg
                d_own = _loss(W2, Xa, yoh) - loss_before
                sc = strategy.sim_score(d_own, d_avg, prev_avg)
                pos = jnp.mod(i, rho_w)
                wscore = wscore.at[pos].set(sc)
                wgrads = wgrads.at[pos].set(g)
                end = jnp.equal(jnp.mod(i + 1, rho_w), 0)
                W3 = jnp.where(end, strategy.sim_replay(W2, wscore, wgrads, lr), W2)
                wscore = jnp.where(end, jnp.zeros_like(wscore), wscore)
            else:
                W3 = W2
            ring = ring.at[jnp.mod(i + 1, R)].set(W3)
            return (W3, ring, acc2, avg, wscore, wgrads), avg

        carry0 = (
            W0,
            jnp.tile(W0[None], (R, 1, 1)),
            tuple(jnp.zeros_like(W0) for _ in range(n_acc)),
            jnp.asarray(jnp.inf, W0.dtype),
            jnp.zeros((rho_w,), W0.dtype),
            jnp.zeros((rho_w, P, k), W0.dtype),
        )
        xs = (jnp.arange(T, dtype=jnp.int32), Xb, yb_oh, stale)
        carry, avgs = jax.lax.scan(step, carry0, xs)
        return carry[0], avgs

    # lint: allow[missing-donate] runner is LRU-cached and re-invoked; inputs must survive the call
    fn = jax.jit(jax.vmap(one_seed, in_axes=(0, 0, 0, 0, 0, 0, 0, None, None)))
    _RUNNERS[key] = fn
    while len(_RUNNERS) > _RUNNERS_MAX:
        _RUNNERS.popitem(last=False)
    return fn


# ------------------------------------------------------------- entry point


def run(spec: ExperimentSpec, X, y, n_classes: int, Xtest=None, ytest=None,
        strategy: DelayCompensator = None) -> dict:
    """Run `spec` on the scan backend. Same contract as train_ps (plus seed
    vectorization): returns train/val losses, per-arrival (t, avg_err)
    history, final model(s) and optional test accuracy. n_seeds == 1 returns
    scalars; n_seeds > 1 returns (n_seeds,) arrays and a list of per-seed
    models. `strategy` reuses an already resolved DelayCompensator (the
    Trainer's); None resolves spec.strategy from the registry."""
    gcfg = spec.to_guided_config()
    if strategy is None:
        strategy = get_compensator(spec.strategy, gcfg)
    topology = spec.resolved_topology
    try:
        sampler = TOPOLOGY_SAMPLERS[topology]
    except KeyError:
        raise KeyError(
            f"unknown topology {topology!r}; known: {', '.join(TOPOLOGY_SAMPLERS)}"
        ) from None

    preps = [
        prepare_run(X, y, n_classes, spec.to_schedule_config(seed=s),
                    delay_sampler=sampler, topology=topology)
        for s in range(spec.seed, spec.seed + spec.n_seeds)
    ]
    schedules = [p[3] for p in preps]
    T = schedules[0].n_steps
    if not all(s.n_steps == T for s in schedules):
        # a real exception, not an assert: this guards the vmapped stacking of
        # per-seed arrival tables and must survive `python -O`
        counts = {spec.seed + i: s.n_steps for i, s in enumerate(schedules)}
        raise ValueError(
            f"seeds disagree on arrival count under mode={spec.mode!r} "
            f"topology={spec.resolved_topology!r} epochs={spec.epochs} "
            f"batch_size={spec.batch_size}: per-seed n_steps {counts}; the "
            f"scan backend needs equal-length schedules to vmap "
            f"n_seeds={spec.n_seeds} (run seeds separately or use backend='sim')"
        )
    if T == 0:
        # n_train < batch_size yields zero arrivals; mirror train_ps (which
        # returns the untouched init) instead of tracing an empty scan
        return _empty_result(spec, preps, Xtest, ytest)
    r_needed = max(s.max_staleness for s in schedules) + 1
    # bucket the ring size: fewer recompiles across runs/modes (a few unused
    # slots of a (R, P, k) ring are free next to one saved jit compile)
    R = max(16, 1 << (r_needed - 1).bit_length())

    W0 = np.stack([p[0] for p in preps])
    Xtr = [p[1][0] for p in preps]
    ytr = [p[1][1] for p in preps]
    Xa_all = np.stack([_aug(x) for x in Xtr])          # (S, n_train, P)
    rows = np.stack([s.batch_rows for s in schedules])  # (S, T, bs)
    yb = np.stack([ytr[i][schedules[i].batch_rows] for i in range(len(preps))])
    Xv = np.stack([_aug(p[2][0]) for p in preps])
    yv = np.stack([p[2][1] for p in preps])
    stale = np.stack([s.staleness for s in schedules])

    fused_lam = strategy.sim_kernel_lambda()
    # the key carries every static the trace can bake in: shapes, the strategy
    # class AND its GuidedConfig (hook implementations may close over any of
    # its fields), the optimizer branch and the backend's dtype regime
    key = (
        type(strategy).__module__, type(strategy).__qualname__, spec.strategy,
        gcfg, T, n_classes, W0.shape[1], Xa_all.shape[1], rows.shape[2],
        Xv.shape[1], R, spec.rho, spec.max_consistent, spec.optimizer,
        bool(fused_lam), float(spec.rmsprop_beta), float(spec.eps),
        spec.n_seeds, jax.default_backend() == "tpu",
    )
    with _x64():
        fn = _build_runner(key, strategy, T, n_classes, R, spec.rho,
                           schedules[0].n_workers, spec.optimizer, bool(fused_lam),
                           float(spec.rmsprop_beta), float(spec.eps))
        Wf, avgs = fn(
            jnp.asarray(W0),
            jnp.asarray(Xa_all), jnp.asarray(rows, jnp.int32), jnp.asarray(yb, jnp.int32),
            jnp.asarray(Xv), jnp.asarray(yv, jnp.int32), jnp.asarray(stale, jnp.int32),
            jnp.asarray(float(spec.lr)), jnp.asarray(float(fused_lam)),
        )
        Wf = np.asarray(Wf)
        avgs = np.asarray(avgs)

    out = _final_metrics(spec, preps, Wf, Xtest, ytest)
    out["history"] = [(t + 1, float(avgs[0, t]) if spec.n_seeds == 1 else avgs[:, t])
                      for t in range(T)]
    out["n_steps"] = T
    out["schedule"] = schedules[0] if spec.n_seeds == 1 else schedules
    return out


def _final_metrics(spec: ExperimentSpec, preps, Wf, Xtest, ytest) -> dict:
    """train/val losses, per-seed models and test accuracy from the final
    weights, computed with the numpy reference model (identical arithmetic).
    n_seeds == 1 unwraps to scalars / a single model."""
    models = [LogisticRegression.from_weights(Wf[i]) for i in range(len(preps))]
    train_loss = np.array([models[i].loss(*preps[i][1]) for i in range(len(preps))])
    val_loss = np.array([models[i].loss(*preps[i][2]) for i in range(len(preps))])
    single = spec.n_seeds == 1
    out = {
        "train_loss": float(train_loss[0]) if single else train_loss,
        "val_loss": float(val_loss[0]) if single else val_loss,
        "model": models[0] if single else models,
    }
    if Xtest is not None:
        acc = np.array([m.accuracy(Xtest, ytest) for m in models])
        out["test_accuracy"] = float(acc[0]) if single else acc
    return out


def _empty_result(spec: ExperimentSpec, preps, Xtest, ytest) -> dict:
    out = _final_metrics(spec, preps, np.stack([p[0] for p in preps]), Xtest, ytest)
    out["history"] = []
    out["n_steps"] = 0
    out["schedule"] = preps[0][3] if spec.n_seeds == 1 else [p[3] for p in preps]
    return out
