"""`Trainer` — one facade over the three backends, `Report` — one result type.

    spec = ExperimentSpec(backend="sim", mode="ssgd", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.test_accuracy, report.history

    spec = ExperimentSpec(backend="scan", mode="asgd", strategy="dc_asgd",
                          topology="heavy_tail", n_seeds=30)
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.wall_time_s, report.steps_per_s          # (timing on every backend)

    spec = ExperimentSpec(backend="mesh", arch="yi_9b", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit()          # synthetic LM stream
    report.final_loss, report.history

The mesh path jits the strategy-driven step from `repro.engine.mesh` and is
numerically identical, step for step, to the legacy
`train.steps.build_train_step` loop (tests/test_engine.py locks this in).
The sim path drives the literal numpy parameter server; the scan path drives
the jitted `repro.engine.delaysim` simulator, which reproduces the sim's
trajectories to float64 round-off (tests/test_delaysim.py). Either way the
caller never touches `PSConfig`, `GuidedConfig`, `train_ps` or
`build_train_step`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

from repro.engine.spec import ExperimentSpec


@dataclasses.dataclass
class Report:
    """Common result of a Trainer.fit run on either backend.

    history: per-step dicts on the mesh backend ({step, loss, worker_var,
    corr_w}); per-arrival (t, avg_err) pairs on the sim backend.
    """

    backend: str
    spec: ExperimentSpec
    history: list
    final: dict
    model: Any = None          # sim/scan: LogisticRegression (scan n_seeds>1:
                               # list of them); mesh: params pytree
    state: Any = None          # mesh: final GuidedState
    wall_time_s: float = 0.0   # wall time of fit() (incl. jit compile)
    steps_per_s: float = 0.0   # server steps (x seeds on scan) per second
    n_steps: int = 0           # server steps this fit actually ran (per seed);
                               # from the schedule/server counter, NOT history
                               # record count — resume/history granularity safe
    start_step: int = 0        # mesh: step resumed from (0 = fresh run)
    interrupted: bool = False  # mesh: SIGTERM cut the run short (state saved)

    @property
    def final_loss(self) -> Optional[float]:
        if self.backend == "mesh":
            return self.final.get("loss")
        return self.final.get("train_loss")

    @property
    def val_loss(self) -> Optional[float]:
        return self.final.get("val_loss")

    @property
    def test_accuracy(self) -> Optional[float]:
        return self.final.get("test_accuracy")


class Trainer:
    """Facade dispatching an ExperimentSpec to its backend.

    Construction is cheap and side-effect free; model init / jit / data
    loading happen inside fit(). `trainer.strategy` is the resolved
    DelayCompensator instance (mesh backend).
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.strategy = None
        if spec.backend == "mesh":
            from repro.engine.mesh import resolve_strategy

            # resolve eagerly so unknown names fail at from_spec, not mid-fit
            self.strategy = resolve_strategy(spec.to_guided_config(), spec.strategy)
        elif spec.backend == "scan":
            from repro.engine.strategies import get_compensator

            self.strategy = get_compensator(spec.strategy, spec.to_guided_config())
        else:
            spec.to_ps_config()  # validates mode/strategy for the simulator

    @classmethod
    def from_spec(cls, spec: ExperimentSpec) -> "Trainer":
        return cls(spec)

    # ------------------------------------------------------------------ fit
    def fit(self, data=None, steps: Optional[int] = None,
            on_step: Optional[Callable] = None, keep_history: bool = True,
            resume: bool = False) -> Report:
        """Run the experiment.

        sim backend: `data` is (X, y, n_classes[, Xtest, ytest]).
        mesh backend: `data` is an iterable of batch dicts (or None for the
        synthetic LM stream); `steps` overrides spec.steps; `on_step(step,
        metrics, params)` fires after every step with the RAW device metrics
        dict (loss, worker_loss_var, corr_weight_sum, lr, step) — reading a
        value forces a host sync, so cheap callbacks only touch them on their
        own logging cadence. The `params` handed to on_step are donated to the
        next step's jit call — read or save them synchronously inside the
        callback; retaining them across steps raises "Array has been deleted".
        Report.history is materialized after the loop so the hot path never
        blocks on device->host transfers; long launcher runs that keep their
        own log-step records pass keep_history=False to retain (and sync)
        only the final step.

        Checkpointing (mesh backend, DESIGN.md §8): spec.ckpt_dir enables
        full-state snapshots — params AND GuidedState (opt state, consistency
        scores, w_stale ring, strategy extra, step) plus the data-stream
        cursor — written asynchronously every spec.ckpt_every steps and once
        at loop exit (SIGTERM included: the handler finishes the in-flight
        step, snapshots, and returns with Report.interrupted=True).
        resume=True restarts from the latest manifest entry in spec.ckpt_dir
        bit-exactly: train(N) == train(k) + resume(N-k), leaf for leaf (a
        missing/empty ckpt_dir starts fresh). When resuming with an explicit
        `data` iterable, the already-consumed prefix is skipped — pass the
        same stream an uninterrupted run would have seen.
        """
        t0 = time.perf_counter()
        if self.spec.backend in ("sim", "scan"):
            if steps is not None or on_step is not None:
                raise ValueError(
                    "steps/on_step apply to the mesh backend; the sim/scan "
                    "backends run the paper's epoch protocol (set spec.epochs)"
                )
            if resume:
                raise ValueError(
                    "resume applies to the mesh backend; sim/scan runs are "
                    "single jit/process calls with nothing to resume into"
                )
            report = (self._fit_sim(data) if self.spec.backend == "sim"
                      else self._fit_scan(data))
            n_total = report.n_steps * self.spec.n_seeds
        else:
            report = self._fit_mesh(data, steps, on_step, keep_history, resume)
            n_total = report.n_steps
        report.wall_time_s = time.perf_counter() - t0
        report.steps_per_s = n_total / max(report.wall_time_s, 1e-9)
        return report

    def _fit_sim(self, data) -> Report:
        from repro.core.parameter_server import train_ps

        if data is None:
            raise ValueError("sim backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = train_ps(X, y, n_classes, self.spec.to_ps_config(), Xtest, ytest)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="sim", spec=self.spec, history=res["history"],
                      final=final, model=res["model"],
                      n_steps=res.get("n_steps", len(res["history"])))

    def _fit_scan(self, data) -> Report:
        """The jitted lax.scan delay simulator (repro.engine.delaysim): same
        data contract and Report shape as the sim backend; n_seeds > 1 turns
        the final metrics into (n_seeds,) arrays (one vmapped compile)."""
        from repro.engine import delaysim

        if data is None:
            raise ValueError("scan backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = delaysim.run(self.spec, X, y, n_classes, Xtest, ytest,
                           strategy=self.strategy)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="scan", spec=self.spec, history=res["history"],
                      final=final, model=res["model"],
                      n_steps=res.get("n_steps", len(res["history"])))

    def _fit_mesh(self, data, steps, on_step, keep_history=True, resume=False) -> Report:
        import signal
        import threading

        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro import checkpoint as C
        from repro.engine import mesh as M
        from repro.optim import for_run, get_optimizer

        spec = self.spec
        n_steps = steps or spec.steps
        cfg = spec.model_config()
        ctx = M.build_ctx(spec.mesh)
        gcfg = spec.to_guided_config()
        opt = get_optimizer(spec.optimizer)
        # schedule phases partition n_steps (for_run); the wsd endpoint
        # actually reaches final_frac before the run ends
        lr = for_run(spec.schedule, spec.lr, spec.warmup, n_steps)

        c = spec.workers or max(ctx.n_workers, 1)
        if spec.global_batch % c != 0:
            # a real exception, not an assert (asserts vanish under python -O):
            # per-worker losses need equal data shards
            raise ValueError(
                f"spec.global_batch={spec.global_batch} is not divisible by the "
                f"worker count c={c} (spec.workers={spec.workers}, mesh "
                f"{spec.mesh!r} provides {ctx.n_workers} data shards); the "
                f"per-worker loss reshape needs equal shards — adjust "
                f"spec.global_batch or spec.workers")
        key = jax.random.PRNGKey(spec.seed)
        params, logical, gstate = M.init_train_state(
            key, cfg, gcfg, opt, n_workers=c, strategy=self.strategy
        )
        step_fn = M.build_train_step(cfg, gcfg, opt, ctx, lr, n_micro=spec.micro,
                                     n_workers=c, strategy=self.strategy)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        start_step = 0
        if resume:
            if not spec.ckpt_dir:
                raise ValueError("fit(resume=True) needs spec.ckpt_dir to know "
                                 "where the snapshots live")
            latest = C.latest_step(spec.ckpt_dir)
            if latest is not None:
                # the freshly initialized state is the restore template: same
                # treedef (incl. strategy extra / w_stale presence), so a
                # checkpoint from a different config fails loudly, not subtly
                template = C.snapshot(params, gstate, 0)
                shardings = (C.train_state_shardings(ctx, logical, params, gstate)
                             if ctx.distributed else None)
                snap = C.restore_train_state(spec.ckpt_dir, latest, template,
                                             shardings=shardings)
                params, gstate = snap["params"], snap["gstate"]
                if shardings is None:
                    # commit host arrays to device so donation keeps working
                    params = jax.tree.map(jnp.asarray, params)
                    gstate = jax.tree.map(jnp.asarray, gstate)
                start_step = int(np.asarray(snap["data"]["cursor"]))
                if start_step > n_steps:
                    raise ValueError(
                        f"checkpoint at step {start_step} is past this run's "
                        f"n_steps={n_steps}; nothing to resume")

        # constructed only once resume validation passed: a failed restore
        # must not strand the writer thread
        ckpt = None
        if spec.ckpt_dir:
            ckpt = C.AsyncCheckpointer(spec.ckpt_dir, keep_last=spec.keep_last,
                                       meta=C.spec_meta(spec))

        batches = iter(data) if data is not None else self._synthetic_batches(cfg, c)
        for _ in range(start_step):  # replay the data cursor: same rng protocol,
            next(batches)            # so resumed steps see the exact batches

        # SIGTERM-safe: a preempted run finishes the in-flight step, snapshots
        # full state, and exits cleanly instead of losing the window
        stop = {"sig": None}
        old_handler, installed = None, False
        if ckpt is not None and threading.current_thread() is threading.main_thread():
            def _on_term(signum, frame):
                stop["sig"] = signum

            try:
                # the previous handler can legitimately be None (installed
                # from C) — track installation separately so restore still runs
                old_handler = signal.signal(signal.SIGTERM, _on_term)
                installed = True
            except (ValueError, AttributeError):  # non-main interpreter / platform
                installed = False

        raw = []
        m = None
        done = start_step
        try:
            for step in range(start_step, n_steps):
                batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
                params, gstate, m = step_fn(params, gstate, batch)
                done = step + 1
                if keep_history:
                    raw.append((step, m))
                if on_step is not None:
                    on_step(step, m, params)
                if ckpt is not None and spec.ckpt_every and done % spec.ckpt_every == 0:
                    # device->host copy here (step boundary, before the next
                    # dispatch donates these buffers); serialization is async
                    ckpt.save(done, C.snapshot(params, gstate, done))
                if stop["sig"] is not None:
                    break
        finally:
            if installed:
                # a None previous handler (installed from C) cannot be
                # re-registered through signal.signal; SIG_DFL beats leaving
                # our dead closure swallowing every later SIGTERM
                signal.signal(signal.SIGTERM,
                              old_handler if old_handler is not None
                              else signal.SIG_DFL)
            if ckpt is not None:
                import sys

                loop_failed = sys.exc_info()[0] is not None
                try:
                    try:
                        # final full-state snapshot (dedupes against a periodic
                        # save that already covered `done`)
                        if done > start_step or C.latest_step(spec.ckpt_dir) is None:
                            ckpt.save(done, C.snapshot(params, gstate, done))
                    finally:
                        ckpt.close()  # drain + join even if the save failed
                except Exception:
                    # a training-loop exception outranks checkpoint teardown
                    # noise; surface the writer error only on a clean loop
                    if not loop_failed:
                        raise
        if not keep_history and m is not None:
            raw = [(done - 1, m)]
        history = [
            {"step": step, "loss": float(mi["loss"]),
             "worker_var": float(mi["worker_loss_var"]),
             "corr_w": float(mi["corr_weight_sum"])}
            for step, mi in raw
        ]
        final = dict(history[-1]) if history else {}
        return Report(backend="mesh", spec=self.spec, history=history, final=final,
                      model=params, state=gstate, n_steps=done - start_step,
                      start_step=start_step, interrupted=stop["sig"] is not None)

    def _synthetic_batches(self, cfg, c: int):
        from repro.data import make_batch_for, synthetic_lm_batches

        spec = self.spec
        if cfg.audio_frontend or cfg.arch_type == "vlm":
            def gen():
                i = 0
                while True:
                    yield make_batch_for(cfg, spec.seq_len, spec.global_batch,
                                         seed=spec.seed + i)
                    i += 1

            return gen()
        return synthetic_lm_batches(cfg.vocab_size, spec.seq_len, spec.global_batch,
                                    seed=spec.seed, n_corpora=c)
