"""`Trainer` — one facade over the three backends, `Report` — one result type.

    spec = ExperimentSpec(backend="sim", mode="ssgd", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.test_accuracy, report.history

    spec = ExperimentSpec(backend="scan", mode="asgd", strategy="dc_asgd",
                          topology="heavy_tail", n_seeds=30)
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.wall_time_s, report.steps_per_s          # (timing on every backend)

    spec = ExperimentSpec(backend="mesh", arch="yi_9b", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit()          # synthetic LM stream
    report.final_loss, report.history

The mesh path jits the strategy-driven step from `repro.engine.mesh` and is
numerically identical, step for step, to the legacy
`train.steps.build_train_step` loop (tests/test_engine.py locks this in).
The sim path drives the literal numpy parameter server; the scan path drives
the jitted `repro.engine.delaysim` simulator, which reproduces the sim's
trajectories to float64 round-off (tests/test_delaysim.py). Either way the
caller never touches `PSConfig`, `GuidedConfig`, `train_ps` or
`build_train_step`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

from repro.engine.spec import ExperimentSpec


@dataclasses.dataclass
class Report:
    """Common result of a Trainer.fit run on either backend.

    history: per-step dicts on the mesh backend ({step, loss, worker_var,
    corr_w}); per-arrival (t, avg_err) pairs on the sim backend.
    """

    backend: str
    spec: ExperimentSpec
    history: list
    final: dict
    model: Any = None          # sim/scan: LogisticRegression (scan n_seeds>1:
                               # list of them); mesh: params pytree
    state: Any = None          # mesh: final GuidedState
    wall_time_s: float = 0.0   # wall time of fit() (incl. jit compile)
    steps_per_s: float = 0.0   # WARM throughput: server steps (x seeds on
                               # scan) per second — warm_steps/warm_time_s
                               # when the mesh loop measured them; falls
                               # back to n_steps/wall_time_s otherwise
    compile_time_s: float = 0.0  # sum of compiling dispatches (first
                               # occurrence of each chunk shape, incl. the
                               # steps they cover; mesh; 0 when unmeasured)
    warm_steps: int = 0        # steps outside compiling dispatches (mesh) —
                               # the numerator of the warm steps_per_s
    warm_time_s: float = 0.0   # wall time of the warm dispatches alone (the
                               # loop span minus compiling windows): setup,
                               # restore, and teardown never land in it
    n_steps: int = 0           # server steps this fit actually ran (per seed);
                               # from the schedule/server counter, NOT history
                               # record count — resume/history granularity safe
    start_step: int = 0        # mesh: step resumed from (0 = fresh run)
    interrupted: bool = False  # mesh: SIGTERM cut the run short (state saved)
    staleness_hist: dict = dataclasses.field(default_factory=dict)
                               # dist: OBSERVED staleness -> count over every
                               # applied update (applied_version - read_version)
    dist: dict = dataclasses.field(default_factory=dict)
                               # dist: run diagnostics (mode, n_workers, drops,
                               # late, worker_exits, joins; with the
                               # resilience layer armed also rejections/
                               # rollbacks/supervisor counters)
    resilience: dict = dataclasses.field(default_factory=dict)
                               # mesh: sentinel outcome ({sentinel,
                               # rejected_steps}) when spec.sentinel is set

    @property
    def final_loss(self) -> Optional[float]:
        if self.backend == "mesh":
            return self.final.get("loss")
        return self.final.get("train_loss")

    @property
    def val_loss(self) -> Optional[float]:
        return self.final.get("val_loss")

    @property
    def test_accuracy(self) -> Optional[float]:
        return self.final.get("test_accuracy")


class Trainer:
    """Facade dispatching an ExperimentSpec to its backend.

    Construction is cheap and side-effect free; model init / jit / data
    loading happen inside fit(). `trainer.strategy` is the resolved
    DelayCompensator instance (mesh backend).
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.strategy = None
        if spec.backend == "mesh":
            from repro.engine.mesh import resolve_strategy

            # resolve eagerly so unknown names fail at from_spec, not mid-fit
            self.strategy = resolve_strategy(spec.to_guided_config(), spec.strategy)
        elif spec.backend in ("scan", "dist"):
            from repro.engine.strategies import get_compensator

            self.strategy = get_compensator(spec.strategy, spec.to_guided_config())
        else:
            spec.to_ps_config()  # validates mode/strategy for the simulator

    @classmethod
    def from_spec(cls, spec: ExperimentSpec) -> "Trainer":
        return cls(spec)

    # ------------------------------------------------------------------ fit
    def fit(self, data=None, steps: Optional[int] = None,
            on_step: Optional[Callable] = None, keep_history: bool = True,
            resume: bool = False) -> Report:
        """Run the experiment.

        sim backend: `data` is (X, y, n_classes[, Xtest, ytest]).
        mesh backend: `data` is an iterable of batch dicts (or None for the
        synthetic LM stream); `steps` overrides spec.steps; `on_step(step,
        metrics, params)` fires after every step with the RAW device metrics
        dict (loss, worker_loss_var, corr_weight_sum, lr, step) — reading a
        value forces a host sync, so cheap callbacks only touch them on their
        own logging cadence. The `params` handed to on_step are donated to the
        next step's jit call — read or save them synchronously inside the
        callback; retaining them across steps raises "Array has been deleted".
        Report.history is materialized after the loop so the hot path never
        blocks on device->host transfers; long launcher runs that keep their
        own log-step records pass keep_history=False to retain (and sync)
        only the final step.

        Pipelining (mesh backend, DESIGN.md §9): spec.chunk_steps=K > 1 fuses
        K train steps into one jitted lax.scan dispatch over a stacked
        (K, ...) batch block — bit-exact with the per-step loop, but on_step
        then fires once per CHUNK with stacked (k,) device metrics and
        step = the last step index of the chunk (chunk_steps=1 restores the
        legacy per-step scalar contract, and runs the literal legacy loop).
        spec.prefetch=True stages the next chunk's batches (generation,
        stacking, device_put against the data-shard sharding) on a background
        thread while the current chunk computes. Checkpoint cadence is
        preserved exactly: chunks split at ckpt_every multiples, and SIGTERM
        drains the in-flight chunk before the final snapshot.

        Checkpointing (mesh backend, DESIGN.md §8): spec.ckpt_dir enables
        full-state snapshots — params AND GuidedState (opt state, consistency
        scores, w_stale ring, strategy extra, step) plus the data-stream
        cursor — written asynchronously every spec.ckpt_every steps and once
        at loop exit (SIGTERM included: the handler finishes the in-flight
        step, snapshots, and returns with Report.interrupted=True).
        resume=True restarts from the latest manifest entry in spec.ckpt_dir
        bit-exactly: train(N) == train(k) + resume(N-k), leaf for leaf (a
        missing/empty ckpt_dir starts fresh). When resuming with an explicit
        `data` iterable, the already-consumed prefix is skipped — pass the
        same stream an uninterrupted run would have seen.
        """
        t0 = time.perf_counter()
        if self.spec.backend in ("sim", "scan", "dist"):
            if steps is not None or on_step is not None:
                raise ValueError(
                    "steps/on_step apply to the mesh backend; the sim/scan/"
                    "dist backends run the paper's epoch protocol (set "
                    "spec.epochs)"
                )
            if resume:
                raise ValueError(
                    "resume applies to the mesh backend; sim/scan/dist runs "
                    "are single fit calls with nothing to resume into"
                )
            report = {"sim": self._fit_sim, "scan": self._fit_scan,
                      "dist": self._fit_dist}[self.spec.backend](data)
            n_total = report.n_steps * self.spec.n_seeds
        else:
            report = self._fit_mesh(data, steps, on_step, keep_history, resume)
            n_total = report.n_steps
        report.wall_time_s = time.perf_counter() - t0
        if report.warm_steps > 0 and report.warm_time_s > 0:
            # warm throughput: compiling dispatches AND the out-of-loop setup
            # (init, restore, teardown) are split out so BENCH numbers stop
            # averaging compilation into the steady state
            report.steps_per_s = report.warm_steps / report.warm_time_s
        else:
            report.steps_per_s = n_total / max(report.wall_time_s, 1e-9)
        return report

    def _fit_sim(self, data) -> Report:
        from repro.core.parameter_server import train_ps

        if data is None:
            raise ValueError("sim backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = train_ps(X, y, n_classes, self.spec.to_ps_config(), Xtest, ytest)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="sim", spec=self.spec, history=res["history"],
                      final=final, model=res["model"],
                      n_steps=res.get("n_steps", len(res["history"])))

    def _fit_scan(self, data) -> Report:
        """The jitted lax.scan delay simulator (repro.engine.delaysim): same
        data contract and Report shape as the sim backend; n_seeds > 1 turns
        the final metrics into (n_seeds,) arrays (one vmapped compile)."""
        from repro.engine import delaysim

        if data is None:
            raise ValueError("scan backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = delaysim.run(self.spec, X, y, n_classes, Xtest, ytest,
                           strategy=self.strategy)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="scan", spec=self.spec, history=res["history"],
                      final=final, model=res["model"],
                      n_steps=res.get("n_steps", len(res["history"])))

    def _fit_dist(self, data) -> Report:
        """The real multi-process async parameter server (repro.dist): same
        data contract as sim/scan. Report additionally carries the OBSERVED
        staleness histogram and the dist diagnostics (drops, worker exits,
        elastic joins) — the quantities the simulators can only assume."""
        from repro.dist import launcher

        if data is None:
            raise ValueError("dist backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = launcher.run_local(self.spec, X, y, n_classes, Xtest, ytest,
                                 strategy=self.strategy)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="dist", spec=self.spec, history=res["history"],
                      final=final, model=res["model"], n_steps=res["n_steps"],
                      staleness_hist=res["staleness_hist"], dist=res["dist"])

    def _fit_mesh(self, data, steps, on_step, keep_history=True, resume=False) -> Report:
        from repro.engine import trainloop

        return trainloop.fit(self.spec, self.strategy, data=data, steps=steps,
                             on_step=on_step, keep_history=keep_history,
                             resume=resume)

    def _synthetic_batches(self, cfg, c: int):
        from repro.engine.trainloop import synthetic_stream

        return synthetic_stream(self.spec, cfg, c)
