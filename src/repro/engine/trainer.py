"""`Trainer` — one facade over the three backends, `Report` — one result type.

    spec = ExperimentSpec(backend="sim", mode="ssgd", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.test_accuracy, report.history

    spec = ExperimentSpec(backend="scan", mode="asgd", strategy="dc_asgd",
                          topology="heavy_tail", n_seeds=30)
    report = Trainer.from_spec(spec).fit((Xtr, ytr, n_classes, Xte, yte))
    report.wall_time_s, report.steps_per_s          # (timing on every backend)

    spec = ExperimentSpec(backend="mesh", arch="yi_9b", strategy="guided_fused")
    report = Trainer.from_spec(spec).fit()          # synthetic LM stream
    report.final_loss, report.history

The mesh path jits the strategy-driven step from `repro.engine.mesh` and is
numerically identical, step for step, to the legacy
`train.steps.build_train_step` loop (tests/test_engine.py locks this in).
The sim path drives the literal numpy parameter server; the scan path drives
the jitted `repro.engine.delaysim` simulator, which reproduces the sim's
trajectories to float64 round-off (tests/test_delaysim.py). Either way the
caller never touches `PSConfig`, `GuidedConfig`, `train_ps` or
`build_train_step`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

from repro.engine.spec import ExperimentSpec


@dataclasses.dataclass
class Report:
    """Common result of a Trainer.fit run on either backend.

    history: per-step dicts on the mesh backend ({step, loss, worker_var,
    corr_w}); per-arrival (t, avg_err) pairs on the sim backend.
    """

    backend: str
    spec: ExperimentSpec
    history: list
    final: dict
    model: Any = None          # sim/scan: LogisticRegression (scan n_seeds>1:
                               # list of them); mesh: params pytree
    state: Any = None          # mesh: final GuidedState
    wall_time_s: float = 0.0   # wall time of fit() (incl. jit compile)
    steps_per_s: float = 0.0   # server steps (x seeds on scan) per second

    @property
    def final_loss(self) -> Optional[float]:
        if self.backend == "mesh":
            return self.final.get("loss")
        return self.final.get("train_loss")

    @property
    def val_loss(self) -> Optional[float]:
        return self.final.get("val_loss")

    @property
    def test_accuracy(self) -> Optional[float]:
        return self.final.get("test_accuracy")


class Trainer:
    """Facade dispatching an ExperimentSpec to its backend.

    Construction is cheap and side-effect free; model init / jit / data
    loading happen inside fit(). `trainer.strategy` is the resolved
    DelayCompensator instance (mesh backend).
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.strategy = None
        if spec.backend == "mesh":
            from repro.engine.mesh import resolve_strategy

            # resolve eagerly so unknown names fail at from_spec, not mid-fit
            self.strategy = resolve_strategy(spec.to_guided_config(), spec.strategy)
        elif spec.backend == "scan":
            from repro.engine.strategies import get_compensator

            self.strategy = get_compensator(spec.strategy, spec.to_guided_config())
        else:
            spec.to_ps_config()  # validates mode/strategy for the simulator

    @classmethod
    def from_spec(cls, spec: ExperimentSpec) -> "Trainer":
        return cls(spec)

    # ------------------------------------------------------------------ fit
    def fit(self, data=None, steps: Optional[int] = None,
            on_step: Optional[Callable] = None, keep_history: bool = True) -> Report:
        """Run the experiment.

        sim backend: `data` is (X, y, n_classes[, Xtest, ytest]).
        mesh backend: `data` is an iterable of batch dicts (or None for the
        synthetic LM stream); `steps` overrides spec.steps; `on_step(step,
        metrics, params)` fires after every step with the RAW device metrics
        dict (loss, worker_loss_var, corr_weight_sum, lr, step) — reading a
        value forces a host sync, so cheap callbacks only touch them on their
        own logging cadence. The `params` handed to on_step are donated to the
        next step's jit call — read or save them synchronously inside the
        callback; retaining them across steps raises "Array has been deleted".
        Report.history is materialized after the loop so the hot path never
        blocks on device->host transfers; long launcher runs that keep their
        own log-step records pass keep_history=False to retain (and sync)
        only the final step.
        """
        t0 = time.perf_counter()
        if self.spec.backend in ("sim", "scan"):
            if steps is not None or on_step is not None:
                raise ValueError(
                    "steps/on_step apply to the mesh backend; the sim/scan "
                    "backends run the paper's epoch protocol (set spec.epochs)"
                )
            report = (self._fit_sim(data) if self.spec.backend == "sim"
                      else self._fit_scan(data))
            n_steps = len(report.history) * self.spec.n_seeds
        else:
            report = self._fit_mesh(data, steps, on_step, keep_history)
            n_steps = steps or self.spec.steps
        report.wall_time_s = time.perf_counter() - t0
        report.steps_per_s = n_steps / max(report.wall_time_s, 1e-9)
        return report

    def _fit_sim(self, data) -> Report:
        from repro.core.parameter_server import train_ps

        if data is None:
            raise ValueError("sim backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = train_ps(X, y, n_classes, self.spec.to_ps_config(), Xtest, ytest)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="sim", spec=self.spec, history=res["history"],
                      final=final, model=res["model"])

    def _fit_scan(self, data) -> Report:
        """The jitted lax.scan delay simulator (repro.engine.delaysim): same
        data contract and Report shape as the sim backend; n_seeds > 1 turns
        the final metrics into (n_seeds,) arrays (one vmapped compile)."""
        from repro.engine import delaysim

        if data is None:
            raise ValueError("scan backend needs data=(X, y, n_classes[, Xtest, ytest])")
        X, y, n_classes, *rest = data
        Xtest, ytest = (rest + [None, None])[:2]
        res = delaysim.run(self.spec, X, y, n_classes, Xtest, ytest,
                           strategy=self.strategy)
        final = {k: res[k] for k in ("train_loss", "val_loss", "test_accuracy") if k in res}
        return Report(backend="scan", spec=self.spec, history=res["history"],
                      final=final, model=res["model"])

    def _fit_mesh(self, data, steps, on_step, keep_history=True) -> Report:
        import jax
        import jax.numpy as jnp

        from repro.engine import mesh as M
        from repro.optim import constant, cosine, get_optimizer, wsd

        spec = self.spec
        n_steps = steps or spec.steps
        cfg = spec.model_config()
        ctx = M.build_ctx(spec.mesh)
        gcfg = spec.to_guided_config()
        opt = get_optimizer(spec.optimizer)
        if spec.schedule == "constant":
            lr = constant(spec.lr)
        elif spec.schedule == "wsd":
            lr = wsd(spec.lr, spec.warmup, n_steps // 2, n_steps // 2)
        elif spec.schedule == "cosine":
            lr = cosine(spec.lr, spec.warmup, n_steps)
        else:
            raise ValueError(spec.schedule)

        c = spec.workers or max(ctx.n_workers, 1)
        if spec.global_batch % c != 0:
            # a real exception, not an assert (asserts vanish under python -O):
            # per-worker losses need equal data shards
            raise ValueError(
                f"spec.global_batch={spec.global_batch} is not divisible by the "
                f"worker count c={c} (spec.workers={spec.workers}, mesh "
                f"{spec.mesh!r} provides {ctx.n_workers} data shards); the "
                f"per-worker loss reshape needs equal shards — adjust "
                f"spec.global_batch or spec.workers")
        key = jax.random.PRNGKey(spec.seed)
        params, logical, gstate = M.init_train_state(
            key, cfg, gcfg, opt, n_workers=c, strategy=self.strategy
        )
        step_fn = M.build_train_step(cfg, gcfg, opt, ctx, lr, n_micro=spec.micro,
                                     n_workers=c, strategy=self.strategy)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        batches = iter(data) if data is not None else self._synthetic_batches(cfg, c)

        raw = []
        m = None
        for step in range(n_steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            params, gstate, m = step_fn(params, gstate, batch)
            if keep_history:
                raw.append((step, m))
            if on_step is not None:
                on_step(step, m, params)
        if not keep_history and m is not None:
            raw = [(n_steps - 1, m)]
        history = [
            {"step": step, "loss": float(mi["loss"]),
             "worker_var": float(mi["worker_loss_var"]),
             "corr_w": float(mi["corr_weight_sum"])}
            for step, mi in raw
        ]
        final = dict(history[-1]) if history else {}
        return Report(backend="mesh", spec=self.spec, history=history, final=final,
                      model=params, state=gstate)

    def _synthetic_batches(self, cfg, c: int):
        from repro.data import make_batch_for, synthetic_lm_batches

        spec = self.spec
        if cfg.audio_frontend or cfg.arch_type == "vlm":
            def gen():
                i = 0
                while True:
                    yield make_batch_for(cfg, spec.seq_len, spec.global_batch,
                                         seed=spec.seed + i)
                    i += 1

            return gen()
        return synthetic_lm_batches(cfg.vocab_size, spec.seq_len, spec.global_batch,
                                    seed=spec.seed, n_corpora=c)
