"""Pipelined mesh fit loop: chunked multi-step dispatch + prefetch (DESIGN.md §9).

The per-step loop the Trainer used to run — one Python-dispatched jit call per
train step with a synchronous `jnp.asarray` host->device copy in front of it —
leaves the accelerator idle on dispatch and data staging whenever per-step
compute is small. This module is the same treatment PR 2 gave the delay
simulator, applied to real mesh training:

  * `chunk_schedule` partitions the step range into dispatch chunks of at most
    `spec.chunk_steps` steps, split (never shifted) so every `ckpt_every`
    multiple lands on a chunk boundary — the snapshot cadence is preserved
    exactly, and a resume point may land anywhere in the schedule;
  * `build_chunk_step` fuses K train steps into ONE jitted `lax.scan` over a
    stacked `(K, ...)` batch block with the `(params, gstate)` carry donated
    end-to-end; metrics accumulate on device and come back as stacked `(K,)`
    arrays, so per-step history is preserved while the host syncs once per
    chunk instead of once per step;
  * the `repro.data.prefetch` double buffer stages block i+1 (batch
    generation, stacking, and the `jax.device_put` against the data-shard
    sharding) on a worker thread while chunk i computes.

Contracts (locked in tests/test_trainloop.py):

  * bit-exactness — chunked+prefetched fit(N) == the stepwise loop
    leaf-for-leaf (params, gstate, and per-step history) for every registered
    strategy; `chunk_steps=1` runs the literal legacy per-step loop;
  * checkpoints land on exactly the same steps as the stepwise loop, and
    resume is bit-exact from any snapshot, including resume points between
    the natural chunk boundaries (the schedule is recomputed from
    `start_step`, and any chunk partition yields the same trajectory);
  * SIGTERM drains the in-flight chunk, snapshots the full state at its
    boundary, and returns `Report.interrupted=True`;
  * `on_step(step, metrics, params)` fires once per chunk with the stacked
    `(k,)` device metrics and `step` = the LAST step index of the chunk;
    `chunk_steps=1` restores the legacy per-step scalar contract. Either way
    the `params` handed over are donated to the next dispatch — read or save
    them synchronously inside the callback.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

from repro.engine.spec import ExperimentSpec


def chunk_schedule(start: int, stop: int, chunk_steps: int,
                   ckpt_every: int = 0) -> List[int]:
    """Sizes of the consecutive dispatch chunks covering steps [start, stop).

    Each chunk is at most `chunk_steps` long; when `ckpt_every` is set, every
    multiple of it lands on a chunk boundary (chunks are split at the cadence,
    never shifted past it), so the chunked loop snapshots at exactly the steps
    the stepwise loop would. A `start` mid-cadence (resume from a snapshot
    that a split chunk produced) re-aligns at the next multiple.
    """
    if chunk_steps < 1:
        raise ValueError(f"chunk_steps must be >= 1 (got {chunk_steps})")
    sizes = []
    s = start
    while s < stop:
        k = min(chunk_steps, stop - s)
        if ckpt_every:
            k = min(k, ckpt_every - s % ckpt_every)
        sizes.append(k)
        s += k
    return sizes


#: Report/launcher history record name -> raw device-metrics key
_METRIC_KEYS = (("loss", "loss"), ("worker_var", "worker_loss_var"),
                ("corr_w", "corr_weight_sum"))


def step_records(m, first: int, indices=None) -> List[dict]:
    """Materialize per-step history records from ONE dispatch's raw device
    metrics — scalar per-step values (`chunk_steps=1`) or stacked `(k,)`
    chunk arrays. `first` is the step index of the dispatch's first step;
    `indices` restricts which in-chunk offsets materialize (None -> all).
    The single host transfer per metric happens here, so callers on a
    logging cadence (the launcher) pass only their log offsets and an empty
    selection never syncs at all.
    """
    import jax

    shape = getattr(m["loss"], "shape", ())
    if indices is None:
        indices = range(shape[0] if shape else 1)
    indices = list(indices)
    if not indices:
        return []
    # ONE batched host transfer for all metrics of the dispatch
    vals = jax.device_get(tuple(m[key] for _, key in _METRIC_KEYS))  # lint: allow[host-sync-in-hot-loop] the single per-dispatch sync point
    arrs = dict(zip((name for name, _ in _METRIC_KEYS), vals))
    return [{"step": first + i,
             **{name: float(a[i] if shape else a) for name, a in arrs.items()}}  # lint: allow[host-sync-in-hot-loop] host np scalars after the batched get
            for i in indices]


def build_chunk_step(step_fn: Callable) -> Callable:
    """Fuse `step_fn(params, gstate, batch) -> (params, gstate, metrics)` into
    `chunk_fn(params, gstate, stacked)`: one `lax.scan` over the leading axis
    of `stacked` (a `(K, ...)`-stacked batch block) with the train state as
    the carry. Returns the final state plus metrics stacked to `(K,)` arrays.
    Jit it with `donate_argnums=(0, 1)` — the carry is donated end-to-end.
    """
    import jax

    def chunk_fn(params, gstate, stacked):
        def body(carry, batch):
            p, g, m = step_fn(carry[0], carry[1], batch)
            return (p, g), m

        (params, gstate), metrics = jax.lax.scan(body, (params, gstate), stacked)
        return params, gstate, metrics

    return chunk_fn


def synthetic_stream(spec: ExperimentSpec, cfg, c: int):
    """The per-step synthetic batch stream for `data=None` mesh fits (the
    deterministic function of (seed, #draws) that makes the checkpoint data
    cursor replayable)."""
    from repro.data import make_batch_for, synthetic_lm_batches

    if cfg.audio_frontend or cfg.arch_type == "vlm":
        def gen():
            i = 0
            while True:
                yield make_batch_for(cfg, spec.seq_len, spec.global_batch,
                                     seed=spec.seed + i)
                i += 1

        return gen()
    return synthetic_lm_batches(cfg.vocab_size, spec.seq_len, spec.global_batch,
                                seed=spec.seed, n_corpora=c)


def fit(spec: ExperimentSpec, strategy, data=None, steps: Optional[int] = None,
        on_step: Optional[Callable] = None, keep_history: bool = True,
        resume: bool = False):
    """The mesh backend's fit loop (what `Trainer.fit` dispatches to).

    Returns a `Report` whose `compile_time_s` sums the compiling dispatches
    (the first occurrence of every chunk shape — the uneven tail and
    ckpt-split chunks each compile their own program), whose `warm_steps`
    counts the steps outside them, and whose `warm_time_s` is the wall time
    of those warm dispatches alone (loop span minus compile windows; setup,
    restore and teardown excluded) — `Report.steps_per_s` is their quotient.
    See the module docstring for the chunk/prefetch contracts.
    """
    import signal
    import threading

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as C
    from repro.data.prefetch import ChunkPrefetcher, batch_put, stack_blocks
    from repro.engine import mesh as M
    from repro.engine.trainer import Report
    from repro.optim import for_run, get_optimizer

    n_steps = steps or spec.steps
    cfg = spec.model_config()
    ctx = M.build_ctx(spec.mesh)
    gcfg = spec.to_guided_config()
    opt = get_optimizer(spec.optimizer)
    # schedule phases partition n_steps (for_run); the wsd endpoint
    # actually reaches final_frac before the run ends
    lr = for_run(spec.schedule, spec.lr, spec.warmup, n_steps)

    c = spec.workers or max(ctx.n_workers, 1)
    if spec.global_batch % c != 0:
        # a real exception, not an assert (asserts vanish under python -O):
        # per-worker losses need equal data shards
        raise ValueError(
            f"spec.global_batch={spec.global_batch} is not divisible by the "
            f"worker count c={c} (spec.workers={spec.workers}, mesh "
            f"{spec.mesh!r} provides {ctx.n_workers} data shards); the "
            f"per-worker loss reshape needs equal shards — adjust "
            f"spec.global_batch or spec.workers")
    key = jax.random.PRNGKey(spec.seed)
    params, logical, gstate = M.init_train_state(
        key, cfg, gcfg, opt, n_workers=c, strategy=strategy
    )
    step_fn = M.build_train_step(cfg, gcfg, opt, ctx, lr, n_micro=spec.micro,
                                 n_workers=c, strategy=strategy)
    if spec.sentinel:
        # divergence sentinel (DESIGN.md §14): screen every step ON DEVICE —
        # a rejected step keeps the previous (params, gstate) carry, so one
        # NaN batch costs a step of progress, never the run; the scan/jit
        # fusion is unchanged because the guard is part of step_fn itself
        from repro.resilience import wrap_step_sentinel

        step_fn = wrap_step_sentinel(step_fn, spec.sentinel,
                                     spec.sentinel_factor)
    chunked = spec.chunk_steps > 1
    dispatch = jax.jit(build_chunk_step(step_fn) if chunked else step_fn,
                       donate_argnums=(0, 1))

    start_step = 0
    if resume:
        if not spec.ckpt_dir:
            raise ValueError("fit(resume=True) needs spec.ckpt_dir to know "
                             "where the snapshots live")
        if C.latest_step(spec.ckpt_dir) is not None:
            # the freshly initialized state is the restore template: same
            # treedef (incl. strategy extra / w_stale presence), so a
            # checkpoint from a different config fails loudly, not subtly
            template = C.snapshot(params, gstate, 0)
            shardings = (C.train_state_shardings(ctx, logical, params, gstate)
                         if ctx.distributed else None)
            # restore_latest re-reads the manifest if retention prunes the
            # step it named between manifest read and archive load
            _, snap = C.restore_latest(spec.ckpt_dir, template,
                                       shardings=shardings)
            params, gstate = snap["params"], snap["gstate"]
            if shardings is None:
                # commit host arrays to device so donation keeps working
                params = jax.tree.map(jnp.asarray, params)
                gstate = jax.tree.map(jnp.asarray, gstate)
            start_step = int(np.asarray(snap["data"]["cursor"]))
            # the fresh-init state lives on only through `template` now that
            # params/gstate are rebound — drop it (and the snapshot dict), or
            # a resumed run holds ~2x the train-state memory of a fresh one
            del template, snap
            if start_step > n_steps:
                raise ValueError(
                    f"checkpoint at step {start_step} is past this run's "
                    f"n_steps={n_steps}; nothing to resume")

    # constructed only once resume validation passed: a failed restore
    # must not strand the writer thread
    ckpt = None
    if spec.ckpt_dir:
        ckpt = C.AsyncCheckpointer(spec.ckpt_dir, keep_last=spec.keep_last,
                                   meta=C.spec_meta(spec))

    batches = iter(data) if data is not None else synthetic_stream(spec, cfg, c)
    for _ in range(start_step):  # replay the data cursor: same rng protocol,
        next(batches)            # so resumed steps see the exact batches

    sizes = chunk_schedule(start_step, n_steps, spec.chunk_steps, spec.ckpt_every)
    # host-side source: pre-stacked (K, ...) blocks for the chunked path
    # (generation + stacking run wherever the source is consumed — on the
    # prefetch thread when spec.prefetch), per-step dicts otherwise
    source = stack_blocks(batches, sizes) if chunked else batches
    put = batch_put(ctx, stacked=chunked)
    prefetcher = None
    if spec.prefetch:
        prefetcher = ChunkPrefetcher(source, put=put)
        source = prefetcher

    # SIGTERM-safe: a preempted run drains the in-flight chunk, snapshots
    # full state, and exits cleanly instead of losing the window
    stop = {"sig": None}
    old_handler, installed = None, False
    if ckpt is not None and threading.current_thread() is threading.main_thread():
        def _on_term(signum, frame):
            stop["sig"] = signum

        try:
            # the previous handler can legitimately be None (installed
            # from C) — track installation separately so restore still runs
            old_handler = signal.signal(signal.SIGTERM, _on_term)
            installed = True
        except (ValueError, AttributeError):  # non-main interpreter / platform
            installed = False

    raw = []                   # (first_step, k, metrics) per dispatch
    m = None
    rej = None                 # device-side rejected-step accumulator
    done = start_step
    compile_time_s = 0.0
    compiled_steps = 0         # steps covered by compiling dispatches
    warm_time_s = 0.0
    seen_sizes = set()
    t_loop = time.perf_counter()   # the loop span: setup/restore excluded
    try:
        for k in sizes:
            # staging always goes through batch_put: sharded H2D placement on
            # distributed meshes, plain jnp.asarray-equivalent on local
            block = next(source) if spec.prefetch else put(next(source))
            # every FIRST dispatch of a chunk shape jit-compiles (the uneven
            # tail and ckpt_every-split chunks each get their own program);
            # timing those (one host sync each) is what lets Report split
            # compile time out of the warm steps/s
            is_new = k not in seen_sizes
            if is_new:
                if m is not None:
                    # drain queued warm dispatches first, or their execution
                    # lands inside the timed window and inflates compile_time
                    jax.block_until_ready(m)
                t_dispatch = time.perf_counter()
            params, gstate, m = dispatch(params, gstate, block)
            if is_new:
                jax.block_until_ready(m)
                compile_time_s += time.perf_counter() - t_dispatch
                compiled_steps += k
                seen_sizes.add(k)
            done += k
            if spec.sentinel:
                # stays device-side (async jnp add): ONE host read after the
                # loop, not a sync per dispatch
                r = m["rejected"].sum() if chunked else m["rejected"]
                rej = r if rej is None else rej + r
            if keep_history:
                raw.append((done - k, k, m))
            if on_step is not None:
                on_step(done - 1, m, params)
            if ckpt is not None and spec.ckpt_every and done % spec.ckpt_every == 0:
                # device->host copy here (chunk boundary, before the next
                # dispatch donates these buffers); serialization is async
                ckpt.save(done, C.snapshot(params, gstate, done))
            if stop["sig"] is not None:
                break
        if m is not None:
            # drain the queue so the warm window closes on finished work;
            # warm time = loop span minus the timed compiling windows, so
            # setup, restore and teardown never land in the throughput
            # denominator (Report.steps_per_s = warm_steps / warm_time_s)
            jax.block_until_ready(m)
        warm_time_s = max(time.perf_counter() - t_loop - compile_time_s, 0.0)
    finally:
        if prefetcher is not None:
            prefetcher.close()
        if installed:
            # a None previous handler (installed from C) cannot be
            # re-registered through signal.signal; SIG_DFL beats leaving
            # our dead closure swallowing every later SIGTERM
            signal.signal(signal.SIGTERM,
                          old_handler if old_handler is not None
                          else signal.SIG_DFL)
        if ckpt is not None:
            import sys

            loop_failed = sys.exc_info()[0] is not None
            try:
                try:
                    # final full-state snapshot (dedupes against a periodic
                    # save that already covered `done`)
                    if done > start_step or C.latest_step(spec.ckpt_dir) is None:
                        ckpt.save(done, C.snapshot(params, gstate, done))
                finally:
                    ckpt.close()  # drain + join even if the save failed
            except Exception:
                # a training-loop exception outranks checkpoint teardown
                # noise; surface the writer error only on a clean loop
                if not loop_failed:
                    raise
    if not keep_history and m is not None:
        last_k = jax.tree.leaves(m)[0].shape[0] if chunked else 1
        raw = [(done - last_k, last_k, m)]

    history = []
    for first, _, mi in raw:
        history.extend(step_records(mi, first))
    if not keep_history:
        history = history[-1:]
    final = dict(history[-1]) if history else {}
    resilience = {}
    if spec.sentinel:
        resilience = {"sentinel": spec.sentinel,
                      "rejected_steps": int(jax.device_get(rej))
                      if rej is not None else 0}
    return Report(backend="mesh", spec=spec, history=history, final=final,
                  model=params, state=gstate, n_steps=done - start_step,
                  start_step=start_step, interrupted=stop["sig"] is not None,
                  compile_time_s=compile_time_s, warm_time_s=warm_time_s,
                  warm_steps=max(done - start_step - compiled_steps, 0),
                  resilience=resilience)
