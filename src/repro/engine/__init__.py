"""repro.engine — the unified Experiment/Trainer API over all four backends
(sim | scan | mesh | dist — the last is the real multi-process async
parameter server of repro.dist, DESIGN.md §10).

    from repro.engine import ExperimentSpec, Trainer

    # the paper's gSSGD on the numpy parameter-server sim
    report = Trainer.from_spec(ExperimentSpec.for_algo("gSSGD", epochs=50)).fit(
        (Xtr, ytr, n_classes, Xte, yte))

    # the jitted scan delay simulator: 30 seeds in one vmapped compile,
    # trajectories identical to the sim (DESIGN.md §6)
    report = Trainer.from_spec(ExperimentSpec.for_algo(
        "gSSGD", backend="scan", n_seeds=30)).fit((Xtr, ytr, n_classes, Xte, yte))

    # the same algorithm on the jitted SPMD mesh trainer
    report = Trainer.from_spec(ExperimentSpec(
        backend="mesh", arch="yi_9b", mode="ssgd", strategy="guided_fused")).fit()

New delay-compensation schemes are ~50-line `DelayCompensator` subclasses
registered with `@register_compensator("name")` — see strategies.py and
DESIGN.md §2.

The spec/Trainer/Report names import eagerly and stay numpy-light; everything
touching the jax stack (strategies, the mesh step builder) is re-exported
lazily so sim-only scripts (paper tables, rho sweeps) don't pay the jax
import cost.
"""
from repro.engine.spec import ALGOS, TOPOLOGIES, ExperimentSpec  # noqa: F401
from repro.engine.trainer import Report, Trainer  # noqa: F401

_LAZY = {
    "DelayCompensator": "strategies",
    "compensator_names": "strategies",
    "get_compensator": "strategies",
    "register_compensator": "strategies",
    "strategy_name_for": "strategies",
    "build_ctx": "mesh",
    "build_train_step": "mesh",
    "init_train_state": "mesh",
    "resolve_strategy": "mesh",
    "build_chunk_step": "trainloop",
    "chunk_schedule": "trainloop",
    "TOPOLOGY_SAMPLERS": "delaysim",
    "clear_runners": "delaysim",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(f"repro.engine.{_LAZY[name]}"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
