"""`ExperimentSpec` — one declarative config subsuming both algorithm stacks.

The paper's algorithm family previously lived behind two disjoint configs:

  * `PSConfig` + `train_ps` — the numpy event-driven parameter-server
    simulator (paper-faithful logistic regression, Tables 2-5 / Figs. 2-14);
  * `GuidedConfig` + `build_train_step` — the jitted SPMD mesh trainer
    (transformer-scale gSSGD/gASGD/DC-ASGD).

An ExperimentSpec names ONE experiment — backend, execution mode, compensation
strategy, optimizer, schedule, mesh, workers, micro-batching — and lowers to
whichever legacy config its backend needs (`to_ps_config` / `to_guided_config`).
`Trainer.from_spec(spec).fit(data)` is the single entry point; see DESIGN.md §1
for the API and §2 for the old-API → new-API migration table.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Tuple

from repro.core.parameter_server import PSConfig

if TYPE_CHECKING:  # GuidedConfig lives in the jax stack; import it lazily so
    from repro.core.guided import GuidedConfig  # sim-only scripts stay numpy-light

BACKENDS = ("mesh", "sim")
MODES = ("seq", "ssgd", "asgd")

# algorithm names as printed in the paper's tables -> (mode, strategy, optimizer)
ALGOS = {
    "SGD": ("seq", "none", "sgd"),
    "gSGD": ("seq", "guided_fused", "sgd"),
    "SSGD": ("ssgd", "none", "sgd"),
    "gSSGD": ("ssgd", "guided_fused", "sgd"),
    "ASGD": ("asgd", "none", "sgd"),
    "gASGD": ("asgd", "guided_fused", "sgd"),
    "SRMSprop": ("ssgd", "none", "rmsprop"),
    "gSRMSprop": ("ssgd", "guided_fused", "rmsprop"),
    "SAdagrad": ("ssgd", "none", "adagrad"),
    "gSAdagrad": ("ssgd", "guided_fused", "adagrad"),
    "DC-ASGD": ("asgd", "dc_asgd", "sgd"),
}

_GUIDED_STRATEGIES = ("guided_fused", "guided_two_pass", "dc_asgd_guided")
_DC_STRATEGIES = ("dc_asgd", "dc_asgd_guided")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment of the paper's algorithm family, on either backend.

    backend="sim" runs the literal numpy parameter-server simulation;
    backend="mesh" runs the jitted SPMD data-parallel trainer. The shared
    fields mean the same thing on both; backend-specific fields are ignored
    by the other backend.
    """

    backend: str = "mesh"          # mesh | sim
    # ------------------------------------------------- shared algorithm knobs
    mode: str = "ssgd"             # seq | ssgd | asgd (execution/delay model)
    strategy: str = "none"         # DelayCompensator registry name
    rho: int = 10                  # delay tolerance / correction period
    max_consistent: int = 4        # paper: replay at most 4 mini-batches
    optimizer: str = "sgd"
    lr: float = 0.2                # paper Table 1 default
    seed: int = 0
    # ------------------------------------------------------------- sim knobs
    epochs: int = 50
    batch_size: int = 16
    verification_frac: float = 0.2
    rmsprop_beta: float = 0.9
    eps: float = 1e-8
    # ------------------------------------------------------------ mesh knobs
    arch: str = "yi_9b"
    reduced: bool = True
    model_overrides: Tuple = ()    # (("n_layers", 2), ...) applied to the cfg
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    schedule: str = "constant"     # constant | wsd | cosine
    warmup: int = 10
    mesh: str = "local"            # local | host | prod | prod-multipod
    workers: int = 0               # paper's c; 0 -> data shards of the mesh
    micro: int = 1                 # gradient-accumulation microbatches
    staleness: int = 0             # asgd: w_stale refresh period (0 -> rho)
    dc_lambda: float = 0.04
    correction_scale: float = 1.0
    magnitude_weight: float = 0.1

    def __post_init__(self):
        assert self.backend in BACKENDS, self.backend
        assert self.mode in MODES, self.mode

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ conversions
    @property
    def guided(self) -> bool:
        return self.strategy in _GUIDED_STRATEGIES

    def to_ps_config(self) -> PSConfig:
        """Lower to the numpy simulator's config. Any guided_* strategy maps to
        the paper's literal replay (the sim has exactly one guided path);
        staleness-Taylor strategies have no sim equivalent."""
        if self.strategy not in ("none", "guided_fused", "guided_two_pass"):
            raise ValueError(
                f"strategy {self.strategy!r} has no parameter-server simulation; "
                "use backend='mesh'"
            )
        return PSConfig(
            mode=self.mode,
            guided=self.guided,
            optimizer=self.optimizer,
            lr=self.lr,
            epochs=self.epochs,
            rho=self.rho,
            batch_size=self.batch_size,
            max_consistent=self.max_consistent,
            verification_frac=self.verification_frac,
            rmsprop_beta=self.rmsprop_beta,
            eps=self.eps,
            seed=self.seed,
        )

    @classmethod
    def from_ps_config(cls, cfg: PSConfig, **kw) -> "ExperimentSpec":
        return cls(
            backend="sim",
            mode=cfg.mode,
            strategy="guided_fused" if cfg.guided else "none",
            optimizer=cfg.optimizer,
            lr=cfg.lr,
            epochs=cfg.epochs,
            rho=cfg.rho,
            batch_size=cfg.batch_size,
            max_consistent=cfg.max_consistent,
            verification_frac=cfg.verification_frac,
            rmsprop_beta=cfg.rmsprop_beta,
            eps=cfg.eps,
            seed=cfg.seed,
            **kw,
        )

    def to_guided_config(self) -> "GuidedConfig":
        """Lower to the mesh trainer's config. strategy="dc_asgd" keeps the
        legacy mode="dc_asgd" spelling so `needs_stale`/compensation semantics
        are bit-identical to the pre-engine step."""
        from repro.core.guided import GuidedConfig

        return GuidedConfig(
            mode="dc_asgd" if self.strategy in _DC_STRATEGIES else self.mode,
            guided=self.guided,
            rho=self.rho,
            max_consistent=self.max_consistent,
            staleness=self.staleness,
            dc_lambda=self.dc_lambda,
            correction="two_pass" if self.strategy == "guided_two_pass" else "fused",
            correction_scale=self.correction_scale,
            magnitude_weight=self.magnitude_weight,
        )

    @classmethod
    def from_guided_config(cls, gcfg: "GuidedConfig", **kw) -> "ExperimentSpec":
        from repro.engine.strategies import strategy_name_for

        return cls(
            backend="mesh",
            mode="asgd" if gcfg.mode == "dc_asgd" else gcfg.mode,
            strategy=strategy_name_for(gcfg),
            rho=gcfg.rho,
            max_consistent=gcfg.max_consistent,
            staleness=gcfg.staleness,
            dc_lambda=gcfg.dc_lambda,
            correction_scale=gcfg.correction_scale,
            magnitude_weight=gcfg.magnitude_weight,
            **kw,
        )

    @classmethod
    def for_algo(cls, name: str, **kw) -> "ExperimentSpec":
        """Spec for a paper-table algorithm name ('gSSGD', 'SRMSprop', ...).
        Defaults to the sim backend (the paper's own scale) except for
        strategies with no sim equivalent (DC-ASGD); pass backend explicitly
        for the other analog."""
        try:
            mode, strategy, optimizer = ALGOS[name]
        except KeyError:
            raise KeyError(f"unknown algorithm {name!r}; known: {', '.join(ALGOS)}") from None
        sim_ok = strategy in ("none", "guided_fused", "guided_two_pass")
        kw.setdefault("backend", "sim" if sim_ok else "mesh")
        return cls(mode=mode, strategy=strategy, optimizer=optimizer, **kw)

    def model_config(self):
        """Resolve arch + reduced + overrides to a ModelConfig (mesh backend)."""
        from repro.configs import get_config

        cfg = get_config(self.arch)
        if self.reduced:
            cfg = cfg.reduced()
        if self.model_overrides:
            cfg = cfg.replace(**dict(self.model_overrides))
        return cfg
