"""`ExperimentSpec` — one declarative config over all three backends.

The paper's algorithm family runs at three scales:

  * backend="sim" — the numpy event-driven parameter-server reference
    (`PSConfig` + `train_ps`; paper-faithful, Tables 2-5 / Figs. 2-14);
  * backend="scan" — the jitted lax.scan delay simulator
    (`repro.engine.delaysim`): same trajectories as the sim to float64
    round-off, vmapped over `n_seeds`, delay topologies via `topology`
    (DESIGN.md §6);
  * backend="mesh" — the jitted SPMD mesh trainer
    (`GuidedConfig` + the strategy-hooked step; transformer scale).

An ExperimentSpec names ONE experiment — backend, execution mode, compensation
strategy, optimizer, schedule, mesh, workers, micro-batching — and lowers to
whichever legacy config its backend needs (`to_ps_config` / `to_guided_config`
/ `to_schedule_config`). Strategy/mode/topology compatibility is validated at
construction with pure-python rules (no jax import), so bad combinations fail
fast with the registry's message. `Trainer.from_spec(spec).fit(data)` is the
single entry point; see DESIGN.md §1 for the API and §2 for the old-API →
new-API migration table.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Tuple

from repro.core.parameter_server import PSConfig

if TYPE_CHECKING:  # GuidedConfig lives in the jax stack; import it lazily so
    from repro.core.guided import GuidedConfig  # sim-only scripts stay numpy-light

BACKENDS = ("mesh", "sim", "scan", "dist")
MODES = ("seq", "ssgd", "asgd")

# every optimizer the repo implements (repro.optim.optimizers registry)
OPTIMIZERS = ("sgd", "momentum", "rmsprop", "adagrad", "adam")
# the numpy parameter-server reference (_Server._apply) and the dist chief's
# numpy apply rule only implement these; mesh/scan run all of OPTIMIZERS
# (momentum/adam via the fused whole-update kernels, DESIGN.md §11)
SIM_OPTIMIZERS = ("sgd", "rmsprop", "adagrad")

# dist-backend execution disciplines (repro.dist, DESIGN.md §10):
#   replay — real worker processes, scheduled interleaving: the chief grants
#            pulls/pushes against the extracted DelaySchedule, so the run is
#            deterministic and parity-checkable against backend="scan".
#   live   — free-running asynchrony: staleness is observed, not scripted;
#            the fault-injection knobs (events, drop rate, slowdowns) and
#            DaSGD delayed averaging only exist here.
DIST_MODES = ("replay", "live")

# fault-injection event verbs: ("kill", wid, at_version) terminates worker
# wid's process once the store reaches at_version; "restart" kills AND
# respawns it; "join" spawns an additional elastic worker (wid ignored).
DIST_EVENT_OPS = ("kill", "restart", "join")

# divergence-sentinel screening levels (repro.resilience, DESIGN.md §14):
#   ""       — off (the default; zero overhead, bit-exact legacy trajectories)
#   "finite" — reject non-finite losses/gradients (NaN/Inf never reach W)
#   "full"   — "finite" plus loss-spike screening on the mesh carry and a
#              norm-explosion screen (vs a running norm EMA) on the chief
SENTINELS = ("", "finite", "full")

# mesh-backend lr schedules; kept as a pure-python tuple (the resolver lives
# in repro.optim.schedules.for_run, which imports jax) so the spec and the
# launcher's argparse choices validate without the jax import cost.
SCHEDULES = ("constant", "wsd", "cosine")

# Delay topologies of the scan backend (repro.engine.delaysim registers the
# matching schedule generators): name -> execution modes it is defined for.
# seq/barrier are the deterministic topologies implied by those modes; the
# event-queue ones need mode="asgd" (heterogeneous per-arrival staleness).
TOPOLOGIES = {
    "seq": ("seq",),
    "barrier": ("ssgd",),
    "exp": ("asgd",),          # train_ps's literal exponential compute times
    "constant": ("asgd",),     # fixed compute time -> round-robin, s = c-1
    "heavy_tail": ("asgd",),   # Pareto compute times (rare huge delays)
    "straggler": ("asgd",),    # one worker 10x slower than the rest
    "hetero": ("asgd",),       # per-worker mean compute time grows with rank
}

_DEFAULT_TOPOLOGY = {"seq": "seq", "ssgd": "barrier", "asgd": "exp"}

# algorithm names as printed in the paper's tables -> (mode, strategy, optimizer)
ALGOS = {
    "SGD": ("seq", "none", "sgd"),
    "gSGD": ("seq", "guided_fused", "sgd"),
    "SSGD": ("ssgd", "none", "sgd"),
    "gSSGD": ("ssgd", "guided_fused", "sgd"),
    "ASGD": ("asgd", "none", "sgd"),
    "gASGD": ("asgd", "guided_fused", "sgd"),
    "SRMSprop": ("ssgd", "none", "rmsprop"),
    "gSRMSprop": ("ssgd", "guided_fused", "rmsprop"),
    "SAdagrad": ("ssgd", "none", "adagrad"),
    "gSAdagrad": ("ssgd", "guided_fused", "adagrad"),
    "DC-ASGD": ("asgd", "dc_asgd", "sgd"),
}

_GUIDED_STRATEGIES = ("guided_fused", "guided_two_pass", "dc_asgd_guided")
_DC_STRATEGIES = ("dc_asgd", "dc_asgd_guided")

# Strategies that compensate against w_stale and therefore only make sense
# under asgd execution. Kept as a pure-python table (no jax import) so
# ExperimentSpec can fail fast at construction; the registry classes raise
# the same message (via needs_stale_message) when driven directly.
_STALE_REQUIRED = {
    "dc_asgd": "compensates with the Taylor term g*g*(W - w_stale)",
    "dc_asgd_guided": "compensates with the Taylor term g*g*(W - w_stale)",
    "gap_aware": "dampens by |W - w_stale|",
}


def needs_stale_message(strategy: str, why: str, mode: str) -> str:
    """The one error message for strategy/mode incompatibility — shared by
    ExperimentSpec.__post_init__ and the DelayCompensator registry classes."""
    return (f"{strategy} {why} and needs stale weights: "
            f"use mode='asgd' (got mode={mode!r})")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment of the paper's algorithm family, on either backend.

    backend="sim" runs the literal numpy parameter-server simulation;
    backend="mesh" runs the jitted SPMD data-parallel trainer. The shared
    fields mean the same thing on both; backend-specific fields are ignored
    by the other backend.
    """

    backend: str = "mesh"          # mesh | sim | scan
    # ------------------------------------------------- shared algorithm knobs
    mode: str = "ssgd"             # seq | ssgd | asgd (execution/delay model)
    strategy: str = "none"         # DelayCompensator registry name
    rho: int = 10                  # delay tolerance / correction period
    max_consistent: int = 4        # paper: replay at most 4 mini-batches
    optimizer: str = "sgd"
    lr: float = 0.2                # paper Table 1 default
    seed: int = 0
    # ------------------------------------------------------ sim / scan knobs
    epochs: int = 50
    batch_size: int = 16
    verification_frac: float = 0.2
    rmsprop_beta: float = 0.9
    eps: float = 1e-8
    topology: str = ""             # scan/dist: TOPOLOGIES key ("" -> mode default)
    n_seeds: int = 1               # scan: vmap-sweep seed..seed+n_seeds-1
    # ------------------------------------------------------------ dist knobs
    dist_mode: str = "replay"      # replay | live (DIST_MODES)
    delayed_avg: bool = False      # live: DaSGD-style push/pull overlap + merge
    dist_drop_rate: float = 0.0    # live: chief drops this fraction of pushes
    dist_time_scale: float = 0.0   # live: seconds per sampled compute-time unit
                                   # (0 -> workers never sleep; full speed)
    dist_events: Tuple = ()        # live: ((op, wid, at_version), ...) faults
    dist_timeout: float = 120.0    # watchdog: max seconds without progress
    # ------------------------------------------------------------ mesh knobs
    arch: str = "yi_9b"
    reduced: bool = True
    model_overrides: Tuple = ()    # (("n_layers", 2), ...) applied to the cfg
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    schedule: str = "constant"     # constant | wsd | cosine
    warmup: int = 10
    mesh: str = "local"            # local | host | prod | prod-multipod
    workers: int = 0               # paper's c; 0 -> data shards of the mesh
                                   # (dist: worker PROCESSES; 0 -> schedule's c)
    micro: int = 1                 # gradient-accumulation microbatches
    staleness: int = 0             # asgd: w_stale refresh period (0 -> rho)
    chunk_steps: int = 1           # fuse K steps into one lax.scan dispatch
                                   # (1 -> the literal per-step legacy loop)
    prefetch: bool = False         # async double-buffered batch staging
    dc_lambda: float = 0.04
    correction_scale: float = 1.0
    magnitude_weight: float = 0.1
    # -------------------------------------------- checkpointing (mesh backend)
    ckpt_dir: str = ""             # "" -> checkpointing off
    ckpt_every: int = 0            # periodic full-state snapshot cadence (steps)
    keep_last: int = 3             # manifest retention (0 -> keep everything)
    # ------------------------------------ resilience (repro.resilience, §14)
    sentinel: str = ""             # SENTINELS level: "" | finite | full
    sentinel_factor: float = 10.0  # spike/norm explosion multiplier vs the
                                   # previous val loss (mesh) / norm EMA (dist)
    rollback: bool = False         # dist live: on post-apply divergence,
                                   # restore the last VERIFIED snapshot + lr
                                   # backoff instead of failing the run
    max_rollbacks: int = 3         # rollback budget before the run is fatal
    lr_backoff: float = 0.5        # lr scale multiplied in at every rollback
    quarantine_steps: int = 0      # dist live: versions a misbehaving worker's
                                   # pushes are ignored for (0 -> never)
    quarantine_after: int = 3      # consecutive rejections that trigger it
    dist_supervise: bool = True    # live: supervisor thread respawns dead
                                   # worker processes (capped backoff+jitter);
                                   # ignored by replay (death is fatal there)
    dist_lease_s: float = 0.0      # heartbeat lease: a worker silent this long
                                   # is presumed hung and killed/respawned
                                   # (0 -> process-death detection only)
    dist_max_respawns: int = 3     # per-worker respawn budget before eviction

    def __post_init__(self):
        assert self.backend in BACKENDS, self.backend
        assert self.mode in MODES, self.mode
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; known: {', '.join(SCHEDULES)}")
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(
                f"unknown optimizer {self.optimizer!r}; known: {', '.join(OPTIMIZERS)}")
        if self.backend in ("sim", "dist") and self.optimizer not in SIM_OPTIMIZERS:
            raise ValueError(
                f"optimizer {self.optimizer!r} has no numpy server apply rule "
                f"(backend={self.backend!r} supports {', '.join(SIM_OPTIMIZERS)}); "
                f"use backend='mesh' or backend='scan' for momentum/adam")
        if self.ckpt_every < 0 or self.keep_last < 0:
            raise ValueError(
                f"ckpt_every/keep_last must be >= 0 "
                f"(got {self.ckpt_every}/{self.keep_last})")
        if self.ckpt_every and not self.ckpt_dir:
            raise ValueError(
                f"ckpt_every={self.ckpt_every} needs ckpt_dir (where should "
                f"the snapshots go?)")
        if self.chunk_steps < 1:
            raise ValueError(
                f"chunk_steps must be >= 1 (got {self.chunk_steps}); 1 runs "
                f"the per-step loop, K > 1 fuses K steps per dispatch")
        # strategy/mode compatibility fails here, at construction, with the
        # registry's message — not deep inside jit or mid-fit.
        why = _STALE_REQUIRED.get(self.strategy)
        if why is not None and self.mode != "asgd":
            raise ValueError(needs_stale_message(self.strategy, why, self.mode))
        if self.n_seeds < 1:
            raise ValueError(f"n_seeds must be >= 1 (got {self.n_seeds})")
        if self.n_seeds > 1 and self.backend != "scan":
            raise ValueError(
                f"n_seeds={self.n_seeds} needs the vmapped scan backend; "
                f"backend={self.backend!r} runs one seed per fit"
            )
        if self.topology:
            if self.topology not in TOPOLOGIES:
                raise ValueError(
                    f"unknown topology {self.topology!r}; known: "
                    f"{', '.join(TOPOLOGIES)}"
                )
            if self.backend not in ("scan", "dist"):
                raise ValueError(
                    f"topology={self.topology!r} is a scan/dist-backend knob "
                    f"(backend={self.backend!r} hardcodes its delay model)"
                )
            if self.mode not in TOPOLOGIES[self.topology]:
                raise ValueError(
                    f"topology {self.topology!r} is defined for mode(s) "
                    f"{TOPOLOGIES[self.topology]}, got mode={self.mode!r}"
                )
        # ---- dist-backend rules: fail at construction, not mid-launch
        if self.dist_mode not in DIST_MODES:
            raise ValueError(
                f"unknown dist_mode {self.dist_mode!r}; known: {', '.join(DIST_MODES)}")
        faults = (self.delayed_avg or self.dist_drop_rate or self.dist_time_scale
                  or self.dist_events)
        if self.backend == "dist":
            if self.dist_mode == "live" and self.mode != "asgd":
                raise ValueError(
                    f"dist_mode='live' IS free-running asynchronous execution: "
                    f"use mode='asgd' (got mode={self.mode!r})")
            if faults and self.dist_mode != "live":
                raise ValueError(
                    "delayed_avg / dist_drop_rate / dist_time_scale / "
                    "dist_events need dist_mode='live' (replay is the "
                    "deterministic parity oracle — no faults there)")
            for ev in self.dist_events:
                if len(ev) != 3 or ev[0] not in DIST_EVENT_OPS:
                    raise ValueError(
                        f"bad dist event {ev!r}; want (op, wid, at_version) "
                        f"with op in {DIST_EVENT_OPS}")
            if not (0.0 <= self.dist_drop_rate < 1.0):
                raise ValueError(
                    f"dist_drop_rate must be in [0, 1) (got {self.dist_drop_rate})")
        elif faults:
            raise ValueError(
                "delayed_avg / dist_drop_rate / dist_time_scale / dist_events "
                f"are dist-backend knobs (backend={self.backend!r})")
        # ---- resilience rules (repro.resilience, DESIGN.md §14)
        if self.sentinel not in SENTINELS:
            raise ValueError(
                f"unknown sentinel {self.sentinel!r}; known: "
                f"{', '.join(repr(s) for s in SENTINELS)}")
        if self.sentinel_factor <= 1.0:
            raise ValueError(
                f"sentinel_factor must be > 1 (got {self.sentinel_factor}): "
                f"it multiplies the previous loss / norm EMA into a threshold")
        if self.sentinel and self.backend not in ("mesh", "dist"):
            raise ValueError(
                f"sentinel={self.sentinel!r} screens the mesh carry or the "
                f"dist chief's push path (backend={self.backend!r} has "
                f"neither)")
        if self.sentinel and self.backend == "dist" and self.dist_mode != "live":
            raise ValueError(
                "sentinel screening on the dist backend needs "
                "dist_mode='live' (replay is the deterministic parity "
                "oracle — rejecting pushes would break the schedule)")
        remediation = self.rollback or self.quarantine_steps
        if remediation and not (self.backend == "dist"
                                and self.dist_mode == "live"):
            raise ValueError(
                "rollback / quarantine_steps remediate the live chief's "
                f"store (backend={self.backend!r}, "
                f"dist_mode={self.dist_mode!r})")
        if remediation and not self.sentinel:
            raise ValueError(
                "rollback / quarantine_steps need a sentinel level to "
                "detect divergence first (set sentinel='finite' or 'full')")
        if self.max_rollbacks < 0 or self.quarantine_steps < 0:
            raise ValueError(
                f"max_rollbacks/quarantine_steps must be >= 0 "
                f"(got {self.max_rollbacks}/{self.quarantine_steps})")
        if not 0.0 < self.lr_backoff <= 1.0:
            raise ValueError(
                f"lr_backoff must be in (0, 1] (got {self.lr_backoff})")
        if self.quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1 (got {self.quarantine_after})")
        if self.dist_lease_s < 0 or self.dist_max_respawns < 0:
            raise ValueError(
                f"dist_lease_s/dist_max_respawns must be >= 0 "
                f"(got {self.dist_lease_s}/{self.dist_max_respawns})")

    @property
    def resolved_topology(self) -> str:
        """The schedule topology this spec runs (mode default when unset)."""
        return self.topology or _DEFAULT_TOPOLOGY[self.mode]

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ conversions
    @property
    def guided(self) -> bool:
        return self.strategy in _GUIDED_STRATEGIES

    def to_ps_config(self) -> PSConfig:
        """Lower to the numpy simulator's config. Any guided_* strategy maps to
        the paper's literal replay (the sim has exactly one guided path);
        staleness-Taylor strategies have no sim equivalent."""
        if self.strategy not in ("none", "guided_fused", "guided_two_pass"):
            raise ValueError(
                f"strategy {self.strategy!r} has no parameter-server simulation; "
                "use backend='mesh' or backend='scan'"
            )
        return self.to_schedule_config()

    def to_schedule_config(self, seed: int = None) -> PSConfig:
        """PSConfig view for the scan backend's data prep + schedule
        extraction (core.parameter_server.prepare_run). Unlike to_ps_config
        this does NOT restrict the strategy: on the scan path the strategy
        stays a live DelayCompensator driving the apply hooks, only the
        protocol knobs (mode, epochs, batching, rho, seed) are lowered.
        `seed` overrides spec.seed for the vmapped multi-seed sweep."""
        return PSConfig(
            mode=self.mode,
            guided=self.guided,
            optimizer=self.optimizer,
            lr=self.lr,
            epochs=self.epochs,
            rho=self.rho,
            batch_size=self.batch_size,
            max_consistent=self.max_consistent,
            verification_frac=self.verification_frac,
            rmsprop_beta=self.rmsprop_beta,
            eps=self.eps,
            seed=self.seed if seed is None else seed,
        )

    @classmethod
    def from_ps_config(cls, cfg: PSConfig, **kw) -> "ExperimentSpec":
        return cls(
            backend="sim",
            mode=cfg.mode,
            strategy="guided_fused" if cfg.guided else "none",
            optimizer=cfg.optimizer,
            lr=cfg.lr,
            epochs=cfg.epochs,
            rho=cfg.rho,
            batch_size=cfg.batch_size,
            max_consistent=cfg.max_consistent,
            verification_frac=cfg.verification_frac,
            rmsprop_beta=cfg.rmsprop_beta,
            eps=cfg.eps,
            seed=cfg.seed,
            **kw,
        )

    def to_guided_config(self) -> "GuidedConfig":
        """Lower to the mesh trainer's config. strategy="dc_asgd" keeps the
        legacy mode="dc_asgd" spelling so `needs_stale`/compensation semantics
        are bit-identical to the pre-engine step."""
        from repro.core.guided import GuidedConfig

        return GuidedConfig(
            mode="dc_asgd" if self.strategy in _DC_STRATEGIES else self.mode,
            guided=self.guided,
            rho=self.rho,
            max_consistent=self.max_consistent,
            staleness=self.staleness,
            dc_lambda=self.dc_lambda,
            correction="two_pass" if self.strategy == "guided_two_pass" else "fused",
            correction_scale=self.correction_scale,
            magnitude_weight=self.magnitude_weight,
        )

    @classmethod
    def from_guided_config(cls, gcfg: "GuidedConfig", **kw) -> "ExperimentSpec":
        from repro.engine.strategies import strategy_name_for

        return cls(
            backend="mesh",
            mode="asgd" if gcfg.mode == "dc_asgd" else gcfg.mode,
            strategy=strategy_name_for(gcfg),
            rho=gcfg.rho,
            max_consistent=gcfg.max_consistent,
            staleness=gcfg.staleness,
            dc_lambda=gcfg.dc_lambda,
            correction_scale=gcfg.correction_scale,
            magnitude_weight=gcfg.magnitude_weight,
            **kw,
        )

    @classmethod
    def for_algo(cls, name: str, **kw) -> "ExperimentSpec":
        """Spec for a paper-table algorithm name ('gSSGD', 'SRMSprop', ...).
        Defaults to the sim backend (the paper's own scale) except for
        strategies with no sim equivalent (DC-ASGD); pass backend explicitly
        for the other analog."""
        try:
            mode, strategy, optimizer = ALGOS[name]
        except KeyError:
            raise KeyError(f"unknown algorithm {name!r}; known: {', '.join(ALGOS)}") from None
        sim_ok = strategy in ("none", "guided_fused", "guided_two_pass")
        kw.setdefault("backend", "sim" if sim_ok else "mesh")
        return cls(mode=mode, strategy=strategy, optimizer=optimizer, **kw)

    def model_config(self):
        """Resolve arch + reduced + overrides to a ModelConfig (mesh backend)."""
        from repro.configs import get_config

        cfg = get_config(self.arch)
        if self.reduced:
            cfg = cfg.reduced()
        if self.model_overrides:
            cfg = cfg.replace(**dict(self.model_overrides))
        return cfg
