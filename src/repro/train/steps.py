"""jit-able train / prefill / decode steps with guided delay compensation.

The train-step implementation now lives in `repro.engine.mesh`, driven by the
pluggable `DelayCompensator` strategies of `repro.engine.strategies`
(DESIGN.md §2-3). `build_train_step` / `make_train_state` here are kept as
thin deprecated shims over that engine — new code should go through
`repro.engine.Trainer` / `repro.engine.build_train_step` directly. The
serve-side prefill/decode step builders and the sharding-tree helpers remain
canonical in this module.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guided as G
from repro.models import transformer as T
from repro.optim import Optimizer
from repro.sharding.rules import ShardCtx, logical_to_spec


class TrainFns(NamedTuple):
    train_step: Callable
    init_fn: Callable


# ------------------------------------------------------------- shardings


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def param_shardings(cfg, ctx: ShardCtx, logical):
    from jax.sharding import NamedSharding

    def one(log, leaf):
        return NamedSharding(ctx.mesh, logical_to_spec(log, ctx.rules, ctx.mesh, leaf.shape))

    return (lambda value_struct: jax.tree.map(one, logical, value_struct, is_leaf=_is_logical))


def batch_shardings(cfg, ctx: ShardCtx, batch_struct):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(leaf):
        spec = [None] * leaf.ndim
        axes = [a for a in ctx.rules.get("batch") if a in ctx.mesh.shape]
        if leaf.shape[0] % max(1, _prod(ctx.mesh.shape[a] for a in axes)) == 0 and axes:
            spec[0] = tuple(axes) if len(axes) > 1 else axes[0]
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree.map(one, batch_struct)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def state_shardings(gcfg: G.GuidedConfig, opt: Optimizer, p_shardings, mesh,
                    extra_shardings=()):
    """GuidedState sharding tree mirroring guided_init's structure.
    `extra_shardings` must mirror the active strategy's init() output
    (the built-in strategies keep it empty; replicate scalars with P())."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    opt_map = {
        "sgd": (),
        "momentum": {"m": p_shardings},
        "rmsprop": {"r": p_shardings},
        "adagrad": {"r": p_shardings},
        "adam": {"m": p_shardings, "v": p_shardings, "t": repl},
    }
    return G.GuidedState(
        step=repl,
        score=repl,
        prev_worker_loss=repl,
        prev_avg_loss=repl,
        w_stale=p_shardings if gcfg.needs_stale else (),
        opt_state=opt_map[opt.name],
        extra=extra_shardings,
    )


def cache_shardings(cfg, ctx: ShardCtx, cache_struct):
    from jax.sharding import NamedSharding

    logical = T.cache_logical(cfg)
    # broadcast logical over the stacked (n_super,) leading dim already included
    def one(log, leaf):
        return NamedSharding(ctx.mesh, logical_to_spec(log, ctx.rules, ctx.mesh, leaf.shape))

    return jax.tree.map(one, logical, cache_struct,
                        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x))


# --------------------------------------------------------------- train step


def make_train_state(key, cfg, gcfg: G.GuidedConfig, opt: Optimizer, n_workers: int):
    """Deprecated shim over repro.engine.init_train_state (same signature)."""
    from repro.engine import mesh as _engine

    return _engine.init_train_state(key, cfg, gcfg, opt, n_workers)


def build_train_step(cfg, gcfg: G.GuidedConfig, opt: Optimizer, ctx: ShardCtx, lr_schedule,
                     n_micro: int = 1, n_workers: int = 0):
    """Deprecated shim over repro.engine.build_train_step: derives the
    DelayCompensator strategy the GuidedConfig flags imply and delegates.
    New code should use repro.engine.Trainer / repro.engine.build_train_step,
    which also accept a strategy by registry name or instance."""
    from repro.engine import mesh as _engine

    return _engine.build_train_step(cfg, gcfg, opt, ctx, lr_schedule,
                                    n_micro=n_micro, n_workers=n_workers)


# --------------------------------------------------------------- serve steps


def build_prefill_step(cfg, ctx: ShardCtx):
    """Batched prompt prefill; pass total_len/prompt_lens through T.prefill
    directly when serving variable-length prompts (repro.serve does)."""
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, ctx)

    return prefill_step


def build_decode_step(cfg, ctx: ShardCtx):
    """One decode step; `t` is a scalar shared position or a (B,) per-request
    position vector (continuous batching — see repro.serve, DESIGN.md §7)."""
    def decode_step(params, caches, tokens, t):
        return T.decode_step(params, caches, tokens, t, cfg, ctx)

    return decode_step
