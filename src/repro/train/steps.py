"""jit-able train / prefill / decode steps with guided delay compensation.

The train step is where the paper's technique meets the mesh:

  * per-worker losses E_i come free from the per-example loss vector (each data
    shard of the batch is one of the paper's c workers);
  * the guided correction enters the SAME backward pass as a consistency-
    weighted loss term (grad(sum w_i L_i) = sum w_i g_i) — zero extra
    collectives, zero stored gradients ("fused" mode, DESIGN.md §3);
  * "two_pass" mode reproduces the paper's literal second sequential update
    with a lax.cond'd second backward every rho steps;
  * ASGD staleness and DC-ASGD compensation are handled through gstate.w_stale.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common import tree_add
from repro.core import guided as G
from repro.models import transformer as T
from repro.models.module import split_params, value_tree
from repro.optim import Optimizer
from repro.sharding.rules import ShardCtx, logical_to_spec


class TrainFns(NamedTuple):
    train_step: Callable
    init_fn: Callable


# ------------------------------------------------------------- shardings


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def param_shardings(cfg, ctx: ShardCtx, logical):
    from jax.sharding import NamedSharding

    def one(log, leaf):
        return NamedSharding(ctx.mesh, logical_to_spec(log, ctx.rules, ctx.mesh, leaf.shape))

    return (lambda value_struct: jax.tree.map(one, logical, value_struct, is_leaf=_is_logical))


def batch_shardings(cfg, ctx: ShardCtx, batch_struct):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(leaf):
        spec = [None] * leaf.ndim
        axes = [a for a in ctx.rules.get("batch") if a in ctx.mesh.shape]
        if leaf.shape[0] % max(1, _prod(ctx.mesh.shape[a] for a in axes)) == 0 and axes:
            spec[0] = tuple(axes) if len(axes) > 1 else axes[0]
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree.map(one, batch_struct)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def state_shardings(gcfg: G.GuidedConfig, opt: Optimizer, p_shardings, mesh):
    """GuidedState sharding tree mirroring guided_init's structure."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    opt_map = {
        "sgd": (),
        "momentum": {"m": p_shardings},
        "rmsprop": {"r": p_shardings},
        "adagrad": {"r": p_shardings},
        "adam": {"m": p_shardings, "v": p_shardings, "t": repl},
    }
    return G.GuidedState(
        step=repl,
        score=repl,
        prev_worker_loss=repl,
        prev_avg_loss=repl,
        w_stale=p_shardings if gcfg.needs_stale else (),
        opt_state=opt_map[opt.name],
    )


def cache_shardings(cfg, ctx: ShardCtx, cache_struct):
    from jax.sharding import NamedSharding

    logical = T.cache_logical(cfg)
    # broadcast logical over the stacked (n_super,) leading dim already included
    def one(log, leaf):
        return NamedSharding(ctx.mesh, logical_to_spec(log, ctx.rules, ctx.mesh, leaf.shape))

    return jax.tree.map(one, logical, cache_struct,
                        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x))


# --------------------------------------------------------------- train step


def make_train_state(key, cfg, gcfg: G.GuidedConfig, opt: Optimizer, n_workers: int):
    boxed = T.model_init(key, cfg)
    params, logical = split_params(boxed)
    gstate = G.guided_init(gcfg, params, opt, n_workers)
    return params, logical, gstate


def _microbatches(batch, n_micro: int, c: int):
    """Split (B, ...) -> (n_micro, B/n_micro, ...) preserving the worker
    (data-shard) structure: every microbatch contains an equal slice of every
    worker's rows, so per-worker losses stay well-defined and no cross-shard
    traffic is introduced (the leading c-blocking is untouched per shard)."""

    def one(x):
        B = x.shape[0]
        b = B // c
        xr = x.reshape(c, n_micro, b // n_micro, *x.shape[1:])
        xr = jnp.moveaxis(xr, 1, 0)
        return xr.reshape(n_micro, B // n_micro, *x.shape[1:])

    return jax.tree.map(one, batch)


def build_train_step(cfg, gcfg: G.GuidedConfig, opt: Optimizer, ctx: ShardCtx, lr_schedule,
                     n_micro: int = 1, n_workers: int = 0):
    """Returns train_step(params, gstate, batch) -> (params, gstate, metrics).

    n_micro > 1 enables microbatched gradient accumulation: the remat-saved
    per-layer activation stack scales with the microbatch, which is what lets
    train_4k (global 256 x 4096) fit a 16 GiB chip at 9B-123B scale.
    n_workers overrides the paper's worker count c (defaults to the number of
    data shards; on a single device it emulates c workers by batch slicing)."""
    c = n_workers or max(ctx.n_workers, 1)

    def loss_fn(p, batch, corr_w):
        per_ex, aux, _ = T.forward_train(p, batch, cfg, ctx)
        B = per_ex.shape[0]
        E_i = per_ex.reshape(c, B // c).mean(axis=1)
        mean_loss = E_i.mean()
        total = mean_loss + aux + (jax.lax.stop_gradient(corr_w) * E_i).sum() * gcfg.correction_scale
        return total, (E_i, mean_loss)

    def grads_and_losses(grad_at, batch, corr_w):
        if n_micro == 1:
            (_, (E_i, mean_loss)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                grad_at, batch, corr_w
            )
            return grads, E_i, mean_loss

        mbs = _microbatches(batch, n_micro, c)

        def body(acc, mb):
            g_acc, e_acc, l_acc = acc
            (_, (E_i, ml)), g = jax.value_and_grad(loss_fn, has_aux=True)(grad_at, mb, corr_w)
            g_acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
            return (g_acc, e_acc + E_i, l_acc + ml), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), grad_at)
        (g_sum, e_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((c,), jnp.float32), jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g, p: (g / n_micro).astype(p.dtype), g_sum, grad_at)
        return grads, e_sum / n_micro, l_sum / n_micro

    def train_step(params, gstate: G.GuidedState, batch):
        # correction weights from scores accumulated over the window so far
        window_end = G.is_window_end(gstate.step, gcfg)
        corr_w = jnp.where(
            window_end & jnp.asarray(gcfg.guided and gcfg.correction == "fused"),
            G.correction_weights(gstate.score, gcfg),
            jnp.zeros((c,), jnp.float32),
        )

        grad_at = gstate.w_stale if gcfg.needs_stale else params
        grads, E_i, mean_loss = grads_and_losses(grad_at, batch, corr_w)
        if gcfg.mode == "dc_asgd":
            grads = G.compensate_dc_asgd(grads, params, gstate.w_stale, gcfg.dc_lambda)

        lr = lr_schedule(gstate.step)
        updates, opt_state = opt.update(grads, gstate.opt_state, params, lr * c if gcfg.mode != "seq" else lr)
        params = tree_add(params, updates)

        if gcfg.guided and gcfg.correction == "two_pass":
            # the paper's literal second sequential update at the moved iterate
            def replay(p):
                w = G.correction_weights(gstate.score, gcfg)
                # gradient of the weighted-consistent loss only (uniform term off)
                (_, _), g2 = jax.value_and_grad(
                    lambda q: (jax.lax.stop_gradient(0.0) + (w * T.forward_train(q, batch, cfg, ctx)[0].reshape(c, -1).mean(1)).sum(), 0.0),
                    has_aux=True,
                )(p)
                return jax.tree.map(lambda pi, gi: pi - lr * gi.astype(pi.dtype), p, g2)

            params = jax.lax.cond(window_end, replay, lambda p: p, params)

        gstate = G.advance(gstate, gcfg, opt_state, params, E_i, mean_loss)
        metrics = {
            "loss": mean_loss,
            "worker_loss_var": jnp.var(E_i),
            "corr_weight_sum": jnp.sum(corr_w),
            "lr": lr,
            "step": gstate.step,
        }
        return params, gstate, metrics

    return train_step


# --------------------------------------------------------------- serve steps


def build_prefill_step(cfg, ctx: ShardCtx):
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, ctx)

    return prefill_step


def build_decode_step(cfg, ctx: ShardCtx):
    def decode_step(params, caches, tokens, t):
        return T.decode_step(params, caches, tokens, t, cfg, ctx)

    return decode_step
