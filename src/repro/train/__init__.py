from repro.train.steps import (  # noqa: F401
    TrainFns,
    batch_shardings,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    make_train_state,
    state_shardings,
)
