"""Logical-axis sharding rules.

Every parameter / activation in the model stack is annotated with *logical* axis
names (strings). A rule table maps logical names to mesh axes. This is the single
point of control for the distribution strategy, and the knob the §Perf hillclimbs
turn (e.g. moving FSDP from `data` to `(pod, data)`, or turning FSDP off for
serving).

Logical axes used by the model stack:

  batch     activation batch dim                     -> data (+ pod)
  fsdp      weight "long" dim, gathered per-use      -> data (FSDP / ZeRO-3)
  tp        weight sharded dim kept sharded in use   -> model (tensor parallel)
  expert    MoE expert dim                           -> data when divisible
  seq_kv    decode-time KV-cache sequence dim        -> model (flash-decode shards)
  seq       training-time sequence dim               -> None (or model for CP)
  vocab     logits vocabulary dim                    -> model
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Sequence[Any]  # tuple of logical axis names (str | None), one per dim


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis -> tuple of mesh axes (or () for replicated)."""

    table: Mapping[str, tuple]

    def get(self, name) -> tuple:
        if name is None:
            return ()
        got = self.table.get(name, ())
        if got is None:
            return ()
        if isinstance(got, str):
            return (got,)
        return tuple(got)

    def replace(self, **kw) -> "AxisRules":
        t = dict(self.table)
        for k, v in kw.items():
            t[k] = v
        return AxisRules(t)


DEFAULT_RULES = AxisRules(
    {
        "batch": ("data",),
        "fsdp": ("data",),
        "tp": ("model",),
        "expert": ("data",),
        "seq_kv": ("model",),
        "seq": (),
        "vocab": ("model",),
    }
)

# Multi-pod: batch is data-parallel across pods as well; FSDP stays intra-pod
# (cross-pod weight gathers over DCI would dominate; see DESIGN.md §4).
MULTIPOD_RULES = DEFAULT_RULES.replace(batch=("pod", "data"))

# Serving variant for small models: keep weights tensor-sharded only (no FSDP
# all-gathers per token). §Perf iteration uses this.
SERVE_TP_ONLY_RULES = DEFAULT_RULES.replace(fsdp=(), expert=())
REPLICATED_RULES = AxisRules({})


def _mesh_axis_size(mesh: Mesh | None, axes: tuple) -> int:
    if mesh is None:
        return int(np.prod([1]))
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def logical_to_spec(
    logical: Logical,
    rules: AxisRules,
    mesh: Mesh | None = None,
    shape: Sequence[int] | None = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec.

    If `mesh` and `shape` are given, any dim whose size does not divide evenly by
    the product of its mesh axes is left replicated (e.g. grok's 8 experts on a
    16-way data axis). This keeps every (arch x mesh) combination lowerable
    without per-arch special cases.
    """
    spec = []
    used: set = set()
    for i, name in enumerate(logical):
        axes = tuple(a for a in rules.get(name) if mesh is None or a in mesh.shape)
        axes = tuple(a for a in axes if a not in used)
        if axes and mesh is not None and shape is not None:
            if shape[i] % _mesh_axis_size(mesh, axes) != 0:
                # try a prefix of the axes that still divides
                while axes and shape[i] % _mesh_axis_size(mesh, axes) != 0:
                    axes = axes[:-1]
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
            used.add(axes[0])
        else:
            spec.append(tuple(axes))
            used.update(axes)
    return P(*spec)


def shardings_for(logical_tree, value_tree, mesh: Mesh, rules: AxisRules):
    """NamedSharding tree from a logical-annotation tree mirroring value_tree."""

    def one(logical, val):
        return NamedSharding(mesh, logical_to_spec(logical, rules, mesh, val.shape))

    return jax.tree.map(one, logical_tree, value_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x))


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Runtime distribution context threaded through the model stack.

    mesh=None means single-device execution (unit tests / smoke tests): all
    shard_map wrappers degrade to plain function calls.
    """

    mesh: Mesh | None = None
    rules: AxisRules = DEFAULT_RULES
    # names of the mesh axes playing each role (for collectives inside shard_map)
    data_axes: tuple = ("data",)
    model_axis: str = "model"
    # MoE distributed dispatch: "gather" (baseline) | "alltoall" (GShard EP)
    moe_impl: str = "gather"

    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    @property
    def n_workers(self) -> int:
        """Number of data-parallel workers (the paper's `c`)."""
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes if a in self.mesh.shape]))

    def spec(self, *logical, shape=None) -> P:
        return logical_to_spec(logical, self.rules, self.mesh, shape)

    def sharding(self, *logical, shape=None):
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(*logical, shape=shape))


LOCAL_CTX = ShardCtx()
