from repro.sharding.rules import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    REPLICATED_RULES,
    ShardCtx,
    logical_to_spec,
    shardings_for,
)
