"""Pure-jnp oracle for flash_decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def decode_ref(q, k_cache, v_cache, cache_len):
    """q: (B,1,H,dh); caches: (B,S,K,dh); cache_len: (B,) -> (B,1,H,dh)."""
    B, _, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q[:, 0].reshape(B, K, G, dh).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)) / np.sqrt(dh)
    valid = jnp.arange(S)[None] < jnp.minimum(cache_len, S)[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh)
