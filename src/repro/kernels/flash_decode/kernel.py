"""Flash-decode: one-token attention against a (ring-buffer) KV cache.

Grid: (B, H, n_kv_blocks) with the kv dim sequential; (num, den, m) output
blocks for a given (b, h) are revisited across kv iterations. The validity
mask handles both partially-filled caches (slot < cache_len) and ring-buffer
caches (all slots valid once cache_len >= S_c). This kernel is the per-shard
body of the sequence-sharded distributed decode (models.transformer.
sharded_decode_attention) on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, num_ref, den_ref, m_ref, *, scale, bk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0, 0].astype(jnp.float32)       # (dh,)
    k = k_ref[0, 0].astype(jnp.float32)       # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)       # (bk, dh)
    valid_len = len_ref[0]                     # scalar int32 for this batch row

    s = k @ q * scale                          # (bk,)
    slots = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    s = jnp.where(slots < valid_len, s, NEG_INF)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(slots < valid_len, jnp.exp(s - m_new), 0.0)  # (bk,)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    num_ref[0, 0, :] = alpha * num_ref[0, 0] + p @ v
    den_ref[0, 0] = alpha * den_ref[0, 0] + jnp.sum(p)
    m_ref[0, 0] = m_new


def flash_decode_raw(q, k_cache, v_cache, cache_len, *, bk: int = 256, interpret: bool = True):
    """q: (B,1,H,dh); caches: (B,S,K,dh); cache_len: (B,) int32.
    Returns (num (B,H,dh), den (B,H)) un-normalized."""
    B, _, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    scale = 1.0 / np.sqrt(dh)

    qt = q[:, 0]                                   # (B,H,dh)
    kt = jnp.transpose(k_cache, (0, 2, 1, 3))      # (B,K,S,dh)
    vt = jnp.transpose(v_cache, (0, 2, 1, 3))

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    num, den, m = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, h)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, cache_len.astype(jnp.int32))
    return num, den
