"""jit'd public wrapper for the flash decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_decode.kernel import flash_decode_raw


@partial(jax.jit, static_argnames=("bk",))
def flash_decode(q, k_cache, v_cache, cache_len, *, bk: int = 256):
    """q: (B,1,H,dh); caches: (B,S,K,dh); cache_len (B,) -> (B,1,H,dh)."""
    S = k_cache.shape[1]
    cache_len = jnp.minimum(cache_len, S)  # ring-buffer: full cache once wrapped
    num, den = flash_decode_raw(q, k_cache, v_cache, cache_len, bk=min(bk, S),
                                interpret=default_interpret())
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)
