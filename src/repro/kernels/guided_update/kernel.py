"""Fused guided / delay-compensated weight update.

The paper's parameter-server hot loop at scale is a pure elementwise chain over
the full parameter state:

    g~ = g + lam * g*g*(W - W_stale)        (DC-ASGD compensation)
    W' = W - lr_eff * g~                     (server update, lr_eff = eta*c)

Unfused, XLA materializes g*g, (W - W_stale) and g~ in HBM: 6+ full-parameter
HBM round trips per step. This kernel does it in ONE read of (W, g, W_stale)
and one write of W' — strictly memory-bound, so fusing is a ~2x traffic win on
the update phase (see EXPERIMENTS.md §Perf). The rmsprop variant additionally
carries the r accumulator in the same pass (paper Fig. 11).

This is also the apply path of the scan delay-simulation backend
(repro.engine.delaysim): `interpret` autodetects from jax.default_backend()
(compiled on gpu/tpu, interpret on cpu), and the compute dtype follows the
weights (promote_types(w.dtype, float32)), so the float64 parity runs of the
scan backend reproduce the numpy reference loop exactly while bf16/f32 mesh
weights keep the f32 arithmetic the TPU path compiles to.

Tiling: flat 1-D blocks of 64k elements (512 KiB fp32) per grid step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret  # noqa: F401  (re-export: ops.py, delaysim)


def _compute_dtype(dtype):
    return jnp.promote_types(dtype, jnp.float32)


def _sgd_kernel(w_ref, g_ref, ws_ref, scal_ref, out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    out_ref[...] = (w - lr * gt).astype(out_ref.dtype)


def _rmsprop_kernel(w_ref, g_ref, ws_ref, r_ref, scal_ref, out_ref, r_out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    beta = scal_ref[2]
    eps = scal_ref[3]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    r = r_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    r_new = beta * r + (1.0 - beta) * gt * gt
    out_ref[...] = (w - lr * gt / jnp.sqrt(r_new + eps)).astype(out_ref.dtype)
    r_out_ref[...] = r_new


def guided_sgd_update_raw(w, g, w_stale, lr, lam, *, block: int = 65536,
                          interpret: bool = None):
    """Flat fused update for one parameter leaf. Returns new w."""
    if interpret is None:
        interpret = default_interpret()
    ct = _compute_dtype(w.dtype)
    scalars = jnp.stack([jnp.asarray(lr, ct), jnp.asarray(lam, ct)])
    n = w.size
    block = min(block, n)
    pad = (-n) % block
    wf = jnp.pad(w.reshape(-1), (0, pad))
    gf = jnp.pad(g.reshape(-1), (0, pad))
    wsf = jnp.pad(w_stale.reshape(-1), (0, pad))
    m = n + pad
    (out,) = pl.pallas_call(
        _sgd_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), w.dtype)],
        interpret=interpret,
    )(wf, gf, wsf, scalars)
    return out[:n].reshape(w.shape)


def guided_rmsprop_update_raw(w, g, w_stale, r, lr, lam, beta, eps, *, block: int = 65536,
                              interpret: bool = None):
    if interpret is None:
        interpret = default_interpret()
    ct = _compute_dtype(w.dtype)
    scalars = jnp.stack([
        jnp.asarray(lr, ct), jnp.asarray(lam, ct),
        jnp.asarray(beta, ct), jnp.asarray(eps, ct),
    ])
    n = w.size
    block = min(block, n)
    pad = (-n) % block
    pad_ = lambda a: jnp.pad(a.reshape(-1), (0, pad))
    m = n + pad
    out, r_new = pl.pallas_call(
        _rmsprop_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), w.dtype),
                   jax.ShapeDtypeStruct((m,), ct)],
        interpret=interpret,
    )(pad_(w), pad_(g), pad_(w_stale), pad_(r), scalars)
    return out[:n].reshape(w.shape), r_new[:n].reshape(w.shape)
