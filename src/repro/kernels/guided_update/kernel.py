"""Fused guided / delay-compensated weight update.

The paper's parameter-server hot loop at scale is a pure elementwise chain over
the full parameter state:

    g~ = g + lam * g*g*(W - W_stale)        (DC-ASGD compensation)
    W' = W - lr_eff * g~                     (server update, lr_eff = eta*c)

Unfused, XLA materializes g*g, (W - W_stale) and g~ in HBM: 6+ full-parameter
HBM round trips per step. This kernel does it in ONE read of (W, g, W_stale)
and one write of W' — strictly memory-bound, so fusing is a ~2x traffic win on
the update phase (see EXPERIMENTS.md §Perf). The rmsprop variant additionally
carries the r accumulator in the same pass (paper Fig. 11).

Tiling: flat 1-D blocks of 64k elements (512 KiB fp32) per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(w_ref, g_ref, ws_ref, scal_ref, out_ref):
    lr = scal_ref[0]
    lam = scal_ref[1]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ws = ws_ref[...].astype(jnp.float32)
    gt = g + lam * g * g * (w - ws)
    out_ref[...] = (w - lr * gt).astype(out_ref.dtype)


def _rmsprop_kernel(w_ref, g_ref, ws_ref, r_ref, scal_ref, out_ref, r_out_ref):
    lr = scal_ref[0]
    lam = scal_ref[1]
    beta = scal_ref[2]
    eps = scal_ref[3]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ws = ws_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    gt = g + lam * g * g * (w - ws)
    r_new = beta * r + (1.0 - beta) * gt * gt
    out_ref[...] = (w - lr * gt / jnp.sqrt(r_new + eps)).astype(out_ref.dtype)
    r_out_ref[...] = r_new


def _flat_call(kernel, n_out, arrs, scalars, block: int, out_dtypes):
    n = arrs[0].size
    block = min(block, n)
    pad = (-n) % block
    flat = [jnp.pad(a.reshape(-1), (0, pad)) for a in arrs]
    m = n + pad
    grid = (m // block,)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in flat]
        + [pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in range(n_out)],
        out_shape=[jax.ShapeDtypeStruct((m,), dt) for dt in out_dtypes],
        interpret=True,
    )(*flat, scalars)
    return [o[:n] for o in outs]


def guided_sgd_update_raw(w, g, w_stale, lr, lam, *, block: int = 65536, interpret: bool = True):
    """Flat fused update for one parameter leaf. Returns new w."""
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32), jnp.asarray(lam, jnp.float32)])
    n = w.size
    block = min(block, n)
    pad = (-n) % block
    wf = jnp.pad(w.reshape(-1), (0, pad))
    gf = jnp.pad(g.reshape(-1), (0, pad))
    wsf = jnp.pad(w_stale.reshape(-1), (0, pad))
    m = n + pad
    (out,) = pl.pallas_call(
        _sgd_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), w.dtype)],
        interpret=interpret,
    )(wf, gf, wsf, scalars)
    return out[:n].reshape(w.shape)


def guided_rmsprop_update_raw(w, g, w_stale, r, lr, lam, beta, eps, *, block: int = 65536,
                              interpret: bool = True):
    scalars = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(lam, jnp.float32),
        jnp.asarray(beta, jnp.float32), jnp.asarray(eps, jnp.float32),
    ])
    n = w.size
    block = min(block, n)
    pad = (-n) % block
    pad_ = lambda a: jnp.pad(a.reshape(-1), (0, pad))
    m = n + pad
    out, r_new = pl.pallas_call(
        _rmsprop_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), w.dtype),
                   jax.ShapeDtypeStruct((m,), jnp.float32)],
        interpret=interpret,
    )(pad_(w), pad_(g), pad_(w_stale), pad_(r), scalars)
    return out[:n].reshape(w.shape), r_new[:n].reshape(w.shape)
