"""Fused guided / delay-compensated weight update.

The paper's parameter-server hot loop at scale is a pure elementwise chain over
the full parameter state:

    g~ = g + lam * g*g*(W - W_stale)        (DC-ASGD compensation)
    W' = W - lr_eff * g~                     (server update, lr_eff = eta*c)

Unfused, XLA materializes g*g, (W - W_stale) and g~ in HBM: 6+ full-parameter
HBM round trips per step. This kernel does it in ONE read of (W, g, W_stale)
and one write of W' — strictly memory-bound, so fusing is a ~2x traffic win on
the update phase (see EXPERIMENTS.md §Perf). The rmsprop variant additionally
carries the r accumulator in the same pass (paper Fig. 11).

The optimizer-fused family extends the same chain through the accumulator
math, so momentum and adam also do gradient → compensate → accumulator →
weight in one pass instead of round-tripping m/v through HBM as separate XLA
ops:

    guided_momentum_update_raw : m' = beta*m + g~ ; W' = W - lr*m'
                                 (nesterov: W' = W - lr*(beta*m' + g~))
    guided_adam_update_raw     : m' = b1*m + (1-b1)*g~ ; v' = b2*v + (1-b2)*g~^2
                                 W' = W - lr * (m'/bc1) / (sqrt(v'/bc2) + eps)

The accumulator recurrences mirror `repro.optim.optimizers` bit-for-bit at the
compute dtype (the (1-b) factors are pre-rounded from the python hypers exactly
as weak-typed promotion does in the reference; adam's bias corrections bc1/bc2
are computed OUTSIDE the kernel from the step counter with the reference's
exact expression and enter as scalars).

This is also the apply path of the scan delay-simulation backend
(repro.engine.delaysim): `interpret` autodetects from jax.default_backend()
(compiled on gpu/tpu, interpret on cpu), and the compute dtype follows the
weights (promote_types(w.dtype, float32)), so the float64 parity runs of the
scan backend reproduce the numpy reference loop exactly while bf16/f32 mesh
weights keep the f32 arithmetic the TPU path compiles to.

Tiling: flat 1-D blocks via `repro.kernels._flat_grid`. `block=None` (the
default) resolves through `repro.kernels.autotune.tuned_block` — a per
(kernel, dtype, backend+device) measured winner, falling back to 64k elements
(512 KiB fp32) where sweeping is meaningless. Resolution happens at trace
time, so the tuned block is a static of the enclosing jit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _flat_grid, default_interpret  # noqa: F401  (re-export: ops.py, delaysim)
from repro.kernels.autotune import tuned_block


def _compute_dtype(dtype):
    return jnp.promote_types(dtype, jnp.float32)


def _resolve(block, interpret, kernel_name, dtype):
    if interpret is None:
        interpret = default_interpret()
    if block is None:
        block = tuned_block(kernel_name, dtype)
    return block, interpret


def _launch(kernel_fn, flats, scalars, block, grid, out_dtypes, interpret):
    """One flat elementwise pallas_call: every array in/out tiled `(block,)`,
    the scalar pack riding along whole in ANY memory space."""
    m = flats[0].shape[0]
    bspec = lambda: pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kernel_fn,
        grid=(grid,),
        in_specs=[bspec() for _ in flats] + [pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[bspec() for _ in out_dtypes],
        out_shape=[jax.ShapeDtypeStruct((m,), d) for d in out_dtypes],
        interpret=interpret,
    )(*flats, scalars)


def _sgd_kernel(w_ref, g_ref, ws_ref, scal_ref, out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    out_ref[...] = (w - lr * gt).astype(out_ref.dtype)


def _momentum_kernel(nesterov, w_ref, g_ref, ws_ref, m_ref, scal_ref, out_ref,
                     m_out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    beta = scal_ref[2]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    m = m_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    m_new = beta * m + gt
    if nesterov:
        upd = -(lr * (beta * m_new + gt))
    else:
        upd = -lr * m_new
    out_ref[...] = (w + upd).astype(out_ref.dtype)
    m_out_ref[...] = m_new


def _rmsprop_kernel(w_ref, g_ref, ws_ref, r_ref, scal_ref, out_ref, r_out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    beta = scal_ref[2]
    eps = scal_ref[3]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    r = r_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    r_new = beta * r + (1.0 - beta) * gt * gt
    out_ref[...] = (w - lr * gt / jnp.sqrt(r_new + eps)).astype(out_ref.dtype)
    r_out_ref[...] = r_new


def _adam_kernel(w_ref, g_ref, ws_ref, m_ref, v_ref, scal_ref, out_ref,
                 m_out_ref, v_out_ref):
    ct = _compute_dtype(w_ref.dtype)
    lr = scal_ref[0]
    lam = scal_ref[1]
    b1 = scal_ref[2]
    omb1 = scal_ref[3]
    b2 = scal_ref[4]
    omb2 = scal_ref[5]
    bc1 = scal_ref[6]
    bc2 = scal_ref[7]
    eps = scal_ref[8]
    w = w_ref[...].astype(ct)
    g = g_ref[...].astype(ct)
    ws = ws_ref[...].astype(ct)
    m = m_ref[...].astype(ct)
    v = v_ref[...].astype(ct)
    gt = g + lam * g * g * (w - ws)
    m_new = b1 * m + omb1 * gt
    v_new = b2 * v + omb2 * (gt * gt)
    step = m_new / bc1 / (jnp.sqrt(v_new / bc2) + eps)
    out_ref[...] = (w - lr * step).astype(out_ref.dtype)
    m_out_ref[...] = m_new
    v_out_ref[...] = v_new


def guided_sgd_update_raw(w, g, w_stale, lr, lam, *, block: int = None,
                          interpret: bool = None):
    """Flat fused update for one parameter leaf. Returns new w."""
    block, interpret = _resolve(block, interpret, "guided_sgd_update", w.dtype)
    ct = _compute_dtype(w.dtype)
    scalars = jnp.stack([jnp.asarray(lr, ct), jnp.asarray(lam, ct)])
    flats, block, grid, n = _flat_grid(block, w, g, w_stale)
    (out,) = _launch(_sgd_kernel, flats, scalars, block, grid,
                     [w.dtype], interpret)
    return out[:n].reshape(w.shape)


def guided_momentum_update_raw(w, g, w_stale, m, lr, lam, beta, *,
                               nesterov: bool = False, block: int = None,
                               interpret: bool = None):
    """Fused compensate + momentum accumulate + apply. Returns (new w, new m)."""
    block, interpret = _resolve(block, interpret, "guided_momentum_update",
                                w.dtype)
    ct = _compute_dtype(w.dtype)
    scalars = jnp.stack([
        jnp.asarray(lr, ct), jnp.asarray(lam, ct), jnp.asarray(beta, ct),
    ])
    flats, block, grid, n = _flat_grid(block, w, g, w_stale, m)
    out, m_new = _launch(partial(_momentum_kernel, nesterov), flats, scalars,
                         block, grid, [w.dtype, ct], interpret)
    return out[:n].reshape(w.shape), m_new[:n].reshape(w.shape)


def guided_rmsprop_update_raw(w, g, w_stale, r, lr, lam, beta, eps, *,
                              block: int = None, interpret: bool = None):
    block, interpret = _resolve(block, interpret, "guided_rmsprop_update",
                                w.dtype)
    ct = _compute_dtype(w.dtype)
    scalars = jnp.stack([
        jnp.asarray(lr, ct), jnp.asarray(lam, ct),
        jnp.asarray(beta, ct), jnp.asarray(eps, ct),
    ])
    flats, block, grid, n = _flat_grid(block, w, g, w_stale, r)
    out, r_new = _launch(_rmsprop_kernel, flats, scalars, block, grid,
                         [w.dtype, ct], interpret)
    return out[:n].reshape(w.shape), r_new[:n].reshape(w.shape)


def guided_adam_update_raw(w, g, w_stale, m, v, t, lr, lam, b1, b2, eps, *,
                           block: int = None, interpret: bool = None):
    """Fused compensate + adam moments + bias-corrected apply.

    `t` is the ALREADY-incremented step (the reference does `t = state+1`
    before the moment updates); `b1`/`b2` must be python floats so the
    pre-rounded (1-b) factors match the reference's weak-typed promotion.
    Returns (new w, new m, new v).
    """
    block, interpret = _resolve(block, interpret, "guided_adam_update", w.dtype)
    ct = _compute_dtype(w.dtype)
    tct = jnp.asarray(t).astype(ct)
    scalars = jnp.stack([
        jnp.asarray(lr, ct), jnp.asarray(lam, ct),
        jnp.asarray(b1, ct), jnp.asarray(1.0 - b1, ct),
        jnp.asarray(b2, ct), jnp.asarray(1.0 - b2, ct),
        1.0 - jnp.asarray(b1, ct) ** tct, 1.0 - jnp.asarray(b2, ct) ** tct,
        jnp.asarray(eps, ct),
    ])
    flats, block, grid, n = _flat_grid(block, w, g, w_stale, m, v)
    out, m_new, v_new = _launch(_adam_kernel, flats, scalars, block, grid,
                                [w.dtype, ct, ct], interpret)
    return (out[:n].reshape(w.shape), m_new[:n].reshape(w.shape),
            v_new[:n].reshape(w.shape))
