"""Pure-jnp oracle for the fused guided update family.

Each reference computes at the kernel's compute dtype (promote_types(w.dtype,
float32) — f32 for f32/bf16 weights, f64 for the scan backend's parity runs)
and mirrors `repro.optim.optimizers` update math bit-for-bit when lam == 0:
same op order, same weak-typed python-float hypers, accumulators returned at
the compute dtype. These double as the mesh trainer's fused-apply path on
interpret backends, where launching per-leaf emulated Pallas kernels would be
pure overhead (XLA fuses these chains into one loop anyway on CPU).
"""
from __future__ import annotations

import jax.numpy as jnp


def _ct(w):
    return jnp.promote_types(w.dtype, jnp.float32)


def guided_sgd_update_ref(w, g, w_stale, lr, lam):
    ct = _ct(w)
    wc, gc, wsc = (a.astype(ct) for a in (w, g, w_stale))
    gt = gc + lam * gc * gc * (wc - wsc)
    return (wc - lr * gt).astype(w.dtype)


def guided_momentum_update_ref(w, g, w_stale, m, lr, lam, beta, *,
                               nesterov: bool = False):
    ct = _ct(w)
    wc, gc, wsc, mc = (a.astype(ct) for a in (w, g, w_stale, m))
    gt = gc + lam * gc * gc * (wc - wsc)
    m_new = beta * mc + gt
    if nesterov:
        upd = -(lr * (beta * m_new + gt))
    else:
        upd = -lr * m_new
    return (wc + upd).astype(w.dtype), m_new


def guided_rmsprop_update_ref(w, g, w_stale, r, lr, lam, beta, eps):
    ct = _ct(w)
    wc, gc, wsc, rc = (a.astype(ct) for a in (w, g, w_stale, r))
    gt = gc + lam * gc * gc * (wc - wsc)
    r_new = beta * rc + (1 - beta) * gt * gt
    return (wc - lr * gt / jnp.sqrt(r_new + eps)).astype(w.dtype), r_new


def guided_adam_update_ref(w, g, w_stale, m, v, t, lr, lam, b1, b2, eps):
    """`t` is the already-incremented step, like the raw kernel."""
    ct = _ct(w)
    wc, gc, wsc, mc, vc = (a.astype(ct) for a in (w, g, w_stale, m, v))
    gt = gc + lam * gc * gc * (wc - wsc)
    m_new = b1 * mc + (1 - b1) * gt
    v_new = b2 * vc + (1 - b2) * jnp.square(gt)
    tct = jnp.asarray(t).astype(ct)
    bc1 = 1 - b1 ** tct
    bc2 = 1 - b2 ** tct
    step = m_new / bc1 / (jnp.sqrt(v_new / bc2) + eps)
    return (wc - lr * step).astype(w.dtype), m_new, v_new
