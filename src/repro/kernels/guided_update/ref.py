"""Pure-jnp oracle for the fused guided update."""
from __future__ import annotations

import jax.numpy as jnp


def guided_sgd_update_ref(w, g, w_stale, lr, lam):
    w32, g32, ws32 = (a.astype(jnp.float32) for a in (w, g, w_stale))
    gt = g32 + lam * g32 * g32 * (w32 - ws32)
    return (w32 - lr * gt).astype(w.dtype)


def guided_rmsprop_update_ref(w, g, w_stale, r, lr, lam, beta, eps):
    w32, g32, ws32, r32 = (a.astype(jnp.float32) for a in (w, g, w_stale, r))
    gt = g32 + lam * g32 * g32 * (w32 - ws32)
    r_new = beta * r32 + (1 - beta) * gt * gt
    return (w32 - lr * gt / jnp.sqrt(r_new + eps)).astype(w.dtype), r_new
