"""jit'd tree-level wrappers + the fused whole-update dispatch.

`fused_update_for(name)` is the seam the engine hot loops (mesh train step,
delaysim scan body) use to select ONE whole-update implementation per
optimizer: gradient → guided/DC compensation → accumulator recurrence →
weight apply, as a single dispatch. Hypers are baked as python floats at
selection time (trace statics), so the closure matches what
`repro.optim.optimizers` closures would compute bit-for-bit.

impl policy:
  * "kernel" — always the Pallas `*_raw` kernel (the scan backend: one tiny
    matrix, interpret on cpu is ~35us/step and preserves the committed f64
    parity trajectories);
  * "ref"    — always the pure-jnp reference;
  * "auto"   — kernel on kernel-capable backends (gpu/tpu), reference on
    interpret backends (the mesh trainer: per-leaf emulated Pallas calls on
    cpu would be ~70x overhead, while XLA fuses the jnp chain anyway).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.guided_update import ref as R
from repro.kernels.guided_update.kernel import (
    default_interpret,
    guided_adam_update_raw,
    guided_momentum_update_raw,
    guided_rmsprop_update_raw,
    guided_sgd_update_raw,
)

#: optimizers with a whole-update fused implementation (adagrad deliberately
#: not: the scan backend keeps its 3-op inline XLA form, and the mesh falls
#: back to the two-phase opt.update path)
FUSED_OPTIMIZERS = ("sgd", "momentum", "rmsprop", "adam")


@partial(jax.jit, static_argnames=("block",))
def guided_sgd_update(params, grads, w_stale, lr, lam=0.0, *, block: int = None):
    """Tree-level fused update: one kernel launch per leaf."""
    return jax.tree.map(
        lambda w, g, ws: guided_sgd_update_raw(w, g, ws, lr, lam, block=block,
                                               interpret=default_interpret()),
        params, grads, w_stale,
    )


def _unzip(out, i):
    return jax.tree.map(lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))


@partial(jax.jit, static_argnames=("block", "beta", "nesterov"))
def guided_momentum_update(params, grads, w_stale, m, lr, lam=0.0, *,
                           beta: float = 0.9, nesterov: bool = False,
                           block: int = None):
    out = jax.tree.map(
        lambda w, g, ws, mi: guided_momentum_update_raw(
            w, g, ws, mi, lr, lam, beta, nesterov=nesterov, block=block,
            interpret=default_interpret()),
        params, grads, w_stale, m,
    )
    return _unzip(out, 0), _unzip(out, 1)


@partial(jax.jit, static_argnames=("block",))
def guided_rmsprop_update(params, grads, w_stale, r, lr, lam=0.0, beta=0.9,
                          eps=1e-8, *, block: int = None):
    out = jax.tree.map(
        lambda w, g, ws, ri: guided_rmsprop_update_raw(
            w, g, ws, ri, lr, lam, beta, eps, block=block, interpret=default_interpret()),
        params, grads, w_stale, r,
    )
    return _unzip(out, 0), _unzip(out, 1)


@partial(jax.jit, static_argnames=("block", "b1", "b2", "eps"))
def guided_adam_update(params, grads, w_stale, m, v, t, lr, lam=0.0, *,
                       b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                       block: int = None):
    """`t` is the already-incremented step (see guided_adam_update_raw)."""
    out = jax.tree.map(
        lambda w, g, ws, mi, vi: guided_adam_update_raw(
            w, g, ws, mi, vi, t, lr, lam, b1, b2, eps, block=block,
            interpret=default_interpret()),
        params, grads, w_stale, m, v,
    )
    return _unzip(out, 0), _unzip(out, 1), _unzip(out, 2)


def fused_update_for(name: str, *, beta: float = 0.9, nesterov: bool = False,
                     b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                     impl: str = "auto"):
    """One whole-update callable for optimizer `name`, uniform signature:

        f(w, g, w_stale, acc, t, lr, lam, *, block=None, interpret=None)
            -> (new_w, new_acc)

    `acc` is the per-leaf accumulator tuple — () for sgd, (m,) for momentum,
    (r,) for rmsprop, (m, v) for adam — and `t` the already-incremented adam
    step (ignored by the others). Hypers must be python floats/bools (they are
    baked into the closure exactly as the `repro.optim.optimizers` closures
    bake them). Raises KeyError for optimizers with no fused form (adagrad).
    """
    if name not in FUSED_OPTIMIZERS:
        raise KeyError(
            f"no fused whole-update for optimizer {name!r}; "
            f"fused: {', '.join(FUSED_OPTIMIZERS)}")
    if impl not in ("auto", "kernel", "ref"):
        raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
    use_kernel = impl == "kernel" or (impl == "auto" and not default_interpret())

    if name == "sgd":
        if use_kernel:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                return (guided_sgd_update_raw(w, g, ws, lr, lam, block=block,
                                              interpret=interpret), acc)
        else:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                return R.guided_sgd_update_ref(w, g, ws, lr, lam), acc
    elif name == "momentum":
        if use_kernel:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, m2 = guided_momentum_update_raw(
                    w, g, ws, acc[0], lr, lam, beta, nesterov=nesterov,
                    block=block, interpret=interpret)
                return w2, (m2,)
        else:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, m2 = R.guided_momentum_update_ref(
                    w, g, ws, acc[0], lr, lam, beta, nesterov=nesterov)
                return w2, (m2,)
    elif name == "rmsprop":
        if use_kernel:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, r2 = guided_rmsprop_update_raw(
                    w, g, ws, acc[0], lr, lam, beta, eps, block=block,
                    interpret=interpret)
                return w2, (r2,)
        else:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, r2 = R.guided_rmsprop_update_ref(
                    w, g, ws, acc[0], lr, lam, beta, eps)
                return w2, (r2,)
    else:  # adam
        if use_kernel:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, m2, v2 = guided_adam_update_raw(
                    w, g, ws, acc[0], acc[1], t, lr, lam, b1, b2, eps,
                    block=block, interpret=interpret)
                return w2, (m2, v2)
        else:
            def f(w, g, ws, acc, t, lr, lam, *, block=None, interpret=None):
                w2, m2, v2 = R.guided_adam_update_ref(
                    w, g, ws, acc[0], acc[1], t, lr, lam, b1, b2, eps)
                return w2, (m2, v2)

    f.optimizer = name
    f.impl = "kernel" if use_kernel else "ref"
    return f


#: accumulator tuple arity per fused optimizer (what `acc` carries)
FUSED_ACC_ARITY = {"sgd": 0, "momentum": 1, "rmsprop": 1, "adam": 2}


def tree_fused_update(fused, name: str, params, grads, w_stale, opt_state,
                      lr, lam):
    """Apply a `fused_update_for` callable across a parameter pytree, mapping
    the optimizer's `repro.optim.optimizers` state layout to the per-leaf acc
    tuples and back. Returns (new_params, new_opt_state). Traced inside the
    caller's jit (the mesh train step)."""
    if name == "sgd":
        new_p = jax.tree.map(
            lambda w, g, ws: fused(w, g, ws, (), None, lr, lam)[0],
            params, grads, w_stale)
        return new_p, opt_state
    if name == "momentum":
        out = jax.tree.map(
            lambda w, g, ws, m: fused(w, g, ws, (m,), None, lr, lam),
            params, grads, w_stale, opt_state["m"])
        return _unzip(out, 0), {"m": jax.tree.map(
            lambda t: t[1][0], out, is_leaf=lambda x: isinstance(x, tuple))}
    if name == "rmsprop":
        out = jax.tree.map(
            lambda w, g, ws, r: fused(w, g, ws, (r,), None, lr, lam),
            params, grads, w_stale, opt_state["r"])
        return _unzip(out, 0), {"r": jax.tree.map(
            lambda t: t[1][0], out, is_leaf=lambda x: isinstance(x, tuple))}
    if name == "adam":
        t = opt_state["t"] + 1
        out = jax.tree.map(
            lambda w, g, ws, m, v: fused(w, g, ws, (m, v), t, lr, lam),
            params, grads, w_stale, opt_state["m"], opt_state["v"])
        tup = lambda x: isinstance(x, tuple)
        return _unzip(out, 0), {
            "m": jax.tree.map(lambda o: o[1][0], out, is_leaf=tup),
            "v": jax.tree.map(lambda o: o[1][1], out, is_leaf=tup),
            "t": t,
        }
    raise KeyError(name)
