"""jit'd tree-level wrapper for the fused guided update kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.guided_update.kernel import (
    default_interpret,
    guided_rmsprop_update_raw,
    guided_sgd_update_raw,
)


@partial(jax.jit, static_argnames=("block",))
def guided_sgd_update(params, grads, w_stale, lr, lam=0.0, *, block: int = 65536):
    """Tree-level fused update: one kernel launch per leaf."""
    return jax.tree.map(
        lambda w, g, ws: guided_sgd_update_raw(w, g, ws, lr, lam, block=block,
                                               interpret=default_interpret()),
        params, grads, w_stale,
    )


@partial(jax.jit, static_argnames=("block",))
def guided_rmsprop_update(params, grads, w_stale, r, lr, lam=0.0, beta=0.9,
                          eps=1e-8, *, block: int = 65536):
    out = jax.tree.map(
        lambda w, g, ws, ri: guided_rmsprop_update_raw(
            w, g, ws, ri, lr, lam, beta, eps, block=block, interpret=default_interpret()),
        params, grads, w_stale, r,
    )
    new_w = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_w, new_r
