"""Block-size autotuning for the flat elementwise Pallas kernels.

Every `*_raw` wrapper in `kernels/guided_update` tiles its arrays into flat
1-D blocks. The historical default (64k elements = 512 KiB fp32) is a good
middle of the road, but the sweet spot depends on the backend (VMEM budget on
TPU, occupancy on GPU) and the dtype (f64 doubles the footprint per element).
This module measures the candidate blocks once per (kernel, dtype) on the
current backend+device and persists the winner, so the `block=None` default of
every `*_raw` entry point resolves to the tuned value:

  * **Sweep on first use** — `tuned_block(kernel, dtype)` times each candidate
    in `CANDIDATES` on synthetic data (compiled, `block_until_ready`) and
    caches the fastest.
  * **Persistent JSON cache keyed by backend+device** — winners land in
    `<cache_dir>/<backend>-<device_kind>.json` (`REPRO_AUTOTUNE_CACHE`
    overrides the directory; CI caches it next to the XLA compilation cache),
    so repeat runs — and repeat *processes* — skip the sweep entirely.
  * **Interpret backends skip the sweep.** On CPU the kernels run in Pallas
    interpret mode (pure emulation, see `default_interpret`): its wall time
    says nothing about the compiled kernel, so the default block is returned
    unswept and nothing is persisted. `REPRO_AUTOTUNE=force` overrides (used
    to exercise the harness end-to-end); `REPRO_AUTOTUNE=0` disables sweeping
    everywhere.

Resolution is trace-time python (`tuned_block` returns a plain int), so the
tuned block is a static of whatever jit the caller is being traced under.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import time

#: candidate flat-block sizes (elements): 16k .. 256k
CANDIDATES = (16384, 32768, 65536, 131072, 262144)

#: the pre-autotune default (and the interpret-mode fallback)
DEFAULT_BLOCK = 65536

#: elements per timing probe — large enough that every candidate runs a
#: multi-step grid (1M = 4..64 grid steps across CANDIDATES)
_PROBE_N = 1 << 20
_PROBE_ITERS = 3

# process-level memo: (cache_path, key) -> block. Refilled from the JSON file
# on first miss, so tuned_block costs a dict hit on the hot path.
_MEMO: dict = {}


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune"),
    )


def _device_tag() -> str:
    import jax

    kind = "unknown"
    devs = jax.devices()
    if devs:
        kind = getattr(devs[0], "device_kind", "unknown") or "unknown"
    tag = f"{jax.default_backend()}-{kind}"
    return re.sub(r"[^A-Za-z0-9._-]+", "_", tag)


def cache_path(dirname: str = None) -> str:
    """The per-(backend, device-kind) winners file."""
    return os.path.join(dirname or cache_dir(), f"{_device_tag()}.json")


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store(path: str, data: dict) -> None:
    """Atomic JSON write (the dir is shared between concurrent runs)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def clear_memo() -> None:
    """Drop the in-process memo (tests: simulates a fresh process, forcing the
    next `tuned_block` to re-read the persisted JSON)."""
    _MEMO.clear()


def _default_measure(kernel: str, dtype, block: int) -> float:
    """Wall seconds per call of `kernel` at `block` on synthetic _PROBE_N-
    element data (compiled path; the first call pays the jit and is excluded)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.guided_update import kernel as K

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(_PROBE_N), dtype)
    g = w * 0.01
    ws = w + 0.05
    acc = jnp.abs(w) * 0.1

    runs = {
        "guided_sgd_update": lambda: K.guided_sgd_update_raw(
            w, g, ws, 0.1, 0.04, block=block),
        "guided_momentum_update": lambda: K.guided_momentum_update_raw(
            w, g, ws, acc, 0.1, 0.04, 0.9, block=block),
        "guided_rmsprop_update": lambda: K.guided_rmsprop_update_raw(
            w, g, ws, acc, 0.1, 0.04, 0.9, 1e-8, block=block),
        "guided_adam_update": lambda: K.guided_adam_update_raw(
            w, g, ws, acc, acc, 3, 0.1, 0.04, 0.9, 0.999, 1e-8, block=block),
    }
    try:
        fn = runs[kernel]
    except KeyError:
        raise KeyError(
            f"no autotune probe for kernel {kernel!r}; known: {', '.join(runs)}"
        ) from None
    jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(_PROBE_ITERS):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / _PROBE_ITERS


def _sweep_allowed() -> bool:
    mode = os.environ.get("REPRO_AUTOTUNE", "").lower()
    if mode in ("0", "off", "false"):
        return False
    if mode == "force":
        return True
    from repro.kernels import default_interpret

    # interpret mode emulates the grid sequentially — timing it would tune
    # the emulator, not the kernel
    return not default_interpret()


def tuned_block(kernel: str, dtype, *, dirname: str = None, measure=None) -> int:
    """The autotuned flat-block size for `(kernel, dtype)` on this
    backend+device — from the process memo, else the persisted JSON, else a
    fresh sweep (persisted for the next run). Falls back to `DEFAULT_BLOCK`
    unswept where timing is meaningless (see module docstring).

    `measure(kernel, dtype, block) -> seconds` overrides the probe (tests
    inject a deterministic one); passing it also forces the sweep."""
    import jax.numpy as jnp

    key = f"{kernel}.{jnp.dtype(dtype).name}"
    path = cache_path(dirname)
    memo_key = (path, key)
    hit = _MEMO.get(memo_key)
    if hit is not None:
        return hit

    data = _load(path)
    if key in data:
        block = int(data[key])
        _MEMO[memo_key] = block
        return block

    if measure is None and not _sweep_allowed():
        # no persist: a later run on a kernel-capable backend should sweep
        return DEFAULT_BLOCK

    probe = measure or _default_measure
    timings = {b: probe(kernel, dtype, b) for b in CANDIDATES}
    block = min(timings, key=timings.get)
    data[key] = block
    _store(path, data)
    _MEMO[memo_key] = block
    return block
