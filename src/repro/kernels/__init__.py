# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def default_interpret() -> bool:
    """The one interpret-mode policy for every Pallas kernel in this package:
    compiled on gpu/tpu, interpret (pure-XLA emulation) on cpu and anything
    else without a kernel-capable accelerator."""
    import jax

    return jax.default_backend() not in ("gpu", "tpu")


def _flat_grid(block, *arrays):
    """The one flatten/pad/grid recipe of the elementwise *_raw wrappers
    (guided_update and its optimizer-fused family): clamp `block` to the
    element count, flatten every array and zero-pad to a block multiple.

    Returns `(flats, block, grid, n)` — the padded 1-D views (same order as
    `arrays`), the clamped block, the 1-D grid size `padded_len // block`, and
    the original element count for the caller's `out[:n].reshape(shape)`.
    """
    import jax.numpy as jnp

    n = arrays[0].size
    block = min(block, n)
    pad = (-n) % block
    flats = [jnp.pad(a.reshape(-1), (0, pad)) for a in arrays]
    return flats, block, (n + pad) // block, n
