# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def default_interpret() -> bool:
    """The one interpret-mode policy for every Pallas kernel in this package:
    compiled on gpu/tpu, interpret (pure-XLA emulation) on cpu and anything
    else without a kernel-capable accelerator."""
    import jax

    return jax.default_backend() not in ("gpu", "tpu")
