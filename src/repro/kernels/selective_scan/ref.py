"""Pure-jnp oracle for the selective scan (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, Bc, Cc, h0=None):
    """x, dt: (B,S,ed); A: (ed,n); Bc, Cc: (B,S,n). fp32 math.
    Returns (y (B,S,ed), h_final (B,ed,n))."""
    B, S, ed = x.shape
    n = A.shape[1]
    h = h0 if h0 is not None else jnp.zeros((B, ed, n), jnp.float32)

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A)
        h = dA * h + (dt_t * x_t)[:, :, None] * B_t[:, None, :]
        y_t = jnp.sum(h * C_t[:, None, :], axis=-1)
        return h, y_t

    tm = lambda z: jnp.moveaxis(z.astype(jnp.float32), 1, 0)
    h, ys = jax.lax.scan(step, h.astype(jnp.float32), (tm(dt), tm(Bc), tm(Cc), tm(x)))
    return jnp.moveaxis(ys, 0, 1), h
