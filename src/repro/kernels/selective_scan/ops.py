"""jit'd public wrapper for the chunked selective scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.selective_scan.kernel import selective_scan_raw


@partial(jax.jit, static_argnames=("chunk", "block_ed"))
def selective_scan(x, dt, A, Bc, Cc, h0=None, *, chunk: int = 16, block_ed: int = 512):
    """x, dt: (B,S,ed); A: (ed,n); Bc,Cc: (B,S,n) -> (y (B,S,ed) fp32, h (B,ed,n))."""
    B, S, ed = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, ed, n), jnp.float32)
    return selective_scan_raw(
        x.astype(jnp.float32), dt.astype(jnp.float32), A.astype(jnp.float32),
        Bc.astype(jnp.float32), Cc.astype(jnp.float32), h0.astype(jnp.float32),
        Q=min(chunk, S), be=min(block_ed, ed), interpret=default_interpret(),
    )
