"""Chunked Mamba selective scan for TPU via Pallas.

Grid: (B, n_ed_blocks, n_chunks); the chunk dim is last (sequential) so the
carried SSM state block h (be, n) lives in a revisited output buffer. Within a
chunk the recurrence runs as an in-VMEM fori_loop — the O(S * ed * n) decay
tensors that make the pure-XLA form memory-infeasible at jamba scale never
leave VMEM (HBM->VMEM->HBM traffic is O(S * (ed + n)) per block).

The ed (inner channel) dim is tiled with be=512 by default: a (Q=16, be=512,
n=16) working set is ~0.5 MiB fp32 — comfortably VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref, y_ref, h_ref, *, Q, be, n):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    x = x_ref[0].astype(jnp.float32)    # (Q, be)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, be)
    A = A_ref[...].astype(jnp.float32)  # (be, n)
    Bc = B_ref[0].astype(jnp.float32)   # (Q, n)
    Cc = C_ref[0].astype(jnp.float32)   # (Q, n)

    def step(t, carry):
        h = carry  # (be, n)
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]   # (be,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]
        B_t = jax.lax.dynamic_slice_in_dim(Bc, t, 1, 0)[0]    # (n,)
        C_t = jax.lax.dynamic_slice_in_dim(Cc, t, 1, 0)[0]
        dA = jnp.exp(dt_t[:, None] * A)                        # (be, n)
        h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=1)                # (be,)
        y_ref[0, t, :] = y_t
        return h

    h = jax.lax.fori_loop(0, Q, step, h_ref[0])
    h_ref[0, :, :] = h


def selective_scan_raw(x, dt, A, Bc, Cc, h0, *, Q: int = 16, be: int = 512, interpret: bool = True):
    """x, dt: (B,S,ed); A: (ed,n); Bc, Cc: (B,S,n); h0: (B,ed,n) fp32.
    Returns (y (B,S,ed) fp32, h_final (B,ed,n) fp32)."""
    B, S, ed = x.shape
    n = A.shape[1]
    Q = min(Q, S)
    be = min(be, ed)
    assert S % Q == 0 and ed % be == 0, (S, Q, ed, be)
    nc, nb = S // Q, ed // be

    kernel = functools.partial(_scan_kernel, Q=Q, be=be, n=n)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nb, nc),
        in_specs=[
            pl.BlockSpec((1, Q, be), lambda b, e, c: (b, c, e)),
            pl.BlockSpec((1, Q, be), lambda b, e, c: (b, c, e)),
            pl.BlockSpec((be, n), lambda b, e, c: (e, 0)),
            pl.BlockSpec((1, Q, n), lambda b, e, c: (b, c, 0)),
            pl.BlockSpec((1, Q, n), lambda b, e, c: (b, c, 0)),
            pl.BlockSpec((1, be, n), lambda b, e, c: (b, e, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, be), lambda b, e, c: (b, c, e)),
            pl.BlockSpec((1, be, n), lambda b, e, c: (b, e, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, ed), jnp.float32),
            jax.ShapeDtypeStruct((B, ed, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bc, Cc, h0)
    return y, h
