"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_raw


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 128, bk: int = 128):
    """q: (B,S,H,dh); k,v: (B,S,K,dh) -> (B,S,H,dh) in q.dtype."""
    acc, m, l = flash_attention_raw(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk, interpret=default_interpret()
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,H,S,dh)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)
