"""Pure-jnp oracle for flash_attention (GQA, causal, sliding-window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,dh); k,v: (B,S,K,dh) -> (B,S,H,dh), float32 math."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / np.sqrt(dh)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qi >= kj
    if window:
        mask &= (qi - kj) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, S, H, dh)
