"""Blocked online-softmax attention (FlashAttention) for TPU via Pallas.

Grid: (B, H, n_q_blocks, n_kv_blocks) with the kv dim sequential ("arbitrary")
so the (acc, m, l) output blocks for a given (b, h, iq) are revisited across kv
iterations — the classic TPU accumulator-in-revisited-output pattern (no
scratch, works identically under interpret=True on CPU).

Block shapes are MXU-aligned (multiples of 128 on the q/kv dims by default;
d_head is kept whole per block since all assigned archs have d_head <= 256).
GQA is handled by the kv index_map (h -> h // group_size). Causal and
sliding-window masks are applied in-kernel; fully-masked kv blocks are still
visited (correctness-first; the §Perf pass may skip them via a predicated
index map).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *, scale, causal, window, bq, bk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, dh)

    s = (q @ k.T) * scale  # (bq, bk)

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, 0]          # (bq,)
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: p would be exp(NEG_INF - NEG_INF) = 1; zero them
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[0, 0, :, :] = alpha[:, None] * acc_ref[0, 0] + p @ v
    m_ref[0, 0, :] = m_new
    l_ref[0, 0, :] = l_new


def flash_attention_raw(q, k, v, *, causal: bool, window: int, bq: int = 128, bk: int = 128,
                        interpret: bool = True):
    """q: (B,S,H,dh); k,v: (B,S,K,dh). Returns (acc, m, l) un-normalized."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    scale = 1.0 / np.sqrt(dh)

    # layout (B, H, S, dh) for blocking
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return acc, m, l
