"""`ParameterStore` — the chief's versioned parameter state + apply path.

One store owns the authoritative weights W, the optimizer accumulator, and
the guided window state. Every applied push increments `version`; the
staleness of an update is OBSERVED, not scripted:

    staleness = version_at_apply - read_version_of_the_push

and the recorded sequence is what `Report.staleness_hist` summarizes. The
apply path drives the same `DelayCompensator` hooks the scan simulator uses
(sim_score / sim_replay / compensate_grads / sim_kernel_lambda), so all six
registered strategies run unmodified on live delay; the arithmetic mirrors
`repro.engine.delaysim`'s scan body in float64 numpy (the fused-kernel math:
gt = g + lam*g*g*(W - W_fetch), then the plain optimizer rule on gt), which
is why a replay-mode run lands on the scan/train_ps trajectory to round-off.

Two grant disciplines share this apply path:

  * replay — the parity oracle. The chief holds the `DelaySchedule` extracted
    by `core.parameter_server.extract_schedule` (same seed -> same table as
    the scan backend) and sequences pulls/pushes against it: worker w's k-th
    pull blocks until `version >= fetch_version` and is served the weights AS
    OF that version (a small version ring keeps the last max_staleness+1
    copies); its push blocks until `version == arrival_step`. Real processes
    compute every gradient; only the interleaving is pinned, so the observed
    staleness sequence must equal the schedule's column — locked in
    tests/test_dist.py.
  * live — free-running. Pushes apply in arrival order at wall-clock speed;
    `drop_rate` injects dropped updates; late pushes after the step budget
    are counted, not crashed on.

Thread safety: one lock/condition serializes applies (the parameter server
is sequential by definition — the asynchrony lives between processes).
Strategy hooks trace tiny (rho, P, k) arrays; they run eagerly under a scoped
enable_x64 so float64 parity survives the jnp round-trip.
"""
from __future__ import annotations

import threading
from collections import deque

import numpy as np


def _aug(X):
    return np.concatenate([X, np.ones((len(X), 1))], axis=1)


def _loss(W, Xa, y):
    """Literal LogisticRegression.loss on pre-augmented rows (float64)."""
    z = Xa @ W
    z = z - z.max(axis=1, keepdims=True)
    lse = np.log(np.exp(z).sum(axis=1))
    return float(np.mean(lse - z[np.arange(len(y)), y]))


def grad(W, Xa, y):
    """Literal LogisticRegression.grad on pre-augmented rows (float64).
    Shared with repro.dist.worker so chief and workers use one arithmetic."""
    z = Xa @ W
    z = z - z.max(axis=1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=1, keepdims=True)
    p[np.arange(len(y)), y] -= 1.0
    return Xa.T @ p / len(y)


def strategy_needs_fetch(strategy) -> bool:
    """True when the strategy compensates against the fetched weights
    (DC-ASGD Taylor term, Gap-Aware dampening): workers then ship W_fetch
    back with the push so the chief never needs an unbounded version ring."""
    from repro.engine.strategies import DelayCompensator

    return bool(strategy.sim_kernel_lambda()) or (
        type(strategy).compensate_grads is not DelayCompensator.compensate_grads
    )


class ParameterStore:
    """Versioned parameter state + the strategy-driven apply path."""

    def __init__(self, spec, strategy, W0, train, val, total_steps: int,
                 schedule=None, drop_rate: float = 0.0, seed: int = 0,
                 checkpointer=None, ckpt_every: int = 0, policy=None):
        self.spec = spec
        self.strategy = strategy
        self.W = np.asarray(W0, np.float64).copy()
        self.r = np.zeros_like(self.W)             # rmsprop/adagrad accumulator
        self.Xa = _aug(np.asarray(train[0], np.float64))
        self.y = np.asarray(train[1])
        self.Xva = _aug(np.asarray(val[0], np.float64))
        self.yv = np.asarray(val[1])
        self.version = 0
        self.total = int(total_steps)
        self.lam = float(strategy.sim_kernel_lambda())
        self.guided = bool(strategy.sim_guided)
        self.need_fetch = strategy_needs_fetch(strategy)
        rho = max(spec.rho, 1)
        self.rho = rho
        self.wscore = np.zeros((rho,), np.float64)
        self.wgrads = np.zeros((rho,) + self.W.shape, np.float64)
        self.prev_avg = np.inf
        # ---- observability
        self.history: list = []          # (version, avg_err) per apply
        self.staleness: list = []        # observed per-apply staleness
        self.drops = 0                   # scenario-dropped pushes
        self.late = 0                    # pushes arriving after the budget
        self.joins = 0
        self.worker_exits = 0
        self.bad_frames = 0              # malformed/unparseable worker frames
        self.resets = 0                  # chaos-injected connection resets
        # ---- resilience (DESIGN.md §14): sentinel screen + rollback policy.
        # The screen/detector own no lock — every call happens under `cond`.
        self.policy = policy
        self.screen = None
        self.detector = None
        if policy is not None and policy.screening:
            from repro.resilience import DivergenceDetector, GradScreen

            self.screen = GradScreen(policy)
            if policy.rollback:
                self.detector = DivergenceDetector(policy.factor)
        self.lr_scale = 1.0              # cut by lr_backoff at every rollback
        self.rollbacks = 0
        self.rollback_log: list = []     # (version, restored_step|None, reason)
        self.diverged = 0                # post-apply divergences detected
        self.fatal: Exception | None = None   # set -> drain workers, launcher raises
        # last committed sane state: the rollback target when no verified
        # on-disk snapshot exists (or the dir predates checksums)
        self._good = (self.W.copy(), self.r.copy())
        # ---- concurrency
        self.cond = threading.Condition()
        self._drop_rng = np.random.default_rng(seed + 7919)
        self.drop_rate = float(drop_rate)
        # ---- checkpointing (chief-side snapshots)
        self._ckpt = checkpointer
        self._ckpt_every = int(ckpt_every)
        # ---- replay grant state
        self.schedule = schedule
        self._ring: dict = {0: self.W.copy()}      # version -> W (replay only)
        self._dispatch: dict = {}                  # wid -> deque of dispatches
        self._ring_keep = 2
        if schedule is not None:
            if schedule.worker is None:
                raise ValueError(
                    "replay mode needs a DelaySchedule with per-arrival worker "
                    "ids (re-extract with the current core.parameter_server)")
            self._ring_keep = int(schedule.max_staleness) + 2
            fetch = schedule.fetch_version
            for t in range(schedule.n_steps):
                w = int(schedule.worker[t])
                self._dispatch.setdefault(w, deque()).append(
                    (t, int(fetch[t]), schedule.batch_rows[t]))

    # ------------------------------------------------------------- numerics

    def _hook_score(self, d_own, d_avg, prev_avg):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            return float(self.strategy.sim_score(
                jnp.float64(d_own), jnp.float64(d_avg), jnp.float64(prev_avg)))

    def _hook_replay(self, W2, lr):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            return np.asarray(self.strategy.sim_replay(
                jnp.asarray(W2), jnp.asarray(self.wscore),
                jnp.asarray(self.wgrads), jnp.float64(lr)))

    def _compensate(self, g, w_fetch):
        """Non-fused compensation (e.g. gap_aware) via the mesh hook, exactly
        as the scan body does for strategies without a kernel lambda."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        from repro.engine.strategies import sim_shim_state

        with enable_x64():
            shim = sim_shim_state(self.version, jnp.asarray(w_fetch),
                                  jnp.float64(self.prev_avg), self.spec.rho)
            return np.asarray(self.strategy.compensate_grads(
                jnp.asarray(g), jnp.asarray(self.W), shim))

    def _apply_opt(self, gt):
        spec = self.spec
        lr = spec.lr * self.lr_scale      # lr_scale == 1.0 until a rollback
        if spec.optimizer == "sgd":
            return self.W - lr * gt
        if spec.optimizer == "rmsprop":
            self.r = spec.rmsprop_beta * self.r + (1 - spec.rmsprop_beta) * gt * gt
            return self.W - lr * gt / np.sqrt(self.r + spec.eps)
        if spec.optimizer == "adagrad":
            self.r = self.r + gt * gt
            return self.W - lr * gt / np.sqrt(self.r + spec.eps)
        raise ValueError(spec.optimizer)

    def _apply_locked(self, g, read_version: int, rows, w_fetch,
                      wid: int = None) -> int:
        """One server step (caller holds the lock). Returns observed staleness.

        With a rollback-capable policy the post-apply validation loss is the
        divergence backstop: a finite-but-poisoned update that slipped the
        per-push screen trips here, the update is NOT committed (version does
        not advance — exactly-once applies and the staleness identity stay
        intact), and the store rolls back to the last verified state."""
        t = self.version
        s = t - int(read_version)
        g = np.asarray(g, np.float64)
        if w_fetch is None:
            w_fetch = self.W          # fresh push (staleness 0) or no-stale strategy
        if self.lam:
            gt = g + self.lam * g * g * (self.W - np.asarray(w_fetch, np.float64))
            g_window = g              # scan body stores the RAW gradient when fused
        else:
            g = self._compensate_maybe(g, w_fetch)
            gt = g_window = g
        loss_before = _loss(self.W, self.Xa[rows], self.y[rows]) if self.guided else 0.0
        W2 = self._apply_opt(gt)
        avg = _loss(W2, self.Xva, self.yv)
        if self.detector is not None and self.detector.update(avg):
            # poisoned trajectory: discard this update (the accumulator `r`
            # is restored by the rollback) and remediate
            self.diverged += 1
            self._rollback_locked(wid)
            return s
        if self.guided:
            d_avg = avg - self.prev_avg
            d_own = _loss(W2, self.Xa[rows], self.y[rows]) - loss_before
            sc = self._hook_score(d_own, d_avg, self.prev_avg)
            pos = t % self.rho
            self.wscore[pos] = sc
            self.wgrads[pos] = g_window
            if (t + 1) % self.rho == 0:
                W2 = self._hook_replay(W2, self.spec.lr)
                self.wscore[:] = 0.0
        self.W = W2
        self.prev_avg = avg
        self.version = t + 1
        if self.schedule is not None:
            self._ring[self.version] = W2.copy()
            for old in [v for v in self._ring if v < self.version - self._ring_keep]:
                del self._ring[old]
        self.history.append((self.version, avg))
        self.staleness.append(s)
        if self.detector is not None:
            # the committed state is by construction sane: the in-memory
            # rollback target when no verified disk snapshot exists
            self._good = (self.W.copy(), self.r.copy())
        if self._ckpt is not None and self._ckpt_every and self.version % self._ckpt_every == 0:
            self._snapshot()
        self.cond.notify_all()
        return s

    # ------------------------------------------------------------ resilience

    def _rollback_locked(self, wid=None):
        """Remediate a detected divergence (caller holds the lock): restore
        W/r from the newest VERIFIED checkpoint (sha-checked, falling back
        through manifest history) or the in-memory last-good copy, back the
        lr off, and quarantine the offending worker. The version counter is
        NEVER rewound — applies stay exactly-once and observed staleness
        stays `version - read_version`. Exhausting `max_rollbacks` marks the
        run fatal: workers drain on their next request, the launcher raises."""
        policy = self.policy
        self.rollbacks += 1
        if self.rollbacks > policy.max_rollbacks:
            self.fatal = RuntimeError(
                f"divergence persisted through {policy.max_rollbacks} "
                f"rollbacks (version {self.version}/{self.total}, "
                f"lr_scale {self.lr_scale:.3g}); the trajectory is not "
                f"recoverable by remediation")
            self.cond.notify_all()
            return
        restored_step = None
        W, r = self._good
        if self._ckpt is not None:
            from repro.checkpoint import CorruptCheckpointError, dist_restore

            try:
                snap = dist_restore(self.spec.ckpt_dir)
                W = snap["W"]
                r = snap.get("r", np.zeros_like(self.W))
                restored_step = int(snap["version"])
            except (FileNotFoundError, CorruptCheckpointError):
                pass  # nothing intact on disk (yet): in-memory last-good
        self.W = np.asarray(W, np.float64).copy()
        self.r = np.asarray(r, np.float64).copy()
        self.lr_scale *= policy.lr_backoff
        self.prev_avg = _loss(self.W, self.Xva, self.yv)
        if self.detector is not None:
            self.detector.best = min(self.detector.best, self.prev_avg)
        # the guided consistency window scored a trajectory that no longer
        # exists; restart it rather than replaying stale corrections
        self.wscore[:] = 0.0
        self.wgrads[:] = 0.0
        if wid is not None and self.screen is not None:
            self.screen.quarantine(wid, self.version)
        self.rollback_log.append((self.version, restored_step,
                                  "post-apply divergence"))
        self.cond.notify_all()

    def record_bad_frame(self, wid, exc) -> None:
        """A malformed/unparseable frame arrived on a worker connection: the
        chief drops the connection, counts it, and the run continues."""
        with self.cond:
            self.bad_frames += 1
            self.cond.notify_all()

    def record_reset(self) -> None:
        """A chaos-injected connection reset (repro.chaos): counted apart
        from organic worker exits so tests can assert the injection fired."""
        with self.cond:
            self.resets += 1
            self.cond.notify_all()

    def fatal_error(self):
        with self.cond:
            return self.fatal

    def resilience_counters(self) -> dict:
        """The sentinel/remediation half of the launcher's `dist` result
        (supervisor stats merge in at the launcher)."""
        with self.cond:
            out = {
                "bad_frames": self.bad_frames,
                "resets": self.resets,
                "rollbacks": self.rollbacks,
                "diverged": self.diverged,
                "lr_scale": self.lr_scale,
                "rollback_log": list(self.rollback_log),
            }
            if self.screen is not None:
                out.update(self.screen.counters())
            return out

    def _compensate_maybe(self, g, w_fetch):
        from repro.engine.strategies import DelayCompensator

        if type(self.strategy).compensate_grads is DelayCompensator.compensate_grads:
            return g
        return self._compensate(g, w_fetch)

    # ------------------------------------------------------------ snapshots

    def _snapshot(self):
        from repro.checkpoint import dist_snapshot

        self._ckpt.save(self.version, dist_snapshot(
            self.W, self.version, np.asarray(self.staleness, np.int64),
            r=self.r, lr_scale=self.lr_scale))

    def final_snapshot(self):
        if self._ckpt is not None:
            with self.cond:
                self._snapshot()
            self._ckpt.close()

    # ---------------------------------------------------------- replay mode

    def replay_pull(self, wid: int):
        """Block until this worker's next scheduled fetch version exists, then
        serve the weights AS OF that version. None -> no dispatches left."""
        q = self._dispatch.get(wid)
        with self.cond:
            if not q:
                return None
            t, fetch_v, rows = q[0]
            self.cond.wait_for(lambda: self.version >= fetch_v)
            return self._ring[fetch_v], fetch_v, rows

    def replay_push(self, wid: int, g, read_version: int):
        """Block until the store reaches this dispatch's scheduled arrival
        step, then apply. Returns the observed staleness."""
        q = self._dispatch[wid]
        with self.cond:
            t, fetch_v, rows = q.popleft()
            self.cond.wait_for(lambda: self.version == t)
            w_fetch = self._ring[fetch_v] if self.need_fetch else None
            return self._apply_locked(g, read_version, rows, w_fetch)

    # ------------------------------------------------------------ live mode

    def live_step(self, wid: int, g, read_version: int, rows, w_fetch):
        """Apply a push (if any) and hand back the freshest params. Returns
        (W, version) or None once the step budget is exhausted (or the run
        went fatal — remediation exhausted — and workers should drain).

        With a sentinel policy the push is screened first: non-finite (and,
        at level "full", norm-exploded) gradients are rejected and counted
        per worker, never applied; a quarantined worker's pushes are ignored
        until its ban lifts, but it still receives fresh params — it may
        recover (a transient NaN source) without a respawn."""
        with self.cond:
            if self.fatal is not None:
                return None
            if g is not None:
                g = np.asarray(g, np.float64)
                if self.version >= self.total:
                    self.late += 1
                elif self.screen is not None and \
                        self.screen.admit(wid, g, self.version) is not None:
                    pass     # rejected/quarantined: counted by the screen
                elif self.drop_rate and self._drop_rng.random() < self.drop_rate:
                    self.drops += 1
                else:
                    self._apply_locked(g, read_version, rows, w_fetch, wid=wid)
            if self.fatal is not None or self.version >= self.total:
                return None
            return self.W, self.version

    # --------------------------------------------------------- worker counts

    def record_join(self):
        """An elastic worker joined (chief assigned it a fresh wid)."""
        with self.cond:
            self.joins += 1

    def record_worker_exit(self):
        """A worker connection died mid-stream (kill/crash): tolerated,
        counted, and waiters are woken so replay grants can re-examine."""
        with self.cond:
            self.worker_exits += 1
            self.cond.notify_all()

    # -------------------------------------------------------------- queries

    def done(self) -> bool:
        with self.cond:
            return self.version >= self.total

    def progress(self) -> int:
        with self.cond:
            return self.version

    def staleness_hist(self) -> dict:
        with self.cond:
            staleness = list(self.staleness)
        counts = np.bincount(np.asarray(staleness, np.int64)) if staleness else []
        return {int(s): int(n) for s, n in enumerate(counts) if n}
