"""`repro.dist` — true asynchronous parameter-server execution (DESIGN.md §10).

The scan backend *simulates* delay; this package *is* delay: a chief process
owning a versioned `ParameterStore` (weights + guided window state), N real
worker processes computing gradients and pushing them with the version they
read, over stdlib `multiprocessing.connection` TCP. Staleness becomes an
observed quantity (`applied_version - read_version`), the same
`DelayCompensator` strategies drive the apply path, and a fault-injection
layer (kill/restart/join, dropped updates, per-worker slowdowns) exercises
what no simulator can: surviving real process death.

Entry points:
  * `Trainer.from_spec(ExperimentSpec(backend="dist", ...)).fit(data)`
  * `python -m repro.launch.train --backend dist --dist-workers N ...`
  * `python -m repro.dist.worker --addr host:port` (spawned per worker)

This module resolves its exports lazily: worker processes import
`repro.dist.worker`/`repro.dist.protocol` (numpy-only) and must not pay for
the launcher's jax-importing dependency chain at startup.
"""
_EXPORTS = {
    "run_local": ("repro.dist.launcher", "run_local"),
    "ParameterStore": ("repro.dist.store", "ParameterStore"),
    "strategy_needs_fetch": ("repro.dist.store", "strategy_needs_fetch"),
    "Scenario": ("repro.dist.scenarios", "Scenario"),
    "Chief": ("repro.dist.chief", "Chief"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        mod, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.dist' has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(mod), attr)
