"""Launcher of the async parameter-server backend (`backend="dist"`).

`run_local(spec, X, y, ...)` is the single-call orchestration the Trainer
facade dispatches to: it prepares data + schedule with the SAME rng protocol
as train_ps/scan (`prepare_run`), builds the chief (store + TCP listener) in
this process, spawns N real worker processes (`python -m repro.dist.worker`),
drives the fault scenario against the store's version counter, and assembles
a result dict with the scan backend's contract plus the dist observability
(observed staleness sequence/histogram, drop/exit/join counters).

Worker processes are monitored, not trusted: replay mode (the deterministic
parity oracle) treats an unexpected worker death as fatal — the schedule
cannot complete without it — while live mode absorbs it and the watchdog only
fires if the VERSION counter stalls for `spec.dist_timeout` seconds (i.e.
nobody is pushing anymore). Worker stderr is captured to per-worker temp
files and surfaced in the failure message, not interleaved with the chief's.

Self-healing (DESIGN.md §14): live spawned runs hand their processes to a
`repro.resilience.Supervisor` — death (or a heartbeat-lease expiry, with
`spec.dist_lease_s`) triggers respawn under capped exponential backoff, and
persistent failures are evicted. `spec.sentinel`/`spec.rollback` arm the
store's gradient screen and divergence rollback; an unrecoverable store
(`store.fatal_error()`) fails the run here, in the launcher's thread, with
the store's diagnosis. A `repro.chaos.ChaosPlan` drives deterministic fault
injection through the same seams (`chaos=` argument).
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.common.topologies import TOPOLOGY_SAMPLERS
from repro.core.parameter_server import LogisticRegression, prepare_run
from repro.dist import protocol
from repro.dist.chief import Chief
from repro.dist.scenarios import Scenario
from repro.dist.store import ParameterStore
from repro.resilience import LeaseTable, SentinelPolicy, Supervisor


def _src_root() -> str:
    """Directory to put on the workers' PYTHONPATH (the parent of `repro`)."""
    import repro.dist as d

    return os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(d.__file__))))


def _worker_env() -> dict:
    env = dict(os.environ)
    src = _src_root()
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    # workers import repro.common (jax at package level); keep them on cpu
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


class _WorkerProc:
    """One spawned worker process + its captured stderr."""

    def __init__(self, wid, addr: str, env: dict):
        self.wid = wid
        self.errfile = tempfile.NamedTemporaryFile(
            mode="w+", suffix=f".dist-worker-{'new' if wid is None else wid}.err",
            delete=False)
        cmd = [sys.executable, "-m", "repro.dist.worker", "--addr", addr]
        if wid is not None:
            cmd += ["--wid", str(wid)]
        self.proc = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                                     stderr=self.errfile)

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self):
        if self.alive():
            self.proc.kill()
        self.proc.wait()

    def stderr_tail(self, n: int = 20) -> str:
        try:
            self.errfile.flush()
            with open(self.errfile.name) as f:
                lines = f.readlines()
            return "".join(lines[-n:])
        except OSError:
            return "<stderr unavailable>"

    def cleanup(self):
        try:
            self.errfile.close()
            os.unlink(self.errfile.name)
        except OSError:
            pass


def run_local(spec, X, y, n_classes: int, Xtest=None, ytest=None,
              strategy=None, spawn: bool = True, port: int = 0,
              chaos=None) -> dict:
    """Run `spec` as a real multi-process async parameter server. Same result
    contract as delaysim.run (train/val losses, history, model, schedule,
    n_steps) plus: staleness_seq, staleness_hist, and a `dist` diagnostics
    dict (drops, late, worker_exits, joins, n_workers, mode, and — when the
    resilience layer is armed — rejections/rollbacks/supervisor counters).

    spawn=False runs the chief only (`--role chief`): the listener address is
    printed and externally launched `repro.dist.worker` processes connect to
    it — lifecycle events that target spawned processes are then skipped.

    `chaos` takes a `repro.chaos.ChaosPlan` (live mode only): deterministic
    fault injection through the launcher (kills, checkpoint truncation), the
    chief (connection resets) and the workers (NaN/exploding gradients,
    garbage frames)."""
    if strategy is None:
        from repro.engine.strategies import get_compensator

        strategy = get_compensator(spec.strategy, spec.to_guided_config())
    topology = spec.resolved_topology
    try:
        sampler = TOPOLOGY_SAMPLERS[topology]
    except KeyError:
        raise KeyError(
            f"unknown topology {topology!r}; known: {', '.join(TOPOLOGY_SAMPLERS)}"
        ) from None

    W0, train, val, schedule = prepare_run(
        X, y, n_classes, spec.to_schedule_config(),
        delay_sampler=sampler, topology=topology)
    T = schedule.n_steps
    if T == 0:
        return _empty_result(spec, W0, train, val, schedule, Xtest, ytest)

    replay = spec.dist_mode == "replay"
    scenario = Scenario.from_spec(spec)
    n_workers = schedule.n_workers if replay else (spec.workers or schedule.n_workers)

    checkpointer = None
    if spec.ckpt_dir:
        from repro.checkpoint import AsyncCheckpointer

        checkpointer = AsyncCheckpointer(
            spec.ckpt_dir, keep_last=spec.keep_last,
            meta={"backend": "dist", "mode": spec.mode, "strategy": spec.strategy,
                  "seed": spec.seed, "dist_mode": spec.dist_mode})

    policy = None
    if not replay:
        policy = SentinelPolicy.from_spec(spec)
        if not (policy.screening or policy.rollback):
            policy = None

    store = ParameterStore(
        spec, strategy, W0, train, val, total_steps=T,
        schedule=schedule if replay else None,
        drop_rate=scenario.drop_rate, seed=spec.seed,
        checkpointer=checkpointer, ckpt_every=spec.ckpt_every,
        policy=policy)

    meta = {
        "Xtr": np.asarray(train[0], np.float64),
        "ytr": np.asarray(train[1]),
        "bs": spec.batch_size,
        "lr": spec.lr,
        "seed": spec.seed,
        "mode": spec.dist_mode,
        "need_fetch": store.need_fetch,
        "delayed_avg": spec.delayed_avg,
        "topology": topology,
        "time_scale": scenario.time_scale,
        "n_workers": n_workers,
    }
    chaos_resets = ()
    chaos_kills: dict = {}
    truncate_at = None
    if chaos is not None and not replay:
        wm = chaos.worker_meta()
        if wm:
            meta["chaos"] = wm
        chaos_resets = chaos.reset_events()
        chaos_kills = dict(chaos.kill_events())
        truncate_at = chaos.truncate_at

    supervise = spawn and not replay and spec.dist_supervise
    leases = LeaseTable(spec.dist_lease_s) \
        if supervise and spec.dist_lease_s else None
    chief = Chief(store, meta, port=port, leases=leases,
                  chaos_resets=chaos_resets)
    addr = protocol.format_addr(chief.address)
    env = _worker_env()

    if not spawn:
        print(f"dist chief listening on {addr} "
              f"(workers: PYTHONPATH=src python -m repro.dist.worker --addr {addr})",
              flush=True)
    sup = None
    procs: dict = {}
    if supervise:
        sup = Supervisor(lambda wid: _WorkerProc(wid, addr, env), n_workers,
                         max_respawns=spec.dist_max_respawns, leases=leases,
                         seed=spec.seed)
        sup.start()
    elif spawn:
        procs = {w: _WorkerProc(w, addr, env) for w in range(n_workers)}
    extra: list = []      # elastically joined workers (wid assigned by chief)
    fired = 0
    try:
        last_v, last_move = store.progress(), time.monotonic()
        while not store.done():
            fatal = store.fatal_error()
            if fatal is not None:
                raise RuntimeError(str(fatal))
            v = store.progress()
            if v != last_v:
                last_v, last_move = v, time.monotonic()
            for op, wid, _at in scenario.due(fired, v):
                fired += 1
                if op == "kill":
                    if sup is not None:
                        sup.kill(wid)
                    elif wid in procs:
                        procs[wid].kill()
                elif op == "restart":
                    if sup is not None:
                        sup.respawn_now(wid)
                    else:
                        if wid in procs:
                            procs[wid].kill()
                            procs[wid].cleanup()
                        procs[wid] = _WorkerProc(wid, addr, env)
                elif op == "join":
                    if sup is not None:
                        sup.spawn_extra()
                    else:
                        extra.append(_WorkerProc(None, addr, env))
            for wid in [w for w, at in chaos_kills.items() if v >= at]:
                del chaos_kills[wid]
                if sup is not None:
                    sup.kill(wid)
                elif wid in procs:
                    procs[wid].kill()
            if truncate_at is not None and v >= truncate_at and spec.ckpt_dir:
                from repro.chaos import truncate_newest

                # retries until an archive exists to tear, then disarms
                if truncate_newest(spec.ckpt_dir) is not None:
                    truncate_at = None
            if replay:
                dead = [w for w, p in procs.items() if not p.alive()]
                if dead and not store.done():
                    w = dead[0]
                    raise RuntimeError(
                        f"replay worker {w} exited before its schedule drained "
                        f"(version {v}/{T}); stderr tail:\n{procs[w].stderr_tail()}")
            if time.monotonic() - last_move > spec.dist_timeout:
                tails = sup.stderr_tails(5) if sup is not None else \
                    {w: p.stderr_tail(5) for w, p in procs.items()}
                raise RuntimeError(
                    f"dist run stalled at version {v}/{T} for "
                    f"{spec.dist_timeout:.0f}s (mode={spec.dist_mode}); "
                    f"worker stderr tails: {tails}")
            time.sleep(0.01)
        # drain: workers learn "done" on their next request and exit. Stop
        # the supervisor FIRST: exits on a drained run are success, not
        # failures to heal.
        if sup is not None:
            sup.stop_polling()
        deadline = time.monotonic() + 10.0
        for p in (sup.procs() if sup is not None
                  else list(procs.values()) + extra):
            if p.alive():
                try:
                    p.proc.wait(timeout=max(0.1, deadline - time.monotonic()))
                except subprocess.TimeoutExpired:
                    p.kill()
    finally:
        if sup is not None:
            sup.close()     # kills + cleans whatever is still up
        for p in list(procs.values()) + extra:
            if p.alive():
                p.kill()
            p.cleanup()
        chief.close()
        store.final_snapshot()

    return _result(spec, store, train, val, schedule, Xtest, ytest,
                   n_workers=n_workers, sup=sup)


def _final_metrics(W, train, val, Xtest, ytest) -> dict:
    model = LogisticRegression.from_weights(np.asarray(W))
    out = {
        "train_loss": model.loss(*train),
        "val_loss": model.loss(*val),
        "model": model,
    }
    if Xtest is not None:
        out["test_accuracy"] = model.accuracy(Xtest, ytest)
    return out


def _result(spec, store: ParameterStore, train, val, schedule, Xtest, ytest,
            n_workers: int, sup=None) -> dict:
    out = _final_metrics(store.W, train, val, Xtest, ytest)
    out["history"] = [(t, float(e)) for t, e in store.history]
    out["n_steps"] = store.progress()
    out["schedule"] = schedule
    out["staleness_seq"] = np.asarray(store.staleness, np.int64)
    out["staleness_hist"] = store.staleness_hist()
    out["dist"] = {
        "mode": spec.dist_mode,
        "n_workers": n_workers,
        "drops": store.drops,
        "late": store.late,
        "worker_exits": store.worker_exits,
        "joins": store.joins,
    }
    out["dist"].update(store.resilience_counters())
    if sup is not None:
        out["dist"]["supervisor"] = sup.stats()
    return out


def _empty_result(spec, W0, train, val, schedule, Xtest, ytest) -> dict:
    out = _final_metrics(W0, train, val, Xtest, ytest)
    out.update(history=[], n_steps=0, schedule=schedule,
               staleness_seq=np.zeros((0,), np.int64), staleness_hist={},
               dist={"mode": spec.dist_mode, "n_workers": 0, "drops": 0,
                     "late": 0, "worker_exits": 0, "joins": 0})
    return out
