"""The chief process: owns the `ParameterStore` and serves workers over TCP.

One accept thread + one thread per worker connection; every connection thread
funnels into the store's single lock, so applies are serialized (a parameter
server is sequential at the store) while gradient COMPUTATION runs in the
worker processes — the asynchrony the scan backend only simulates.

Worker lifecycle is connection-scoped: a dropped connection (kill -9, crash)
is recorded and tolerated; a reconnect with the same wid resumes that
worker's stream (restart), a hello without a wid is assigned the next free
id (elastic join). The chief never blocks on a dead worker in live mode —
the step budget is filled by whoever is still pushing.

Robustness (DESIGN.md §14): a malformed frame — unknown verb, wrong arity,
garbage payload — no longer kills the connection thread silently (leaving
the worker wedged in recv): it is counted in `store.bad_frames` and the
connection is dropped, so the worker dies with EOF and the supervisor
respawns it. Every message a worker sends refreshes its heartbeat lease
(when the launcher runs with `spec.dist_lease_s`), and `close()` reports
any connection thread that outlives its join timeout instead of leaking it
silently.
"""
from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.dist import protocol
from repro.dist.store import ParameterStore


class Chief:
    """Listener + connection threads around one ParameterStore."""

    def __init__(self, store: ParameterStore, meta: dict, host: str = protocol.DEFAULT_HOST,
                 port: int = 0, authkey: bytes = protocol.AUTHKEY,
                 leases=None, chaos_resets=()):
        self.store = store
        self.meta = meta
        self._authkey = authkey
        self.leases = leases                       # resilience.LeaseTable | None
        self._chaos_resets = tuple(chaos_resets)   # ((wid, at_version), ...)
        self.listener = protocol.listen(host, port, authkey)
        self.address = self.listener.address
        self._threads: list = []
        self._next_wid = int(meta.get("n_workers", 0))
        self._lock = threading.Lock()   # guards _next_wid/_threads/_fired/leaked
        self._fired_resets: set = set()
        self.leaked_threads: list = []  # populated by close() on leak
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="dist-chief-accept", daemon=True)
        self._accept_thread.start()

    # ------------------------------------------------------------- lifecycle

    def _accept_loop(self):
        while True:
            try:
                conn = self.listener.accept()
            except OSError:
                return  # listener closed
            if self._stop.is_set():
                conn.close()  # close()'s wake-up connection
                return
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="dist-chief-conn", daemon=True)
            t.start()
            with self._lock:
                self._threads.append(t)

    def close(self, timeout: float = 5.0, strict: bool = False):
        """Stop accepting, join every thread, and REPORT stragglers: a
        connection thread that outlives `timeout` is recorded in
        `leaked_threads` and warned about (raised with strict=True) — a
        silent leak here is a wedged worker connection nobody notices until
        `test_no_leaked_threads` does."""
        self._stop.set()
        # closing a listener does NOT reliably unblock an accept() parked in
        # another thread; a throwaway connection is the portable wake-up, so
        # the accept thread can observe _stop and exit instead of leaking
        try:
            protocol.connect(self.address, self._authkey, timeout=1.0).close()
        except Exception:  # refused/auth/EOF — thread already gone, fine
            pass
        try:
            self.listener.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=timeout)
        with self._lock:
            threads = list(self._threads)
        for t in threads:     # join outside the lock: _serve threads take it
            t.join(timeout=timeout)
        leaked = [t.name for t in [self._accept_thread] + threads
                  if t.is_alive()]
        if leaked:
            with self._lock:
                self.leaked_threads = list(leaked)
            msg = (f"Chief.close() leaked {len(leaked)} unjoined thread(s) "
                   f"after {timeout:.1f}s joins: {leaked} — a connection "
                   f"thread is wedged (worker stuck mid-recv?)")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)

    def _assign_wid(self, requested):
        if requested is not None:
            return int(requested)
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
        self.store.record_join()  # outside _lock: never nest it with cond
        return wid

    # --------------------------------------------------------------- serving

    def _reset_due(self, wid) -> bool:
        """True once per (wid, at_version) chaos entry when the store reached
        `at_version`: the connection thread then drops the link mid-stream."""
        if not self._chaos_resets:
            return False
        v = self.store.progress()   # before _lock: never nest it with cond
        with self._lock:
            for i, (w, at_v) in enumerate(self._chaos_resets):
                if w == wid and v >= at_v and i not in self._fired_resets:
                    self._fired_resets.add(i)
                    return True
        return False

    def _check_gradient(self, g):
        """Reject garbage payloads before they reach the store: a gradient
        must be None or array-like of the parameter shape."""
        if g is None:
            return
        arr = np.asarray(g)
        if arr.dtype == object or arr.shape != self.store.W.shape:
            raise ValueError(
                f"gradient payload has shape {arr.shape}/dtype {arr.dtype}, "
                f"expected {self.store.W.shape} float")

    def _serve(self, conn):
        store = self.store
        wid = None
        try:
            verb, requested = conn.recv()
            if verb != "hello":
                store.record_bad_frame(wid, ValueError(f"expected hello, got {verb!r}"))
                return
            wid = self._assign_wid(requested)
            if self.leases is not None:
                self.leases.touch(wid)
            conn.send(("welcome", wid, self.meta))
            while True:
                msg = conn.recv()
                if self.leases is not None:
                    self.leases.touch(wid)
                if self._reset_due(wid):
                    store.record_reset()
                    return   # drop the link: worker sees EOF, supervisor heals
                try:
                    verb = msg[0]
                    if verb == "pull":
                        grant = store.replay_pull(wid)
                        if grant is None:
                            conn.send(("done",))
                        else:
                            W, fetch_v, rows = grant
                            conn.send(("work", W, fetch_v, rows))
                    elif verb == "push":
                        _, _, g, read_v = msg
                        self._check_gradient(g)
                        conn.send(("applied", store.replay_push(wid, g, read_v)))
                    elif verb == "step":
                        _, _, g, read_v, rows, w_fetch = msg
                        self._check_gradient(g)
                        out = store.live_step(wid, g, read_v, rows, w_fetch)
                        conn.send(("done",) if out is None else ("work",) + out)
                    elif verb == "bye":
                        break
                    else:
                        raise ValueError(f"unknown verb {verb!r} from worker {wid}")
                except (EOFError, ConnectionResetError, BrokenPipeError, OSError):
                    raise      # transport death: the outer handler counts it
                except Exception as e:
                    # malformed frame (unknown verb, bad arity, garbage
                    # payload): count it and drop the connection — the worker
                    # dies with EOF and supervision takes over, instead of
                    # this thread dying silently with the worker wedged
                    store.record_bad_frame(wid, e)
                    return
        except (EOFError, ConnectionResetError, BrokenPipeError, OSError):
            # worker died mid-stream (kill/crash): tolerated, counted
            store.record_worker_exit()
        finally:
            if self.leases is not None and wid is not None:
                self.leases.drop(wid)
            try:
                conn.close()
            except OSError:
                pass
