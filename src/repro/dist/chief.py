"""The chief process: owns the `ParameterStore` and serves workers over TCP.

One accept thread + one thread per worker connection; every connection thread
funnels into the store's single lock, so applies are serialized (a parameter
server is sequential at the store) while gradient COMPUTATION runs in the
worker processes — the asynchrony the scan backend only simulates.

Worker lifecycle is connection-scoped: a dropped connection (kill -9, crash)
is recorded and tolerated; a reconnect with the same wid resumes that
worker's stream (restart), a hello without a wid is assigned the next free
id (elastic join). The chief never blocks on a dead worker in live mode —
the step budget is filled by whoever is still pushing.
"""
from __future__ import annotations

import threading

from repro.dist import protocol
from repro.dist.store import ParameterStore


class Chief:
    """Listener + connection threads around one ParameterStore."""

    def __init__(self, store: ParameterStore, meta: dict, host: str = protocol.DEFAULT_HOST,
                 port: int = 0, authkey: bytes = protocol.AUTHKEY):
        self.store = store
        self.meta = meta
        self._authkey = authkey
        self.listener = protocol.listen(host, port, authkey)
        self.address = self.listener.address
        self._threads: list = []
        self._next_wid = int(meta.get("n_workers", 0))
        self._lock = threading.Lock()   # guards _next_wid and _threads
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="dist-chief-accept", daemon=True)
        self._accept_thread.start()

    # ------------------------------------------------------------- lifecycle

    def _accept_loop(self):
        while True:
            try:
                conn = self.listener.accept()
            except OSError:
                return  # listener closed
            if self._stop.is_set():
                conn.close()  # close()'s wake-up connection
                return
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="dist-chief-conn", daemon=True)
            t.start()
            with self._lock:
                self._threads.append(t)

    def close(self):
        self._stop.set()
        # closing a listener does NOT reliably unblock an accept() parked in
        # another thread; a throwaway connection is the portable wake-up, so
        # the accept thread can observe _stop and exit instead of leaking
        try:
            protocol.connect(self.address, self._authkey, timeout=1.0).close()
        except Exception:  # refused/auth/EOF — thread already gone, fine
            pass
        try:
            self.listener.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=5.0)
        with self._lock:
            threads = list(self._threads)
        for t in threads:     # join outside the lock: _serve threads take it
            t.join(timeout=5.0)

    def _assign_wid(self, requested):
        if requested is not None:
            return int(requested)
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
        self.store.record_join()  # outside _lock: never nest it with cond
        return wid

    # --------------------------------------------------------------- serving

    def _serve(self, conn):
        store = self.store
        wid = None
        try:
            verb, requested = conn.recv()
            if verb != "hello":
                conn.close()
                return
            wid = self._assign_wid(requested)
            conn.send(("welcome", wid, self.meta))
            while True:
                msg = conn.recv()
                verb = msg[0]
                if verb == "pull":
                    grant = store.replay_pull(wid)
                    if grant is None:
                        conn.send(("done",))
                    else:
                        W, fetch_v, rows = grant
                        conn.send(("work", W, fetch_v, rows))
                elif verb == "push":
                    _, _, g, read_v = msg
                    conn.send(("applied", store.replay_push(wid, g, read_v)))
                elif verb == "step":
                    _, _, g, read_v, rows, w_fetch = msg
                    out = store.live_step(wid, g, read_v, rows, w_fetch)
                    conn.send(("done",) if out is None else ("work",) + out)
                elif verb == "bye":
                    break
                else:
                    raise ValueError(f"unknown verb {verb!r} from worker {wid}")
        except (EOFError, ConnectionResetError, BrokenPipeError, OSError):
            # worker died mid-stream (kill/crash): tolerated, counted
            store.record_worker_exit()
        finally:
            try:
                conn.close()
            except OSError:
                pass
