"""Worker process: `python -m repro.dist.worker --addr host:port [--wid N]`.

Pure numpy gradient computation (the literal `LogisticRegression.grad`
arithmetic, shared via `repro.dist.store.grad`) — a worker never touches jax,
so replay-mode runs reproduce the float64 reference trajectory and process
startup stays cheap. Everything a worker needs arrives in the chief's
`welcome` meta: the training set, batch size, lr, its rng seed, the compute
-time topology, and the execution mode.

Two loops:

  * replay — request/compute/push against the chief's scheduled grants. The
    chief decides which batch, at which fetch version; the worker's only job
    is to really compute the gradient in its own process.
  * live — free-running ASGD: sample a batch from this worker's strided
    shard, optionally sleep a sampled compute time (topology * time_scale,
    the fault injector's per-worker slowdown knob), push with the read
    version of the params the gradient was computed at. With
    `delayed_avg` (DaSGD-style) the worker overlaps the push RTT with the
    NEXT gradient at its optimistically-updated local params, then merges
    the server reply: W = (W_local + W_server) / 2. Each gradient carries
    the read version current AT ITS COMPUTE TIME, so observed staleness
    stays honest under the overlap.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro.dist import protocol
from repro.dist.store import _aug, grad


def _sample_rows(shard, bs, rng):
    replace = len(shard) < bs
    return np.asarray(rng.choice(shard, size=bs, replace=replace), np.int32)


def _chaos_at(chaos: dict, kind: str, wid: int):
    """Version threshold at which fault `kind` fires for this worker, or None.
    Keys may arrive as str or int depending on how the plan was serialized."""
    table = chaos.get(kind) or {}
    for k, v in table.items():
        if int(k) == wid:
            return int(v)
    return None


def run_replay(conn, wid: int, meta: dict):
    Xa = _aug(np.asarray(meta["Xtr"], np.float64))
    y = np.asarray(meta["ytr"])
    while True:
        conn.send(("pull", wid))
        msg = conn.recv()
        if msg[0] == "done":
            break
        _, W, fetch_v, rows = msg
        g = grad(W, Xa[rows], y[rows])
        conn.send(("push", wid, g, fetch_v))
        conn.recv()  # ("applied", staleness)
    conn.send(("bye", wid))


def run_live(conn, wid: int, meta: dict):
    from repro.common.topologies import compute_time_sampler

    Xa = _aug(np.asarray(meta["Xtr"], np.float64))
    y = np.asarray(meta["ytr"])
    bs = meta["bs"]
    lr = meta["lr"]
    need_fetch = meta["need_fetch"]
    delayed_avg = meta["delayed_avg"]
    time_scale = meta["time_scale"]
    sampler = compute_time_sampler(meta["topology"])
    shard = np.arange(wid % max(meta["n_workers"], 1), len(y), max(meta["n_workers"], 1))
    rng = np.random.default_rng(meta["seed"] * 9973 + wid)

    # chaos injections (repro.chaos): thresholds are store versions, so the
    # faults fire mid-run, after the sentinel's norm EMA has warmed up
    chaos = meta.get("chaos") or {}
    nan_at = _chaos_at(chaos, "nan_grad", wid)
    boom_at = _chaos_at(chaos, "boom_grad", wid)
    corrupt_at = _chaos_at(chaos, "corrupt_frame", wid)
    corrupt_fired = False

    def compute(W, read_v):
        rows = _sample_rows(shard, bs, rng)
        if time_scale:
            time.sleep(sampler(wid, rng) * time_scale)
        return grad(W, Xa[rows], y[rows]), rows, W, read_v

    # bootstrap pull
    conn.send(("step", wid, None, 0, None, None))
    msg = conn.recv()
    if msg[0] == "done":
        conn.send(("bye", wid))
        return
    _, W, read_v = msg
    pending = None
    while True:
        g, rows, w_at, rv = pending if pending is not None else compute(W, read_v)
        pending = None
        if nan_at is not None and rv >= nan_at:
            g = g + np.nan        # sick worker: every push non-finite
        elif boom_at is not None and rv >= boom_at:
            g = g * 1e12          # finite but divergent: slips a finite-only
            #                       screen, trips the DivergenceDetector
        if corrupt_at is not None and not corrupt_fired and rv >= corrupt_at:
            corrupt_fired = True
            conn.send((b"\xde\xad", wid))   # garbage frame, not a verb
            conn.recv()   # chief drops the link -> EOFError -> process dies
        conn.send(("step", wid, g, rv, rows, w_at if need_fetch else None))
        if delayed_avg:
            # optimistic local step, then overlap the RTT with the next grad
            W = W - lr * g
            pending = compute(W, read_v)
        msg = conn.recv()
        if msg[0] == "done":
            break
        _, W_srv, v = msg
        W = 0.5 * (W + W_srv) if delayed_avg else W_srv
        read_v = v
    conn.send(("bye", wid))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="repro.dist worker process")
    ap.add_argument("--addr", required=True, help="chief address host:port")
    ap.add_argument("--wid", type=int, default=None,
                    help="worker id (omit to join elastically)")
    args = ap.parse_args(argv)

    authkey = os.environ.get("REPRO_DIST_AUTHKEY", "").encode() or protocol.AUTHKEY
    conn = protocol.connect(protocol.parse_addr(args.addr), authkey=authkey)
    try:
        conn.send(("hello", args.wid))
        verb, wid, meta = conn.recv()
        if verb != "welcome":
            raise RuntimeError(f"expected welcome, got {verb!r}")
        if meta["mode"] == "replay":
            run_replay(conn, wid, meta)
        else:
            run_live(conn, wid, meta)
    finally:
        try:
            conn.close()
        except OSError:
            pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
