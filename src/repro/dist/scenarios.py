"""Fault-injection scenarios for the live async backend.

A `Scenario` is everything that makes a live run deviate from the happy path:
per-worker slowdowns (the compute-time topology scaled to wall-clock via
`time_scale`), dropped updates (the chief discards a seeded fraction of
pushes), and versioned lifecycle events — kill a worker's process when the
store reaches a version, restart it, or join a fresh elastic worker. Events
are keyed on store VERSION, not wall time, so scenarios are loosely
reproducible across machines of different speed.

The launcher polls the store and fires due events; the chief/store tolerate
every one of them by construction (a dead connection is counted, a reconnect
resumes the wid, the step budget is filled by whoever still pushes), which is
exactly the property the CI smoke job locks in.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative fault plan for one live run (see spec.DIST_EVENT_OPS)."""

    drop_rate: float = 0.0       # fraction of pushes the chief discards
    time_scale: float = 0.0      # seconds per sampled compute-time unit
    events: Tuple = ()           # ((op, wid, at_version), ...), version-sorted

    @classmethod
    def from_spec(cls, spec) -> "Scenario":
        return cls(
            drop_rate=spec.dist_drop_rate,
            time_scale=spec.dist_time_scale,
            events=tuple(sorted(spec.dist_events, key=lambda ev: ev[2])),
        )

    def due(self, fired: int, version: int):
        """Events [fired:] whose trigger version has been reached."""
        out = []
        for ev in self.events[fired:]:
            if version >= ev[2]:
                out.append(ev)
            else:
                break
        return out
