"""Wire protocol of the async parameter server (`repro.dist`).

Transport is `multiprocessing.connection` over TCP: length-framed, pickled,
HMAC-authenticated (AUTHKEY) — the stdlib's process-to-process channel, so the
subsystem adds no dependency and runs anywhere `JAX_PLATFORMS=cpu` does.
Messages are plain tuples whose first element is the verb:

  worker -> chief                         chief -> worker
  ("hello", wid|None)                     ("welcome", wid, meta)
  ("pull", wid)              [replay]     ("work", W, fetch_version, rows)
                                          | ("done",)
  ("push", wid, g, read_v)   [replay]     ("applied", staleness)
  ("step", wid, g|None,      [live]       ("work", W, version)
      read_v, rows|None,                  | ("done",)
      w_fetch|None)
  ("bye", wid)                            (connection closed)

`meta` carries everything a worker needs to run headless: the training shard
(Xtr, ytr), batch size, lr, its rng seed, the scenario's compute-time
topology + time scale, whether the chief's strategy needs the fetched params
shipped back (`need_fetch` — DC-ASGD / Gap-Aware compensate against W_stale),
and the execution mode. Workers are deliberately numpy-only: gradient math is
the literal `LogisticRegression` arithmetic, so a replay-mode run reproduces
the train_ps/scan trajectory to float64 round-off.

In replay mode `read_v` IS the scheduled fetch version the chief granted; in
live mode it is the version of the last server params the worker merged, and
the chief's `applied_version - read_v` is the *observed* staleness.
"""
from __future__ import annotations

import random
import socket
import time
from multiprocessing.connection import Client, Listener

# Shared secret for the HMAC challenge of multiprocessing.connection: this
# authenticates peers (no unpickling from strangers) for processes WE spawn
# on one host; multi-host deployments should rotate it via REPRO_DIST_AUTHKEY.
AUTHKEY = b"repro-dist-ps-v1"

DEFAULT_HOST = "127.0.0.1"


def parse_addr(addr: str) -> tuple:
    """'host:port' -> (host, int(port))."""
    host, _, port = addr.rpartition(":")
    return (host or DEFAULT_HOST, int(port))


def format_addr(addr: tuple) -> str:
    return f"{addr[0]}:{addr[1]}"


def listen(host: str = DEFAULT_HOST, port: int = 0, authkey: bytes = AUTHKEY) -> Listener:
    """Bind the chief's listener. port=0 picks an ephemeral port; the bound
    address is `listener.address`."""
    return Listener((host, port), family="AF_INET", authkey=authkey)


def connect(addr: tuple, authkey: bytes = AUTHKEY, timeout: float = 20.0,
            backoff_base: float = 0.02, backoff_cap: float = 1.0):
    """Connect to the chief, retrying while it boots (worker processes race
    the listener's bind, and a respawned worker races the chief's recovery).

    Retries back off exponentially from `backoff_base` up to `backoff_cap`
    seconds with full jitter — a respawning fleet must not hammer the
    listener in lockstep. On timeout the last transport error is re-raised
    wrapped in a ConnectionError recording elapsed time and attempt count.
    """
    deadline = time.monotonic() + timeout
    start = time.monotonic()
    attempts = 0
    delay = backoff_base
    while True:
        try:
            return Client(addr, family="AF_INET", authkey=authkey)
        except (ConnectionRefusedError, socket.timeout, OSError) as e:
            attempts += 1
            now = time.monotonic()
            if now >= deadline:
                raise ConnectionError(
                    f"could not connect to chief at {format_addr(addr)} "
                    f"after {attempts} attempts over {now - start:.1f}s "
                    f"(last error: {type(e).__name__}: {e})") from e
            # full jitter: sleep U(0, delay], never past the deadline
            time.sleep(min(random.random() * delay + 1e-3, deadline - now))
            delay = min(delay * 2, backoff_cap)
