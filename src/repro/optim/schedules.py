"""Learning-rate schedules. WSD (warmup-stable-decay) is the schedule MiniCPM
(arXiv:2404.06395) trains with; included because minicpm-2b is an assigned arch."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f


def wsd(lr: float, warmup: int, stable: int, decay: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish decay."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = step > (warmup + stable)
        prog = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = lr * jnp.power(final_frac, prog)
        return jnp.where(step < warmup, warm, jnp.where(in_decay, dec, lr))

    return f


# canonical name list lives in repro.engine.spec (jax-free, so the spec and
# the launcher's argparse choices validate without importing this module)
from repro.engine.spec import SCHEDULES  # noqa: E402


def for_run(name: str, lr: float, warmup: int, n_steps: int):
    """Resolve a schedule name for a run of `n_steps` total steps, with the
    phases partitioning the run. For wsd the decay phase is the back (ceil)
    half of the post-warmup budget, so warmup + stable + decay == n_steps and
    the decay actually reaches final_frac by the end of the run (the old
    wiring passed stable = decay = n_steps // 2, overrunning by `warmup`
    steps — the run ended before the decay finished)."""
    if name == "constant":
        return constant(lr)
    if name == "cosine":
        return cosine(lr, warmup, n_steps)
    if name == "wsd":
        rem = max(n_steps - warmup, 0)
        stable = rem // 2
        decay = rem - stable
        return wsd(lr, warmup, stable, decay)
    raise ValueError(f"unknown schedule {name!r}; known: {', '.join(SCHEDULES)}")
