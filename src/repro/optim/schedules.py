"""Learning-rate schedules. WSD (warmup-stable-decay) is the schedule MiniCPM
(arXiv:2404.06395) trains with; included because minicpm-2b is an assigned arch."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f


def wsd(lr: float, warmup: int, stable: int, decay: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish decay."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = step > (warmup + stable)
        prog = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = lr * jnp.power(final_frac, prog)
        return jnp.where(step < warmup, warm, jnp.where(in_decay, dec, lr))

    return f
