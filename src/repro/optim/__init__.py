from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adagrad,
    adam,
    get_optimizer,
    momentum,
    rmsprop,
    sgd,
)
from repro.optim.schedules import SCHEDULES, constant, cosine, for_run, wsd  # noqa: F401
