"""Pure pytree optimizers (optax is not on the image).

Each optimizer is an `Optimizer(init, update)` pair:
  state = opt.init(params)
  updates, state = opt.update(grads, state, params, lr)
  params = tree_add(params, updates)          # updates already include -lr

The RMSprop/Adagrad variants match the paper's Section 5 definitions exactly
(Fig. 11): rmsprop uses r_t = beta*r_{t-1} + (1-beta)*v_t^2, eps inside sqrt.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)
    name: str = ""
    # the factory's hyperparameters, exposed so the fused whole-update kernels
    # (repro.kernels.guided_update.ops.fused_update_for) can bake the SAME
    # values the closures use; None means "unknown" and disables fusion
    hypers: dict = None


def _zeros(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return jax.tree.map(lambda g: (-lr * g.astype(jnp.float32)).astype(g.dtype), grads), state

    return Optimizer(init, update, "sgd", {})


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": _zeros(params)}

    def update(grads, state, params, lr):
        m = jax.tree.map(lambda mi, g: beta * mi + g.astype(jnp.float32), state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda mi, g: -(lr * (beta * mi + g.astype(jnp.float32))), m, grads)
        else:
            upd = jax.tree.map(lambda mi: -lr * mi, m)
        return upd, {"m": m}

    return Optimizer(init, update, "momentum", {"beta": beta, "nesterov": nesterov})


def rmsprop(beta: float = 0.9, eps: float = 1e-8) -> Optimizer:
    """Paper Fig. 11: r_t = beta r_{t-1} + (1-beta) v_t^2; W -= eta v/sqrt(r+eps)."""

    def init(params):
        return {"r": _zeros(params)}

    def update(grads, state, params, lr):
        r = jax.tree.map(
            lambda ri, g: beta * ri + (1 - beta) * jnp.square(g.astype(jnp.float32)), state["r"], grads
        )
        upd = jax.tree.map(lambda g, ri: -lr * g.astype(jnp.float32) / jnp.sqrt(ri + eps), grads, r)
        return upd, {"r": r}

    return Optimizer(init, update, "rmsprop", {"beta": beta, "eps": eps})


def adagrad(eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"r": _zeros(params)}

    def update(grads, state, params, lr):
        r = jax.tree.map(lambda ri, g: ri + jnp.square(g.astype(jnp.float32)), state["r"], grads)
        upd = jax.tree.map(lambda g, ri: -lr * g.astype(jnp.float32) / jnp.sqrt(ri + eps), grads, r)
        return upd, {"r": r}

    return Optimizer(init, update, "adagrad", {"eps": eps})


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _zeros(params), "v": _zeros(params), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mi, vi, p):
            step = mi / bc1 / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adam", {"b1": b1, "b2": b2, "eps": eps, "weight_decay": weight_decay})


_REGISTRY = {
    "sgd": sgd,
    "momentum": momentum,
    "rmsprop": rmsprop,
    "adagrad": adagrad,
    "adam": adam,
}


def get_optimizer(name: str, **kw) -> Optimizer:
    return _REGISTRY[name](**kw)
