"""Synthetic LM / multimodal data pipeline.

Deterministic, host-shardable batch generators for the large-model trainer and
examples. The token stream has learnable structure (an order-1 Markov chain
over a Zipf vocabulary) so training loss actually decreases — important for the
end-to-end example and the guided-consistency integration tests. Worker shards
draw from differently-mixed corpora so per-worker losses genuinely differ (the
signal the paper's consistency statistic keys on).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def _markov_tables(vocab: int, n_corpora: int, seed: int):
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, size=vocab * 4) % vocab
    tables = []
    for c in range(n_corpora):
        # sparse successor table: each token has a few likely successors
        succ = rng.integers(0, vocab, size=(vocab, 4))
        tables.append(succ)
    return tables


def synthetic_lm_batches(
    vocab: int,
    seq_len: int,
    global_batch: int,
    *,
    seed: int = 0,
    n_corpora: int = 0,
    noise: float = 0.1,
) -> Iterator[dict]:
    """Yields {"tokens", "labels"} with labels = next-token shift."""
    n_corpora = n_corpora or max(1, global_batch // 8)
    tables = _markov_tables(vocab, n_corpora, seed)
    rng = np.random.default_rng(seed + 1)
    step = 0
    while True:
        toks = np.empty((global_batch, seq_len + 1), np.int32)
        for b in range(global_batch):
            succ = tables[b % n_corpora]
            t = rng.integers(0, vocab)
            row = np.empty(seq_len + 1, np.int32)
            for s in range(seq_len + 1):
                row[s] = t
                if rng.random() < noise:
                    t = rng.integers(0, vocab)
                else:
                    t = succ[t, rng.integers(0, succ.shape[1])]
            toks[b] = row
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


def make_batch_for(cfg, seq_len: int, global_batch: int, seed: int = 0) -> dict:
    """One synthetic batch with the right structure for any assigned arch."""
    rng = np.random.default_rng(seed)
    if cfg.audio_frontend:
        mask = rng.random((global_batch, seq_len)) < 0.08
        return {
            "frames": rng.standard_normal((global_batch, seq_len, cfg.d_model)).astype(np.float32),
            "mask_positions": mask,
            "labels": rng.integers(0, cfg.vocab_size, (global_batch, seq_len)).astype(np.int32),
            "mask": mask.astype(np.float32),
        }
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (global_batch, seq_len)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (global_batch, seq_len)).astype(np.int32),
    }
    if cfg.arch_type == "vlm" and cfg.n_patches:
        batch["patches"] = rng.standard_normal((global_batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    return batch
