"""Statistically-matched synthetic analogs of the paper's 9 UCI datasets.

The UCI files are not redistributable on this offline image, so each dataset is
generated with the same n_examples, n_features, n_classes and class balance as
the original, with separability/noise calibrated so a sequential-SGD logistic
regression lands near the paper's Table 2/3 accuracy. The paper's *relative*
claims (gSSGD > SSGD, etc.) are what EXPERIMENTS.md validates — see DESIGN.md.

Also implements the paper's preprocessing: statistical IQR outlier filtering
(applied to the 'pima*' and 'liver*' variants, as in Section 5.1).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class TabularSpec:
    name: str
    n: int
    d: int
    classes: int
    priors: tuple
    sep: float          # inter-class mean distance (in feature-noise units)
    flip: float         # label flip fraction (irreducible noise)
    outlier_frac: float # fraction of rows with heavy-tailed feature noise
    paper_sgd_acc: float  # Table 3 average SGD accuracy (calibration target)


SPECS = {
    "pima": TabularSpec("pima", 768, 8, 2, (0.65, 0.35), 3.3, 0.10, 0.08, 76.1),
    "breast_cancer_diagnostic": TabularSpec("breast_cancer_diagnostic", 569, 30, 2, (0.63, 0.37), 8.5, 0.01, 0.02, 95.8),
    "haberman": TabularSpec("haberman", 306, 3, 2, (0.74, 0.26), 2.2, 0.13, 0.05, 74.6),
    "liver": TabularSpec("liver", 345, 6, 2, (0.58, 0.42), 2.4, 0.15, 0.10, 64.9),
    "new_thyroid": TabularSpec("new_thyroid", 215, 5, 3, (0.70, 0.16, 0.14), 5.5, 0.02, 0.03, 92.4),
    "cancer": TabularSpec("cancer", 699, 9, 2, (0.66, 0.34), 8.0, 0.01, 0.02, 97.8),
    "phishing": TabularSpec("phishing", 2456, 30, 2, (0.56, 0.44), 8.0, 0.08, 0.04, 82.2),
}

# the paper's 9 rows: two of them are IQR-filtered variants
DATASETS = [
    "pima",
    "pima_filtered",
    "breast_cancer_diagnostic",
    "haberman",
    "liver",
    "liver_filtered",
    "new_thyroid",
    "cancer",
    "phishing",
]


# Conditioning structure shared by all analogs. UCI tabular data is used RAW in
# the paper ("no preprocessing"), i.e. features have wildly different scales.
# That conditioning is what makes the parallel-SGD delay measurable at all:
#   * "stiff" UNINFORMATIVE dims (large scale, no class signal): their optimal
#     weight is 0, but under the parallel effective step eta*c the weights
#     oscillate around 0 with amplitude ~ eta*c -> logit noise -> the smooth,
#     rho-proportional accuracy damage of Figs. 12-13 ("long jump" victims);
#   * "slow" informative dims (small scale): converge slowly at lr 0.2 in the
#     50-epoch budget -> the paper's O(1/(cT)) undertraining term, and what the
#     guided replay's extra verified-consistent updates recover (Fig. 14).
# Values chosen once, globally (not per-dataset): see EXPERIMENTS.md §Paper.
S_STIFF = 3.0
S_SLOW = 0.12


def _generate(spec: TabularSpec, seed: int):
    rng = np.random.default_rng(seed)
    counts = (np.asarray(spec.priors) * spec.n).astype(int)
    counts[0] += spec.n - counts.sum()
    # class-conditional gaussians on a random low-rank structure + noise dims
    informative = max(2, (2 * spec.d) // 3)
    X, y = [], []
    # orthonormal class-mean directions (deterministic geometry: calibration is
    # monotone in `sep`, independent of the seed's random mean placement)
    raw = rng.standard_normal((informative, max(spec.classes, 2)))
    q, _ = np.linalg.qr(raw)
    means = q[:, : spec.classes].T * spec.sep
    for k, nk in enumerate(counts):
        Xi = rng.standard_normal((nk, spec.d))
        Xi[:, :informative] += means[k]
        X.append(Xi)
        y.append(np.full(nk, k))
    X = np.concatenate(X)
    y = np.concatenate(y)
    # heavy-tailed outliers (what the IQR filter is for)
    n_out = int(spec.outlier_frac * spec.n)
    if n_out:
        rows = rng.choice(spec.n, n_out, replace=False)
        X[rows] += rng.standard_t(1.5, size=(n_out, spec.d)) * 4.0
    # label flips (irreducible noise)
    n_flip = int(spec.flip * spec.n)
    if n_flip:
        rows = rng.choice(spec.n, n_flip, replace=False)
        y[rows] = (y[rows] + rng.integers(1, spec.classes, n_flip)) % spec.classes
    # raw-UCI-like heterogeneous conditioning (NO standardization; see above)
    X[:, :informative] *= S_SLOW
    X[:, informative:] *= S_STIFF
    perm = rng.permutation(spec.n)
    X, y = X[perm], y[perm]
    return X.astype(np.float64), y.astype(np.int64)


def iqr_filter(X, y):
    """Statistical inter-quartile-range outlier removal (paper Section 5.1,
    via WEKA's InterquartileRange): drop rows with any feature outside
    [Q1 - 1.5 IQR, Q3 + 1.5 IQR]."""
    q1 = np.percentile(X, 25, axis=0)
    q3 = np.percentile(X, 75, axis=0)
    iqr = q3 - q1
    lo, hi = q1 - 1.5 * iqr, q3 + 1.5 * iqr
    keep = np.all((X >= lo) & (X <= hi), axis=1)
    return X[keep], y[keep]


def load_dataset(name: str, seed: int = 0):
    """Returns (X, y, n_classes). '<base>_filtered' applies the IQR filter."""
    base = name.removesuffix("_filtered")
    spec = SPECS[base]
    X, y = _generate(spec, seed=(zlib.crc32(base.encode()) + 7919 * seed) % (2**31))
    if name.endswith("_filtered"):
        X, y = iqr_filter(X, y)
    return X, y, spec.classes


def train_test_split(X, y, test_frac: float = 0.2, seed: int = 0):
    """Paper Table 1: training:testing = 80:20 (stratified by class so the
    small minority classes, e.g. new-thyroid's, appear in every test fold)."""
    rng = np.random.default_rng(seed)
    te_idx = []
    for k in np.unique(y):
        rows = np.flatnonzero(y == k)
        rows = rows[rng.permutation(len(rows))]
        te_idx.append(rows[: max(1, int(test_frac * len(rows)))])
    te = np.concatenate(te_idx)
    mask = np.ones(len(X), bool)
    mask[te] = False
    tr = np.flatnonzero(mask)
    tr = tr[rng.permutation(len(tr))]
    return X[tr], y[tr], X[te], y[te]
