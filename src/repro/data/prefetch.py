"""Async double-buffered host->device batch staging (`repro.data.prefetch`).

The chunked mesh trainer (DESIGN.md §9) dispatches K fused train steps per
jit call; this module keeps that dispatch fed. Two pieces:

  * `stack_blocks` turns a per-step batch stream into pre-stacked `(K, ...)`
    numpy blocks following a chunk schedule. It is a plain generator, so the
    *generation* cost (the synthetic corpus samplers are Python loops) runs
    wherever the generator is consumed — inline in the fit loop, or on the
    prefetch worker thread, where it overlaps the in-flight chunk.
  * `ChunkPrefetcher` is the double buffer: a daemon worker thread pulls
    host-side blocks from the source, commits them to device with
    `jax.device_put` against the data-shard sharding (`batch_put`), and parks
    them in a bounded queue (depth 2: block i+1 stages while chunk i
    computes). Neither batch generation nor the H2D copy ever sits on the
    dispatch critical path.

Both are backend-agnostic: the "blocks" are arbitrary pytrees, so the same
prefetcher stages single per-step batches when `chunk_steps=1`.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional, Sequence

import numpy as np


def stack_blocks(batches: Iterator[dict], sizes: Sequence[int]) -> Iterator[dict]:
    """Stack consecutive per-step batches into `(K, ...)` numpy blocks.

    `sizes[i]` batches are consumed from `batches` for block i — the chunk
    schedule of the fit loop (`trainloop.chunk_schedule`). The per-step stream
    is consumed in order and unmodified: unstacking the blocks reproduces it
    exactly (tests/test_trainloop.py locks this in).
    """
    for k in sizes:
        rows = []
        for _ in range(k):
            try:
                rows.append(next(batches))
            except StopIteration:
                raise ValueError(
                    f"data stream exhausted mid-chunk (got {len(rows)} of {k} "
                    f"batches); a chunked fit needs n_steps batches — pass a "
                    f"long-enough stream or lower spec.steps") from None
        yield {key: np.stack([np.asarray(r[key]) for r in rows])
               for key in rows[0]}


def batch_put(ctx, stacked: bool) -> Callable:
    """Leaf-wise device placement for (stacked) batches on `ctx`.

    On a distributed ShardCtx the batch dimension — axis 1 of a stacked
    `(K, B, ...)` block, axis 0 of a per-step batch — is committed against the
    data axes, so the H2D transfer lands each worker's shard directly on its
    devices; everything else replicates. On the local (meshless) ctx this is
    a plain transfer, byte-identical to the `jnp.asarray` staging it replaces.
    """
    import jax
    import jax.numpy as jnp

    if not getattr(ctx, "distributed", False):
        return lambda tree: jax.tree.map(jnp.asarray, tree)

    from jax.sharding import NamedSharding, PartitionSpec

    bdim = 1 if stacked else 0
    axes = tuple(a for a in ctx.data_axes if a in ctx.mesh.shape)
    n_shards = int(np.prod([ctx.mesh.shape[a] for a in axes])) if axes else 1

    def one(x):
        spec = [None] * np.ndim(x)
        if axes and np.ndim(x) > bdim and x.shape[bdim] % n_shards == 0:
            spec[bdim] = axes if len(axes) > 1 else axes[0]
        return jax.device_put(x, NamedSharding(ctx.mesh, PartitionSpec(*spec)))

    return lambda tree: jax.tree.map(one, tree)


class ChunkPrefetcher:
    """Double-buffered async host->device staging of a batch/block stream.

    A daemon worker thread iterates `source`, applies `put` (device placement;
    defaults to `jax.device_put`) and parks the committed arrays in a bounded
    queue. Iterating the prefetcher yields device-resident items in order;
    an exception raised by the source or the transfer re-raises at the
    consuming end. `close()` is idempotent and safe mid-stream (the SIGTERM
    drain path): it unblocks and joins the worker without consuming the rest
    of the source.
    """

    _DONE = object()

    def __init__(self, source: Iterable, put: Optional[Callable] = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1 (got {depth})")
        if put is None:
            import jax

            put = jax.device_put
        self._put = put
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._lock = threading.Lock()   # guards _err (worker writes, consumer reads)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._work, args=(iter(source),),
            name="chunk-prefetch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- worker
    def _work(self, it: Iterator) -> None:
        try:
            while not self._stop.is_set():
                try:
                    item = next(it)
                except StopIteration:
                    break
                self._offer(self._put(item))
        except BaseException as e:  # surfaced from __next__, not swallowed
            with self._lock:
                self._err = e
        self._offer(self._DONE)

    def _offer(self, item) -> None:
        """put() that close() can always unblock."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    # ----------------------------------------------------------- consumer
    def __iter__(self) -> "ChunkPrefetcher":
        return self

    def __next__(self):
        while True:
            try:
                item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if not self._thread.is_alive():
                    # worker gone without the sentinel landing (e.g. the
                    # queue was drained by close()): treat as end-of-stream
                    item = self._DONE
                    break
        if item is self._DONE:
            err = self._take_err()
            if err is not None:
                raise err
            raise StopIteration
        return item

    def _take_err(self) -> Optional[BaseException]:
        with self._lock:
            err, self._err = self._err, None
            return err

    def close(self) -> None:
        """Stop the worker and join it; pending staged items are dropped."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "ChunkPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
