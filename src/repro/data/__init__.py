from repro.data.uci_analogs import DATASETS, iqr_filter, load_dataset, train_test_split  # noqa: F401
from repro.data.tokens import synthetic_lm_batches, make_batch_for  # noqa: F401
from repro.data.prefetch import ChunkPrefetcher, batch_put, stack_blocks  # noqa: F401
