"""Mixture-of-Experts layer: top-k token-choice routing with gather-based
fixed-capacity dispatch.

Why gather-based: the classic GShard one-hot dispatch einsum costs
O(N * E * C * d) FLOPs — for qwen3 (128 experts) that is orders of magnitude
more than the expert GEMMs themselves and would poison the roofline numbers.
jax.lax.ragged_dot lowers to dense-per-expert on CPU (E x overcount). The
sort + index-gather dispatch below costs exactly the active-expert FLOPs
(3 * 2 * E * C * d * d_ff for a SwiGLU expert) plus cheap integer work, on any
backend.

Dispatch runs per data shard (wrapped in shard_map by the caller — routing is
local to each worker's tokens, as in Switch/GShard; expert weights stay sharded
over `model` as auto axes inside the region).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Param, dense_param


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    # expert dim shards over `data` when divisible (qwen3: 128, jamba: 16);
    # otherwise rules fall back to replicating it and FSDP-sharding d_model.
    p = {
        "router": Param((0.02 * jax.random.normal(ks[0], (d, E))).astype(jnp.float32), (None, None)),
        "wi": Param(
            (jax.random.normal(ks[1], (E, d, 2, f)) / np.sqrt(d)).astype(dt),
            ("expert", "fsdp", None, "tp"),
        ),
        "wo": Param(
            (jax.random.normal(ks[2], (E, f, d)) / np.sqrt(f)).astype(dt),
            ("expert", "tp", "fsdp"),
        ),
    }
    if m.d_shared_ff:
        p["shared_wi"] = dense_param(ks[3], d, (2, m.d_shared_ff), ("fsdp", None, "tp"), dt)
        p["shared_wo"] = dense_param(ks[3], m.d_shared_ff, d, ("tp", "fsdp"), dt)
    return p


def capacity(n_tokens: int, n_experts: int, topk: int, factor: float) -> int:
    c = int(np.ceil(n_tokens * topk * factor / n_experts))
    return max(4, min(c, n_tokens))


def _topk_by_argmax(probs, k: int):
    """top-k as k masked argmaxes (same values/order/tie-breaks as lax.top_k
    for small k). lax.TopK crashes the partial-manual SPMD partitioner of the
    pinned jax/XLA inside shard_map regions ("Check failed: IsManualSubgroup"),
    while argmax lowers to plain reduces that partition fine."""
    E = probs.shape[-1]
    masked = probs
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(masked, axis=-1)
        onehot = jax.nn.one_hot(i, E, dtype=jnp.bool_)
        vals.append(jnp.sum(jnp.where(onehot, probs, 0.0), axis=-1))
        idxs.append(i.astype(jnp.int32))
        masked = jnp.where(onehot, -jnp.inf, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def route(gates_logits, topk: int):
    """Returns (weights (N,k), expert_ids (N,k), probs (N,E))."""
    probs = jax.nn.softmax(gates_logits.astype(jnp.float32), axis=-1)
    w, eid = _topk_by_argmax(probs, topk)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, eid, probs


def moe_apply_local(p, x, cfg, capacity_factor=None, a2a_axes=None, n_shards=1):
    """x: (N, d) — tokens local to this data shard. Returns (y (N,d), aux loss).

    a2a_axes: when set (a tuple of manual mesh axis names), expert weights are
    expert-sharded across those axes and dispatch uses two all-to-alls (GShard
    expert parallelism) instead of gathering every expert's weights to every
    shard. This removes the dominant collective of MoE training at scale
    (EXPERIMENTS.md §Perf: qwen3 train_4k 99.8s -> sub-second collective term).
    """
    m = cfg.moe
    E, k = m.n_experts, m.topk
    N, d = x.shape
    C = capacity(N, E, k, capacity_factor or m.capacity_factor)

    gate_logits = x.astype(jnp.float32) @ p["router"]
    w, eid, probs = route(gate_logits, k)

    # ---- sort-based dispatch: slot (e, rank) for every (token, expert) pair
    flat_eid = eid.reshape(-1)                      # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(N), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_eid, stable=True)      # stable: earlier tokens win capacity
    s_eid, s_tok, s_w = flat_eid[order], flat_tok[order], flat_w[order]
    counts = jnp.bincount(flat_eid, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(N * k) - starts[s_eid]
    keep = rank < C
    slot = jnp.where(keep, s_eid * C + rank, E * C)  # overflow -> sentinel slot

    buf_tok = jnp.full((E * C + 1,), N, jnp.int32).at[slot].set(s_tok.astype(jnp.int32))[:-1]
    buf_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(s_w)[:-1]

    # ---- expert compute on gathered buffers
    # NOTE: on non-TPU backends the expert dots run in f32 — XLA CPU hard-
    # crashes ("Invalid binary instruction opcode copy") when differentiating
    # a bf16 dot through a manual-axes shard_map with auto-sharded operands.
    # On TPU the bf16 MXU path is used as intended.
    ed = jnp.float32 if jax.default_backend() != "tpu" else x.dtype
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[buf_tok].reshape(E, C, d)

    if a2a_axes:
        # GShard expert parallelism, fully-manual region (data AND model axes
        # manual — mixing a manual-axes all-to-all with an auto tensor axis
        # makes the SPMD partitioner materialize the a2a cotangent at full
        # data extent; hand-placing the Megatron psum avoids it, §Perf):
        #   a2a tokens -> local experts; wi/wo enter f-sharded over `model`;
        #   down-proj contracts the f shard -> psum over `model`.
        model_axis, n_model = a2a_axes[-1], None
        data_axes = a2a_axes[:-1]
        xe = jax.lax.all_to_all(xe, data_axes, split_axis=0, concat_axis=1, tiled=True)
        # xe: (E/n, C*n, d); p["wi"]: (E/n, d, 2, f/n_model) local shard
        h = jnp.einsum("ecd,edtf->ectf", xe.astype(ed), p["wi"].astype(ed),
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(h[..., 0, :]) * h[..., 1, :]).astype(ed)
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(ed)).astype(x.dtype)
        ye = jax.lax.all_to_all(ye, data_axes, split_axis=1, concat_axis=0, tiled=True)
        # back to (E, C, d) with this shard's own tokens. ye is still PARTIAL
        # over `model` (f-shard contributions); the psum happens after the
        # token combine, on the k*cf-times-smaller (N, d) buffer (§Perf it.3).
    else:
        h = jnp.einsum("ecd,edtf->ectf", xe.astype(ed), p["wi"].astype(ed),
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(h[..., 0, :]) * h[..., 1, :]).astype(ed)
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(ed)).astype(x.dtype)

    # ---- combine (weighted scatter-add back to token order)
    contrib = ye.reshape(E * C, d) * buf_w[:, None].astype(ye.dtype)
    y = jnp.zeros((N + 1, d), ye.dtype).at[buf_tok].add(contrib)[:-1]
    if a2a_axes:
        y = jax.lax.psum(y, a2a_axes[-1])  # model-axis reduction post-combine

    if "shared_wi" in p:
        hs = jnp.einsum("nd,dtf->ntf", x, p["shared_wi"])
        y = y + (jax.nn.silu(hs[..., 0, :]) * hs[..., 1, :]) @ p["shared_wo"]

    # ---- Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean((jax.nn.one_hot(eid, E)).sum(1), axis=0)  # (E,) ~ k*f_e
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.router_aux_weight * E * jnp.sum(frac_tokens / k * mean_prob)
    return y.astype(x.dtype), aux


def moe_apply(p, x, cfg, ctx, capacity_factor=None):
    """x: (B, S, d). shard_map over the data axes when distributed.

    Two distributed dispatch strategies (ShardCtx.moe_impl):
      "gather"   — expert weights enter the region replicated over the data
                   axes (XLA all-gathers them per use). Baseline.
      "alltoall" — expert weights stay expert-sharded over the data axes;
                   token buffers are exchanged with two all-to-alls (GShard
                   expert parallelism). Requires n_experts % n_shards == 0;
                   falls back to gather otherwise (grok: 8 experts, 16 shards).
    """
    B, S, d = x.shape

    def local(p_, x_, a2a_axes=None):
        y, aux = moe_apply_local(p_, x_.reshape(-1, d), cfg, capacity_factor, a2a_axes)
        return y.reshape(x_.shape), aux

    if not ctx.distributed:
        return local(p, x)

    from jax.sharding import PartitionSpec as P

    manual = tuple(a for a in ctx.data_axes if a in ctx.mesh.shape)
    n_shards = 1
    for a in manual:
        n_shards *= ctx.mesh.shape[a]
    if not manual or B % n_shards != 0:
        # batch not shardable over the data axes (e.g. long_500k's B=1 decode):
        # run the routing replicated; expert weights stay model-sharded (auto)
        return local(p, x)
    batch_axes = manual if len(manual) > 1 else manual[0]
    batch_spec = P(batch_axes)

    E = cfg.moe.n_experts
    model_ok = (
        ctx.model_axis in ctx.mesh.shape
        and cfg.d_ff % ctx.mesh.shape[ctx.model_axis] == 0
    )
    use_a2a = (
        getattr(ctx, "moe_impl", "gather") == "alltoall"
        and E % n_shards == 0
        and model_ok
    )
    # a2a region is manual over data axes AND the model axis (see apply_local)
    a2a_axes = manual + (ctx.model_axis,) if use_a2a else None
    region_axes = manual + ((ctx.model_axis,) if use_a2a else ())

    def local_psum(p_, x_):
        y, aux = local(p_, x_, a2a_axes)
        aux = jax.lax.psum(aux, manual) / n_shards
        return y, aux

    p_specs = jax.tree.map(lambda _: P(), p)
    if use_a2a:
        # expert dim manual-sharded over data; f dim manual-sharded over model
        p_specs = dict(p_specs)
        p_specs["wi"] = P(batch_axes, None, None, ctx.model_axis)
        p_specs["wo"] = P(batch_axes, ctx.model_axis, None)

    from repro.common.compat import shard_map

    fn = shard_map(
        local_psum,
        mesh=ctx.mesh,
        in_specs=(p_specs, P(*batch_spec, None, None)),
        out_specs=(P(*batch_spec, None, None), P()),
        axis_names=set(region_axes),
    )
    return fn(p, x)
