"""Core NN layers: RMSNorm, RoPE, GQA attention (full / sliding-window / decode),
FFN (SwiGLU / GELU), embedding and logits head.

All layer `apply` functions are pure; params are pytrees of jnp arrays (already
unboxed). Attention dispatches between the XLA einsum implementation (used for
dry-run lowering and CPU tests) and the Pallas kernels in repro.kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Param, dense_param


# ------------------------------------------------------------------- norms


def rmsnorm_init(d: int, dtype=jnp.float32) -> Param:
    return Param(jnp.ones((d,), dtype), (None,))


def rmsnorm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


# -------------------------------------------------------------------- rope


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, d_head); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d_head, theta))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


NEG_INF = -1e30


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,K,G,dh), k/v: (B,Skv,K,dh), mask: broadcastable (B,1,1,Sq,Skv)."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def _full_attention_xla(q, k, v, *, causal: bool, q_offset, scale):
    B, Sq, K, G, dh = q.shape
    Skv = k.shape[1]
    if causal:
        qi = q_offset + jnp.arange(Sq)
        kj = jnp.arange(Skv)
        mask = (qi[:, None] >= kj[None, :])[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, Sq, Skv), bool)
    return _sdpa(q, k, v, mask, scale)


def _swa_blocked_xla(q, k, v, *, window: int, scale):
    """Exact sliding-window causal attention, computed block-locally so the
    lowered FLOPs reflect the banded structure (each query block of size W
    attends only to itself + the previous block), not the dense S^2 einsum."""
    B, S, K, G, dh = q.shape
    W = window
    assert S % W == 0, (S, W)
    nb = S // W
    qb = q.reshape(B, nb, W, K, G, dh)
    kb = k.reshape(B, nb, W, K, dh)
    vb = v.reshape(B, nb, W, K, dh)
    zpad = jnp.zeros_like(kb[:, :1])
    k_prev = jnp.concatenate([zpad, kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2W, K, dh)
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    i = jnp.arange(W)
    j = jnp.arange(2 * W)
    # key j in [0,W) is previous block: valid iff j-W... local prev index jp=j:
    # global delta = W + i - j (prev)  -> valid iff 0 < W+i-j <= ... j > i
    # current block j' = j-W: valid iff j-W <= i (causal) and i-(j-W) < W (always)
    mask = jnp.where(j[None, :] < W, j[None, :] > i[:, None], (j[None, :] - W) <= i[:, None])
    first_block_mask = jnp.where(j[None, :] < W, False, (j[None, :] - W) <= i[:, None])
    full_mask = jnp.broadcast_to(mask, (nb, W, 2 * W)).at[0].set(first_block_mask)
    full_mask = full_mask[None, :, None, None, :, :]  # (1, nb, 1, 1, W, 2W)

    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2, preferred_element_type=jnp.float32) * scale
    logits = jnp.where(full_mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs, v2)
    return out.reshape(B, S, K, G, dh)


def _chunked_attention_xla(qg, k, v, *, causal: bool, scale, chunk: int = 1024):
    """Flash-style online-softmax attention as a lax.scan over KV chunks.

    Never materializes the (Sq, Skv) score matrix in HBM — the per-chunk
    working set is O(Sq * chunk). This is the pure-XLA analog of the Pallas
    flash kernel, used for long-sequence prefill where the dense einsum's
    S^2 f32 buffer dominates the memory roofline term (§Perf pair 3)."""
    B, Sq, K, G, dh = qg.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0
    nk = Skv // chunk
    qf = qg.astype(jnp.float32)

    kb = jnp.moveaxis(k.reshape(B, nk, chunk, K, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, chunk, K, dh), 1, 0)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, j = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32)) * scale
        if causal:
            rows = jnp.arange(Sq)[:, None]
            cols = j * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(rows[None, None, None] >= cols[None, None, None], p, 0.0)
        alpha = jnp.where(m > NEG_INF / 2, jnp.exp(m - m_new), 0.0)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(qg.dtype)  # (B,Sq,K,G,dh)


def attention(
    q,
    k,
    v,
    *,
    n_kv_heads: int,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    impl: str = "xla",
):
    """Grouped-query attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, K, dh). Returns (B, Sq, H, dh).
    window > 0 selects exact sliding-window causal attention.
    """
    B, Sq, H, dh = q.shape
    K = n_kv_heads
    G = H // K
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, Sq, K, G, dh)

    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
        return out

    if impl == "xla_chunked" and not window and Sq == k.shape[1]:
        out = _chunked_attention_xla(qg, k, v, causal=causal, scale=scale)
    elif window and causal and Sq == k.shape[1] and Sq > 2 * window and Sq % window == 0:
        out = _swa_blocked_xla(qg, k, v, window=window, scale=scale)
    else:
        if window and causal and Sq == k.shape[1]:
            # small seq relative to window: fall back to masked full attention
            qi = jnp.arange(Sq)
            kj = jnp.arange(Sq)
            m = (qi[:, None] >= kj[None, :]) & (qi[:, None] - kj[None, :] < window)
            out = _sdpa(qg, k, v, m[None, None, None], scale)
        else:
            out = _full_attention_xla(qg, k, v, causal=causal, q_offset=q_offset, scale=scale)
    return out.reshape(B, Sq, H, dh)


def decode_attention(q, k_cache, v_cache, cache_len, *, n_kv_heads: int, impl: str = "xla"):
    """One-token attention against a (possibly ring-buffer) KV cache.

    q: (B, 1, H, dh); caches: (B, S_c, K, dh); cache_len: (B,) number of valid
    entries. Ring-buffer semantics: positions are valid iff slot < min(len, S_c);
    RoPE is applied by the caller (cache stores post-RoPE keys).
    """
    B, _, H, dh = q.shape
    K = n_kv_heads
    G = H // K
    scale = 1.0 / np.sqrt(dh)

    if impl == "pallas":
        from repro.kernels.flash_decode import ops as fd_ops

        return fd_ops.flash_decode(q, k_cache, v_cache, cache_len)

    S_c = k_cache.shape[1]
    qg = q.reshape(B, K, G, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S_c)[None] < jnp.minimum(cache_len, S_c)[:, None]  # (B, S_c)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(B, 1, H, dh)


# ---------------------------------------------------------------- attention block


def attn_init(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": dense_param(ks[0], d, H * dh, ("fsdp", "tp"), dt),
        "wk": dense_param(ks[1], d, K * dh, ("fsdp", "tp"), dt),
        "wv": dense_param(ks[2], d, K * dh, ("fsdp", "tp"), dt),
        "wo": dense_param(ks[3], H * dh, d, ("tp", "fsdp"), dt),
    }


def attn_apply(p, x, cfg, *, positions, k_cache=None, v_cache=None, cache_len=None):
    """Returns (out, (new_k, new_v)) — new_k/new_v are this call's K/V entries
    (pre-cache-write, post-RoPE), used by the caller to update caches."""
    B, S, d = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, K, dh)
    v = (x @ p["wv"]).reshape(B, S, K, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if k_cache is not None:
        out = decode_attention(q, k_cache, v_cache, cache_len, n_kv_heads=K, impl=cfg.attn_impl)
    else:
        out = attention(
            q, k, v,
            n_kv_heads=K,
            causal=cfg.causal,
            window=cfg.sliding_window if cfg.causal else 0,
            impl=cfg.attn_impl,
        )
    return out.reshape(B, S, H * dh) @ p["wo"], (k, v)


# ----------------------------------------------------------------------- ffn


def ffn_init(key, cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    if not cfg.mlp_gated:  # non-gated GELU MLP (GPT/wav2vec2 family)
        return {
            "wi": dense_param(k1, d, f, ("fsdp", "tp"), dt),
            "wo": dense_param(k2, f, d, ("tp", "fsdp"), dt),
        }
    return {
        "wi": dense_param(k1, d, (2, f), ("fsdp", None, "tp"), dt),
        "wo": dense_param(k2, f, d, ("tp", "fsdp"), dt),
    }


def ffn_apply(p, x):
    if p["wi"].ndim == 2:  # GELU MLP
        return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
    h = jnp.einsum("bsd,dtf->bstf", x, p["wi"])
    gate, up = h[..., 0, :], h[..., 1, :]
    return (jax.nn.silu(gate) * up) @ p["wo"]


# ----------------------------------------------------------- embedding / head


def embed_init(key, cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    V, d = cfg.vocab_size, cfg.d_model
    out = {}
    if cfg.tie_embeddings:
        out["table"] = Param((0.02 * jax.random.normal(k1, (V, d))).astype(dt), ("vocab", None))
    else:
        out["table"] = Param((0.02 * jax.random.normal(k1, (V, d))).astype(dt), (None, "tp"))
        out["head"] = dense_param(k2, d, V, ("fsdp", "vocab"), dt)
    return out


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def logits_head(p, x):
    table = p["table"]
    if "head" in p:
        return x @ p["head"]
    return x @ table.T


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions; logits (B,S,V) possibly vocab-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - true
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def per_example_cross_entropy(logits, labels, mask=None):
    """(B,) mean CE per example — feeds the guided consistency statistics."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - true
    if mask is None:
        return jnp.mean(nll, axis=-1)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask, axis=-1) / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
