"""Unified model assembly for every assigned architecture family.

A model is a stack of `n_layers` blocks executed as a lax.scan over
*super-blocks*: the smallest repeating period of heterogeneous layers
(dense/moe: 1; xlstm: len(pattern)=2; jamba: attn_every=8). Scanning keeps the
HLO size O(period) instead of O(n_layers) — essential for 94-layer models on a
single-core compile host, and the production-standard layout for TPU.

API (all pure functions):
  model_init(key, cfg)                       -> boxed param tree
  forward_train(params, batch, cfg, ctx)     -> (per_example_loss, aux, logits)
  prefill(params, batch, cfg, ctx)           -> (last_logits, caches)
  decode_step(params, caches, tokens, t, cfg, ctx) -> (logits, caches)
  init_caches(cfg, batch, cache_len, ctx)    -> cache pytree (ShapeDtype-friendly)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models.module import Param, stacked, value_tree
from repro.sharding.rules import ShardCtx, LOCAL_CTX


# ----------------------------------------------------------- block structure


def period(cfg) -> int:
    if cfg.arch_type == "hybrid":
        return cfg.attn_every
    if cfg.xlstm is not None:
        return len(cfg.xlstm.pattern)
    return 1


def n_super(cfg) -> int:
    p = period(cfg)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return cfg.n_layers // p


def mixer_kind(cfg, i: int) -> str:
    """Kind of the i-th layer within a super-block."""
    if cfg.xlstm is not None:
        return "mlstm" if cfg.xlstm.pattern[i % len(cfg.xlstm.pattern)] else "slstm"
    if cfg.arch_type == "hybrid":
        return "attn" if cfg.layer_is_attn(i) else "mamba"
    return "attn"


def ffn_kind(cfg, i: int) -> Optional[str]:
    if cfg.xlstm is not None:
        return None  # xLSTM blocks carry their own projections
    if cfg.layer_is_moe(i):
        return "moe"
    return "dense"


def block_init(key, cfg) -> dict:
    """One super-block: dict l0..l{P-1}, each {norm1, mixer, [norm2, ffn]}."""
    P = period(cfg)
    keys = jax.random.split(key, P)
    out = {}
    for i in range(P):
        ki = jax.random.split(keys[i], 3)
        lp: dict = {}
        mk = mixer_kind(cfg, i)
        if mk == "attn":
            lp["norm1"] = L.rmsnorm_init(cfg.d_model)
            lp["mixer"] = L.attn_init(ki[0], cfg)
        elif mk == "mamba":
            lp["norm1"] = L.rmsnorm_init(cfg.d_model)
            lp["mixer"] = M.mamba_init(ki[0], cfg)
        elif mk == "mlstm":
            lp["mixer"] = X.mlstm_init(ki[0], cfg)
        elif mk == "slstm":
            lp["mixer"] = X.slstm_init(ki[0], cfg)
        fk = ffn_kind(cfg, i)
        if fk == "dense":
            lp["norm2"] = L.rmsnorm_init(cfg.d_model)
            lp["ffn"] = L.ffn_init(ki[1], cfg)
        elif fk == "moe":
            lp["norm2"] = L.rmsnorm_init(cfg.d_model)
            lp["ffn"] = MOE.moe_init(ki[1], cfg)
        out[f"l{i}"] = lp
    return out


def model_init(key, cfg):
    k_embed, k_blocks = jax.random.split(key)
    params: dict = {"final_norm": L.rmsnorm_init(cfg.d_model)}
    if cfg.audio_frontend:
        dt = jnp.dtype(cfg.param_dtype)
        params["mask_emb"] = Param((0.02 * jax.random.normal(k_embed, (cfg.d_model,))).astype(dt), (None,))
        k2 = jax.random.fold_in(k_embed, 1)
        params["head"] = Param(
            (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)).astype(dt),
            ("fsdp", "vocab"),
        )
    else:
        params["embed"] = L.embed_init(k_embed, cfg)
    params["blocks"] = stacked(n_super(cfg), lambda k: block_init(k, cfg), k_blocks)
    return params


# ------------------------------------------------------------------- caches


def cache_len_for(cfg, total_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, total_len)
    return total_len


def layer_cache_init(cfg, i: int, batch: int, s_c: int):
    mk = mixer_kind(cfg, i)
    dt = cfg.dtype
    if mk == "attn":
        K, dh = cfg.n_kv_heads, cfg.d_head
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros((batch, s_c, K, dh), jnp.int8),
                "v": jnp.zeros((batch, s_c, K, dh), jnp.int8),
                "k_scale": jnp.zeros((batch, s_c, K), jnp.float32),
                "v_scale": jnp.zeros((batch, s_c, K), jnp.float32),
            }
        return {
            "k": jnp.zeros((batch, s_c, K, dh), dt),
            "v": jnp.zeros((batch, s_c, K, dh), dt),
        }
    if mk == "mamba":
        conv, ssm = M.mamba_state_init(cfg, batch, dt)
        return {"conv": conv, "ssm": ssm}
    if mk == "mlstm":
        conv, (C, n, m) = X.mlstm_state_init(cfg, batch, dt)
        return {"conv": conv, "C": C, "n": n, "m": m}
    if mk == "slstm":
        conv, (h, c, n, m) = X.slstm_state_init(cfg, batch, dt)
        return {"conv": conv, "h": h, "c": c, "n": n, "m": m}
    raise ValueError(mk)


def init_caches(cfg, batch: int, total_len: int):
    s_c = cache_len_for(cfg, total_len)
    P = period(cfg)
    one = {f"l{i}": layer_cache_init(cfg, i, batch, s_c) for i in range(P)}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_super(cfg),) + x.shape), one)


def cache_logical(cfg):
    """Logical sharding annotations mirroring init_caches output."""
    P = period(cfg)
    one = {}
    for i in range(P):
        mk = mixer_kind(cfg, i)
        if mk == "attn":
            one[f"l{i}"] = {"k": (None, "batch", "seq_kv", None, None), "v": (None, "batch", "seq_kv", None, None)}
            if cfg.kv_cache_dtype == "int8":
                one[f"l{i}"]["k_scale"] = (None, "batch", "seq_kv", None)
                one[f"l{i}"]["v_scale"] = (None, "batch", "seq_kv", None)
        elif mk == "mamba":
            one[f"l{i}"] = {"conv": (None, "batch", None, "tp"), "ssm": (None, "batch", "tp", None)}
        elif mk == "mlstm":
            one[f"l{i}"] = {
                "conv": (None, "batch", None, "tp"),
                "C": (None, "batch", None, None, None),
                "n": (None, "batch", None, None),
                "m": (None, "batch", None),
            }
        else:
            one[f"l{i}"] = {
                "conv": (None, "batch", None, "tp"),
                "h": (None, "batch", None, None),
                "c": (None, "batch", None, None),
                "n": (None, "batch", None, None),
                "m": (None, "batch", None, None),
            }
    return one


# --------------------------------------------------- distributed decode attn


def sharded_decode_attention(q, k_cache, v_cache, cache_len, cfg, ctx: ShardCtx):
    """Flash-decode with the KV-cache *sequence* dim sharded over `model`:
    each model shard attends to its local chunk; partials are combined with a
    max-stabilized (num, den) psum. q is replicated over `model` in-region."""
    K = cfg.n_kv_heads

    if not ctx.distributed or "seq_kv" not in ctx.rules.table or not ctx.rules.get("seq_kv"):
        return L.decode_attention(q, k_cache, v_cache, cache_len, n_kv_heads=K, impl=cfg.attn_impl)

    axis = ctx.model_axis
    s_c = k_cache.shape[1]
    if s_c % ctx.mesh.shape[axis] != 0:
        return L.decode_attention(q, k_cache, v_cache, cache_len, n_kv_heads=K, impl=cfg.attn_impl)

    def local(q_, kc, vc, clen, slots):
        # slots: (s_c / n_model,) — this shard's global cache positions. Passed
        # in as a sequence-sharded operand rather than derived from
        # lax.axis_index: PartitionId doesn't lower through partial-manual
        # SPMD on the pinned XLA.
        B, _, H, dh = q_.shape
        G = H // K
        scale = 1.0 / np.sqrt(dh)
        # f32 dots off-TPU: XLA CPU miscompiles bf16 dots inside manual-axes
        # shard_map regions (see models/moe.py note); bf16 MXU path on TPU.
        ed = jnp.float32 if jax.default_backend() != "tpu" else q_.dtype
        qg = q_.reshape(B, K, G, dh).astype(ed)
        logits = jnp.einsum("bkgd,bskd->bkgs", qg, kc.astype(ed),
                            preferred_element_type=jnp.float32) * scale
        valid = slots[None] < jnp.minimum(clen, s_c)[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, L.NEG_INF)
        m_loc = jnp.max(logits, axis=-1)
        m = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(logits - m[..., None])
        den = jax.lax.psum(jnp.sum(p, axis=-1), axis)
        num = jax.lax.psum(jnp.einsum("bkgs,bskd->bkgd", p.astype(ed), vc.astype(ed)), axis)
        out = num / jnp.maximum(den[..., None], 1e-30).astype(num.dtype)
        return out.reshape(B, 1, H, dh).astype(q_.dtype)

    from jax.sharding import PartitionSpec as P

    from repro.common.compat import shard_map

    fn = shard_map(
        local,
        mesh=ctx.mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P(), P(axis)),
        out_specs=P(),
        axis_names={axis},
    )
    return fn(q, k_cache, v_cache, cache_len, jnp.arange(s_c, dtype=jnp.int32))


# ------------------------------------------------------------- block apply


def layer_apply(lp, x, cfg, ctx, i, positions, cache=None, t=None):
    """Apply layer i of a super-block. Returns (x, aux, new_cache)."""
    mk = mixer_kind(cfg, i)
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if mk == "attn":
        h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        if cache is not None and t is not None:
            # decode: one token per request against the cache. t is a (B,)
            # per-slot position vector (a scalar is broadcast by decode_step),
            # so requests at different depths share one jitted step.
            B, S, d = h.shape
            H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            q = (h @ lp["mixer"]["wq"]).reshape(B, S, H, dh)
            k = (h @ lp["mixer"]["wk"]).reshape(B, S, K, dh)
            v = (h @ lp["mixer"]["wv"]).reshape(B, S, K, dh)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            s_c = cache["k"].shape[1]
            slot = jnp.mod(t, s_c)  # (B,) per-request ring-buffer slots
            rows = jnp.arange(B)
            if cfg.kv_cache_dtype == "int8":
                from repro.models.kvquant import dequantize_kv, quantize_kv

                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                k_cache = cache["k"].at[rows, slot].set(kq[:, 0])
                v_cache = cache["v"].at[rows, slot].set(vq[:, 0])
                ks_cache = cache["k_scale"].at[rows, slot].set(ks[:, 0])
                vs_cache = cache["v_scale"].at[rows, slot].set(vs[:, 0])
                k_full = dequantize_kv(k_cache, ks_cache, cfg.dtype)
                v_full = dequantize_kv(v_cache, vs_cache, cfg.dtype)
                # this step's attention reads the current token's exact k/v
                # (the int8 copy only pays its quantization cost from t+1 on)
                k_full = k_full.at[rows, slot].set(k[:, 0].astype(cfg.dtype))
                v_full = v_full.at[rows, slot].set(v[:, 0].astype(cfg.dtype))
                new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks_cache, "v_scale": vs_cache}
            else:
                k_cache = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
                v_cache = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
                k_full, v_full = k_cache, v_cache
                new_cache = {"k": k_cache, "v": v_cache}
            clen = (t + 1).astype(jnp.int32)
            out = sharded_decode_attention(q, k_full, v_full, clen, cfg, ctx)
            att = out.reshape(B, S, H * dh) @ lp["mixer"]["wo"]
        else:
            att, (k, v) = L.attn_apply(lp["mixer"], h, cfg, positions=positions)
            if cache is not None:  # prefill: write the (window of the) sequence
                s_c = cache["k"].shape[1]
                S = k.shape[1]
                s_eff = min(S, s_c)  # window may truncate; cache may be larger
                kw, vw = k[:, -s_eff:], v[:, -s_eff:]
                slots = jnp.mod(jnp.arange(S - s_eff, S), s_c)
                if cfg.kv_cache_dtype == "int8":
                    from repro.models.kvquant import quantize_kv

                    kq, ks = quantize_kv(kw)
                    vq, vs = quantize_kv(vw)
                    new_cache = {
                        "k": jnp.zeros_like(cache["k"]).at[:, slots].set(kq),
                        "v": jnp.zeros_like(cache["v"]).at[:, slots].set(vq),
                        "k_scale": jnp.zeros_like(cache["k_scale"]).at[:, slots].set(ks),
                        "v_scale": jnp.zeros_like(cache["v_scale"]).at[:, slots].set(vs),
                    }
                else:
                    k_cache = jnp.zeros_like(cache["k"]).at[:, slots].set(kw.astype(cache["k"].dtype))
                    v_cache = jnp.zeros_like(cache["v"]).at[:, slots].set(vw.astype(cache["v"].dtype))
                    new_cache = {"k": k_cache, "v": v_cache}
        x = x + att

    elif mk == "mamba":
        h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        conv = cache["conv"] if cache is not None else None
        ssm = cache["ssm"] if cache is not None else None
        y, (new_conv, new_ssm) = M.mamba_apply(lp["mixer"], h, cfg, conv, ssm, impl=cfg.attn_impl if cfg.attn_impl == "pallas" else "xla")
        x = x + y
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}

    elif mk == "mlstm":
        st = (cache["conv"], (cache["C"], cache["n"], cache["m"])) if cache is not None else None
        x, (new_conv, (C, n, m)) = X.mlstm_apply(lp["mixer"], x, cfg, st)
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "C": C, "n": n, "m": m}

    elif mk == "slstm":
        st = (cache["conv"], (cache["h"], cache["c"], cache["n"], cache["m"])) if cache is not None else None
        x, (new_conv, (hh, c, n, m)) = X.slstm_apply(lp["mixer"], x, cfg, st)
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "h": hh, "c": c, "n": n, "m": m}

    fk = ffn_kind(cfg, i)
    if fk is not None:
        h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if fk == "dense":
            x = x + L.ffn_apply(lp["ffn"], h)
        else:
            y, aux_moe = MOE.moe_apply(lp["ffn"], h, cfg, ctx)
            x = x + y
            aux = aux + aux_moe
    return x, aux, new_cache


def block_apply(bp, x, cfg, ctx, positions, caches=None, t=None):
    """One super-block (period P layers)."""
    P = period(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None
    for i in range(P):
        cache_i = caches[f"l{i}"] if caches is not None else None
        x, aux_i, nc = layer_apply(bp[f"l{i}"], x, cfg, ctx, i, positions, cache_i, t)
        aux = aux + aux_i
        if caches is not None:
            new_caches[f"l{i}"] = nc
    return x, aux, new_caches


# ------------------------------------------------------------ full forward


def _embed_inputs(params, batch, cfg):
    if cfg.audio_frontend:
        x = batch["frames"].astype(cfg.dtype)
        mask = batch["mask_positions"]
        x = jnp.where(mask[..., None], params["mask_emb"].astype(cfg.dtype), x)
        return x
    x = L.embed_lookup(params["embed"], batch["tokens"]).astype(cfg.dtype)
    if cfg.arch_type == "vlm" and "patches" in batch:
        P_ = batch["patches"].shape[1]
        x = jnp.concatenate([x[:, :1], batch["patches"].astype(cfg.dtype), x[:, 1 + P_ :]], axis=1)
    return x


@functools.lru_cache(maxsize=None)
def _block_logical(cfg):
    import jax as _jax
    from repro.models.module import logical_tree

    boxed = _jax.eval_shape(lambda: block_init(_jax.random.PRNGKey(0), cfg))
    return logical_tree(boxed)


def _constrain_block(bp, cfg, ctx):
    """Re-assert per-layer weight shardings inside the scan body. Without this
    the SPMD partitioner loses the sharding of the scanned slice's *gradient*
    accumulator and falls back to full-size all-reduces (184 GiB/device temp on
    yi-9b vs ~2 GiB with constraints — see EXPERIMENTS.md §Perf)."""
    if not ctx.distributed:
        return bp
    from repro.sharding.rules import logical_to_spec

    logical = _block_logical(cfg)
    # scanned slices have lost the leading layer dim: drop it from annotations
    def one(v, log):
        log = tuple(log)[-v.ndim:] if len(log) > v.ndim else log
        spec = logical_to_spec(log, ctx.rules, ctx.mesh, v.shape)
        return jax.lax.with_sharding_constraint(v, jax.sharding.NamedSharding(ctx.mesh, spec))

    return jax.tree.map(one, bp, logical)


def _stack_scan(params, x, cfg, ctx, positions, caches=None, t=None):
    blocks = params["blocks"]

    def body(carry, xs):
        xc, aux = carry
        if caches is not None:
            bp, cache = xs
        else:
            bp, cache = xs, None
        bp = _constrain_block(bp, cfg, ctx)
        if ctx.distributed:
            # "seq" resolves to () by default; under sequence-parallel rules it
            # shards the inter-block activations over `model`, turning the
            # Megatron all-reduces into all-gather+reduce-scatter pairs.
            xc = jax.lax.with_sharding_constraint(
                xc, jax.sharding.NamedSharding(ctx.mesh, ctx.spec("batch", "seq", None, shape=xc.shape))
            )
        xc, aux_i, nc = block_apply(bp, xc, cfg, ctx, positions, cache, t)
        return (xc, aux + aux_i), nc

    if cfg.remat == "full":
        body = jax.checkpoint(body)

    xs = (blocks, caches) if caches is not None else blocks
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


def _head(params, x, cfg):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.audio_frontend:
        return x @ params["head"]
    return L.logits_head(params["embed"], x)


def forward_train(params, batch, cfg, ctx: ShardCtx = LOCAL_CTX):
    """Returns (per_example_loss (B,), aux, logits)."""
    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, aux, _ = _stack_scan(params, x, cfg, ctx, positions)
    logits = _head(params, x, cfg)
    labels = batch["labels"]
    mask = batch.get("mask")
    per_ex = L.per_example_cross_entropy(logits, labels, mask)
    return per_ex, aux, logits


def prefill(params, batch, cfg, ctx: ShardCtx = LOCAL_CTX, total_len: int = 0,
            prompt_lens=None):
    """Returns (last-position logits (B,V), caches). Caches are sized for
    `total_len` (>= prompt length) so decode can continue in place.

    `prompt_lens` ((B,) int32, optional) supports right-padded prompts: logits
    are gathered at each row's last *real* position (prompt_lens-1) instead of
    the last padded one. Padded KV slots hold junk, but causal masking keeps
    real-token activations exact and decode overwrites slot t exactly when it
    first becomes visible (clen = t+1) — see DESIGN.md §7 for the arch classes
    where this is sound (recurrent state integrates pad junk; windows wrap)."""
    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    caches = init_caches(cfg, B, max(total_len, S))
    x, _, caches = _stack_scan(params, x, cfg, ctx, positions, caches=caches)
    if prompt_lens is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.clip(jnp.asarray(prompt_lens, jnp.int32) - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = _head(params, x_last, cfg)
    return logits[:, 0], caches


def decode_step(params, caches, tokens, t, cfg, ctx: ShardCtx = LOCAL_CTX):
    """tokens: (B,1) int32 (or (B,1,d) frames); t: scalar position shared by
    the batch, or a (B,) per-request position vector (continuous batching:
    every slot advances at its own depth). Returns (logits (B,V), new caches)."""
    if cfg.audio_frontend:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    B = x.shape[0]
    tv = jnp.asarray(t, jnp.int32)
    if tv.ndim == 0:
        tv = jnp.broadcast_to(tv, (B,))
    positions = tv[:, None]
    x, _, caches = _stack_scan(params, x, cfg, ctx, positions, caches=caches, t=tv)
    logits = _head(params, x, cfg)
    return logits[:, 0], caches
