"""Mamba-1 selective SSM block (Gu & Dao 2023), as used by Jamba's mamba mixer.

Training/prefill uses a time-major lax.scan with O(B * ed * n) live state —
the only memory-feasible pure-XLA form at jamba scale (materializing per-position
decay tensors is O(S * ed * n)). The TPU hot path is the chunked Pallas kernel in
repro.kernels.selective_scan; the XLA scan here is the dry-run/CPU reference.

The inner dim `ed = expand * d_model` is tensor-sharded over `model`; the SSM
state dim `n` is small (16) and replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Param


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or int(np.ceil(cfg.d_model / 16))


def mamba_init(key, cfg) -> dict:
    d = cfg.d_model
    ed = cfg.ssm.expand * d
    n = cfg.ssm.d_state
    r = _dt_rank(cfg)
    dc = cfg.ssm.d_conv
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (ed, 1))
    return {
        "in_proj": Param((jax.random.normal(ks[0], (d, 2 * ed)) / np.sqrt(d)).astype(dt), ("fsdp", "tp")),
        "conv_w": Param((jax.random.normal(ks[1], (dc, ed)) / np.sqrt(dc)).astype(dt), (None, "tp")),
        "conv_b": Param(jnp.zeros((ed,), dt), ("tp",)),
        "x_proj": Param((jax.random.normal(ks[2], (ed, r + 2 * n)) / np.sqrt(ed)).astype(dt), ("tp", None)),
        "dt_proj": Param((jax.random.normal(ks[3], (r, ed)) / np.sqrt(r)).astype(dt), (None, "tp")),
        "dt_bias": Param(jnp.log(jnp.expm1(jnp.full((ed,), 0.01))).astype(jnp.float32), ("tp",)),
        "A_log": Param(jnp.log(A), ("tp", None)),
        "D": Param(jnp.ones((ed,), jnp.float32), ("tp",)),
        "out_proj": Param((jax.random.normal(ks[4], (ed, d)) / np.sqrt(ed)).astype(dt), ("tp", "fsdp")),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv over time. x: (B,S,ed), w: (dc,ed).
    conv_state: (B, dc-1, ed) trailing inputs from the previous segment."""
    dc = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+dc-1, ed)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(dc))
    return out + b, xp[:, -(dc - 1) :]  # new conv_state


def _ssm_inputs(p, x, cfg):
    """Shared projection math. x: (B,S,d) -> (xconv, z, dt, Bc, Cc, new_conv_state)."""
    n, r = cfg.ssm.d_state, _dt_rank(cfg)
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    return x1, z, n, r


def mamba_apply(p, x, cfg, conv_state=None, ssm_state=None, impl: str = "xla"):
    """Full-sequence form. x: (B,S,d). Returns (y, (conv_state, ssm_state))."""
    B, S, d = x.shape
    x1, z, n, r = _ssm_inputs(p, x, cfg)
    xconv, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], conv_state)
    xconv = jax.nn.silu(xconv)

    proj = xconv @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)  # (B,S,ed)
    A = -jnp.exp(p["A_log"])  # (ed, n)

    if impl == "pallas":
        from repro.kernels.selective_scan import ops as ss_ops

        ys, new_state = ss_ops.selective_scan(
            xconv.astype(jnp.float32), dt, A, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
            h0=ssm_state,
        )
    else:
        h0 = ssm_state if ssm_state is not None else jnp.zeros((B, x1.shape[-1], n), jnp.float32)

        def step(h, inp):
            dt_t, B_t, C_t, x_t = inp  # (B,ed), (B,n), (B,n), (B,ed)
            dA = jnp.exp(dt_t[:, :, None] * A)
            h = dA * h + (dt_t * x_t)[:, :, None] * B_t[:, None, :].astype(jnp.float32)
            y_t = jnp.sum(h * C_t[:, None, :].astype(jnp.float32), axis=-1)
            return h, y_t

        xs = (
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bc, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
            jnp.moveaxis(xconv.astype(jnp.float32), 1, 0),
        )
        new_state, ys = jax.lax.scan(step, h0, xs)
        ys = jnp.moveaxis(ys, 0, 1)  # (B,S,ed)

    y = ys.astype(x.dtype) + xconv * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], (new_conv, new_state)


def mamba_decode(p, x, cfg, conv_state, ssm_state):
    """Single-token step. x: (B,1,d); states as returned by mamba_apply."""
    y, (new_conv, new_ssm) = mamba_apply(p, x, cfg, conv_state, ssm_state, impl="xla")
    return y, (new_conv, new_ssm)


def mamba_state_init(cfg, batch: int, dtype=jnp.float32):
    ed = cfg.ssm.expand * cfg.d_model
    return (
        jnp.zeros((batch, cfg.ssm.d_conv - 1, ed), dtype),
        jnp.zeros((batch, ed, cfg.ssm.d_state), jnp.float32),
    )
