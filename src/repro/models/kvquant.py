"""int8 KV-cache quantization (beyond-paper serving feature).

Per-(token, kv-head) absmax quantization: k (B,S,K,dh) -> int8 values + one
f32 scale per (B,S,K). Halves the decode-time cache footprint relative to
bf16 (the dominant HBM tenant at decode_32k: B=128 x S=32k), at ~0.3% relative
attention-output error (tests/test_kvquant.py). Dequantization fuses into the
attention einsum's operand read under XLA.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_kv(x):
    """x: (..., dh) float -> (int8 values (..., dh), f32 scales (...))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    """Inverse of quantize_kv."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
