"""Minimal module system: parameter pytrees with logical-axis annotations.

flax/haiku are not on this image; this is deliberately a *function-first* module
system in the MaxText tradition: each layer is (init_fn, apply_fn). `init` returns
a pytree of `Param(value, logical)`; `split_params` unzips it into a value tree
(fed to jit) and a logical-annotation tree (resolved to NamedShardings by
repro.sharding.rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Param:
    value: Any
    logical: tuple  # logical axis name per dim, e.g. ("fsdp", "tp")


# Registered as a pytree node (logical as static aux data) so boxed trees pass
# through jax.eval_shape / jit tracing — the dry-run builds parameter structure
# without ever materializing the (multi-hundred-GB) weights.
jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.logical),
    lambda logical, children: Param(children[0], logical),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def value_tree(tree):
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def logical_tree(tree):
    return jax.tree.map(lambda p: p.logical, tree, is_leaf=is_param)


def split_params(tree):
    return value_tree(tree), logical_tree(tree)


# ---------------------------------------------------------------- initializers


def normal(key, shape, scale: float, dtype) -> jax.Array:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def lecun(key, shape, fan_in: int, dtype) -> jax.Array:
    return normal(key, shape, 1.0 / np.sqrt(max(fan_in, 1)), dtype)


def dense_param(key, d_in: int, d_out, logical: tuple, dtype) -> Param:
    shape = (d_in,) + ((d_out,) if isinstance(d_out, int) else tuple(d_out))
    return Param(lecun(key, shape, d_in, dtype), logical)


def stacked(n: int, init_fn: Callable[[jax.Array], Any], key: jax.Array):
    """Initialize `n` copies of a sub-tree and stack leaves on a leading dim,
    for lax.scan-over-layers. Logical annotations get a leading "layers"=None."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]

    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]), (None,) + tuple(ps[0].logical))

    return jax.tree.map(stack, *trees, is_leaf=is_param)


def param_count(tree) -> int:
    vals = jax.tree.leaves(value_tree(tree))
    return sum(int(np.prod(v.shape)) for v in vals)
