from repro.models.module import (  # noqa: F401
    Param,
    dense_param,
    is_param,
    logical_tree,
    param_count,
    split_params,
    stacked,
    value_tree,
)
