"""xLSTM blocks (Beck et al. 2024, arXiv:2405.04517): mLSTM (matrix memory,
parallelizable) and sLSTM (scalar memory, exponential gating, recurrent mixing).

Both are implemented in their exact recurrent form with a time-major lax.scan
(stabilized exponential gating in log space). The recurrent carry is
O(B * nh * dh^2) for mLSTM and O(B * d) for sLSTM — small — so the scan is
memory-safe at every assigned shape including long_500k decode (a single step).
A chunkwise-parallel mLSTM is a §Perf/kernel-level optimization, validated
against this reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Param
from repro.models.layers import rmsnorm, rmsnorm_init


def _log_sigmoid(x):
    return -jax.nn.softplus(-x)


def _dense(key, di, do, logical, dt):
    return Param((jax.random.normal(key, (di, do)) / np.sqrt(di)).astype(dt), logical)


# ------------------------------------------------------------------ mLSTM


def mlstm_init(key, cfg) -> dict:
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    nh = cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "w_up": _dense(ks[0], d, di, ("fsdp", "tp"), dt),
        "w_gate": _dense(ks[1], d, di, ("fsdp", "tp"), dt),
        "conv_w": Param((jax.random.normal(ks[2], (4, di)) * 0.5).astype(dt), (None, "tp")),
        "conv_b": Param(jnp.zeros((di,), dt), ("tp",)),
        "wq": _dense(ks[3], di, di, ("tp", None), dt),
        "wk": _dense(ks[4], di, di, ("tp", None), dt),
        "wv": _dense(ks[5], di, di, ("tp", None), dt),
        "w_if": _dense(ks[6], d, 2 * nh, ("fsdp", None), jnp.float32),
        "b_if": Param(jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]), (None,)),
        "out_norm": rmsnorm_init(di),
        "w_down": _dense(ks[7], di, d, ("tp", "fsdp"), dt),
    }


def _mlstm_scan(q, k, v, log_i, log_f, state):
    """q,k,v: (B,S,nh,dh); log_i/log_f: (B,S,nh). state: (C,n,m) or None.
    Returns h (B,S,nh,dh), new state. Exact stabilized recurrence."""
    B, S, nh, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    if state is None:
        C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, nh, dh), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = inp  # (B,nh,dh) x3, (B,nh) x2
        m_new = jnp.maximum(lf_t + m, li_t)
        i_p = jnp.exp(li_t - m_new)
        f_p = jnp.exp(lf_t + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :]
        )  # (B,nh,dh_v,dh_k)
        n = f_p[..., None] * n + i_p[..., None] * k_t
        qs = q_t * scale
        num = jnp.einsum("bhvk,bhk->bhv", C, qs)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qs)), jnp.exp(-m_new))
        h_t = num / den[..., None]
        return (C, n, m_new), h_t

    tm = lambda x: jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), (tm(q), tm(k), tm(v), tm(log_i), tm(log_f)))
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def mlstm_apply(p, x, cfg, state=None):
    """x: (B,S,d). state: (conv_state, (C,n,m)) or None. Returns (y, state)."""
    from repro.models.mamba import _causal_conv

    B, S, d = x.shape
    nh = cfg.n_heads
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    a = xn @ p["w_up"]
    g = xn @ p["w_gate"]
    conv_state = state[0] if state is not None else None
    ac, new_conv = _causal_conv(a, p["conv_w"], p["conv_b"], conv_state)
    ac = jax.nn.silu(ac)
    di = a.shape[-1]
    dh = di // nh
    q = (ac @ p["wq"]).reshape(B, S, nh, dh)
    k = ((ac @ p["wk"]) / np.sqrt(dh)).reshape(B, S, nh, dh)
    v = (a @ p["wv"]).reshape(B, S, nh, dh)
    gates = xn.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i = gates[..., :nh]
    log_f = _log_sigmoid(gates[..., nh:])
    inner = state[1] if state is not None else None
    h, new_inner = _mlstm_scan(q, k, v, log_i, log_f, inner)
    h = h.reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    y = (h * jax.nn.silu(g)) @ p["w_down"]
    return x + y, (new_conv, new_inner)


def mlstm_state_init(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    nh = cfg.n_heads
    dh = di // nh
    return (
        jnp.zeros((batch, 3, di), dtype),
        (
            jnp.zeros((batch, nh, dh, dh), jnp.float32),
            jnp.zeros((batch, nh, dh), jnp.float32),
            jnp.full((batch, nh), -1e30, jnp.float32),
        ),
    )


# ------------------------------------------------------------------ sLSTM


def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    f = int(cfg.xlstm.slstm_proj_factor * d)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "conv_w": Param((jax.random.normal(ks[0], (4, d)) * 0.5).astype(dt), (None, "tp")),
        "conv_b": Param(jnp.zeros((d,), dt), ("tp",)),
        "w_gates": _dense(ks[1], d, 4 * d, ("fsdp", "tp"), dt),  # i,f,z,o stacked
        "r_gates": Param(
            (jax.random.normal(ks[2], (4, nh, dh, dh)) / np.sqrt(dh)).astype(jnp.float32),
            (None, None, None, None),
        ),
        "b_gates": Param(jnp.zeros((4, d), jnp.float32).at[1].set(3.0), (None, None)),
        "out_norm": rmsnorm_init(d),
        "w_ff": Param((jax.random.normal(ks[3], (d, 2, f)) / np.sqrt(d)).astype(dt), ("fsdp", None, "tp")),
        "w_ff_out": _dense(ks[4], f, d, ("tp", "fsdp"), dt),
    }


def _slstm_scan(wx, r, state):
    """wx: (B,S,4,nh,dh) input contributions; r: (4,nh,dh,dh).
    state: (h,c,n,m) each (B,nh,dh). Exact stabilized sLSTM recurrence."""
    B, S, _, nh, dh = wx.shape
    if state is None:
        z = jnp.zeros((B, nh, dh), jnp.float32)
        state = (z, z, z + 1.0, z - 1e30)

    def step(carry, wx_t):
        h, c, n, m = carry
        rec = jnp.einsum("ghkd,bhd->bghk", r, h)  # (B,4,nh,dh)
        pre = wx_t + rec
        li = pre[:, 0]
        lf = _log_sigmoid(pre[:, 1])
        z_t = jnp.tanh(pre[:, 2])
        o_t = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c = f_p * c + i_p * z_t
        n = f_p * n + i_p
        h = o_t * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    tm = jnp.moveaxis(wx.astype(jnp.float32), 1, 0)
    new_state, hs = jax.lax.scan(step, state, tm)
    return jnp.moveaxis(hs, 0, 1), new_state


def slstm_apply(p, x, cfg, state=None):
    """x: (B,S,d). state: (conv_state, (h,c,n,m)) or None."""
    from repro.models.mamba import _causal_conv

    B, S, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    conv_state = state[0] if state is not None else None
    xc, new_conv = _causal_conv(xn, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    wx = (xc @ p["w_gates"]).astype(jnp.float32) + p["b_gates"].reshape(1, 1, 4 * d).astype(jnp.float32).reshape(1, 1, -1)
    wx = wx.reshape(B, S, 4, nh, dh)
    inner = state[1] if state is not None else None
    h, new_inner = _slstm_scan(wx, p["r_gates"], inner)
    h = h.reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    hf = jnp.einsum("bsd,dtf->bstf", h, p["w_ff"])
    y = (jax.nn.silu(hf[..., 0, :]) * hf[..., 1, :]) @ p["w_ff_out"]
    return x + y, (new_conv, new_inner)


def slstm_state_init(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return (jnp.zeros((batch, 3, d), dtype), (z, z, z + 1.0, z - 1e30))
