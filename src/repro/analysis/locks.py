"""Repo-wide static lockset analysis (`repro.analysis.locks`, DESIGN.md §13).

PR 8's `audit_lock_discipline` proved the pattern on one hardcoded class
(`ParameterStore`); this pass generalizes it to every concurrent class in the
tree. A class is *concurrent* when it creates a `threading.Lock` / `RLock` /
`Condition` or starts a `threading.Thread` — today that discovers
`dist/store.py` (ParameterStore), `dist/chief.py` (Chief),
`checkpoint/writer.py` (AsyncCheckpointer) and `data/prefetch.py`
(ChunkPrefetcher); `serve/engine.py` has no threading and passes trivially.

Inference rules (the Eraser lockset discipline, adapted to AST):

  1. *Shared attributes.* `self.X` is shared-mutable when it is assigned,
     aug-assigned, subscript-stored, deleted, or container-mutated
     (append/update/...) in any method other than `__init__`. Attributes
     assigned only during `__init__` are construction-immutable (publication
     happens-before the threads that read them); synchronization primitives
     themselves (locks, events, queues, thread handles, `threading.local`)
     are exempt.
  2. *Locally held locks.* `with self.L:` (or any dotted `with self.a.b:`)
     adds the lock to the held set for the scope of the `with`; a
     `Condition.wait_for` predicate evaluates under the re-acquired lock, so
     scanning the lambda with the lock held is exact.
  3. *Guaranteed entry locksets* propagate interprocedurally: a method's
     entry lockset is the intersection over all intra-class call sites of
     (caller's entry lockset | locks held at the call). Public methods,
     dunders, and `Thread(target=...)` targets are entry points (empty entry
     lockset); helpers never called from a reachable method are conservative
     (empty) rather than trusted.
  4. *The discipline.* Every shared attribute must have a non-empty
     intersection of effective locksets (entry | held) over all of its
     non-`__init__` accesses. An access with an empty effective lockset is
     `lock-shared-unlocked`; all-locked accesses with no *common* lock are
     `lock-inconsistent` (two locks that don't exclude each other).

Lock-ordering graph: nodes are `Class.attr` lock identities (dotted
acquisitions resolve through `__init__` parameter annotations, so
`Chief.store.cond` and `ParameterStore.cond` unify); an edge A -> B is
recorded whenever B is acquired — directly or via a transitive self-call —
while A is held. A strongly-connected component of >= 2 nodes is a potential
deadlock: `lock-order-cycle`. Self-edges are not reported (Condition wraps an
RLock; single-lock reentrancy is a kind the AST cannot decide).

Findings are `repro.analysis.lint.Finding`s, so the inline
`# lint: allow[rule-id] reason` tag and the committed baseline apply
unchanged. CLI: `python -m repro.analysis.locks src/` (also folded into
`python -m repro.analysis` and `make check`); `--report` prints the
discovery table CI archives as proof of coverage.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis.lint import Finding, _inline_allowed, iter_py_files

#: factory callables whose result is a mutual-exclusion lock (with-able)
_LOCK_FACTORIES = {"Lock", "RLock", "Condition"}
#: factory callables whose result is a sync primitive but not a lockset lock
_SYNC_FACTORIES = {
    "Event", "Semaphore", "BoundedSemaphore", "Barrier", "local",
    "Queue", "SimpleQueue", "LifoQueue", "PriorityQueue", "Thread",
}
#: container methods that mutate their receiver (shared with lint/protocol)
_CONTAINER_MUTATORS = {
    "append", "extend", "insert", "remove", "clear", "pop", "popleft",
    "appendleft", "update", "add", "discard", "setdefault", "popitem",
}

LOCK_RULES = {
    "lock-shared-unlocked": (
        "shared attribute of a concurrent class accessed without any lock"),
    "lock-inconsistent": (
        "shared attribute accessed under locks with no common member"),
    "lock-order-cycle": (
        "lock-ordering graph contains a cycle (potential deadlock)"),
}


@dataclasses.dataclass(frozen=True)
class Access:
    """One read/write of a shared attribute, with the locally held locks."""

    attr: str
    method: str
    kind: str                   # "read" | "write"
    line: int
    col: int
    held: FrozenSet[str]


@dataclasses.dataclass
class MethodSummary:
    name: str
    lineno: int
    accesses: List[Access] = dataclasses.field(default_factory=list)
    #: (lock name, line, locks held at the acquisition)
    acquisitions: List[Tuple[str, int, FrozenSet[str]]] = (
        dataclasses.field(default_factory=list))
    #: (callee method name, line, locks held at the call)
    calls: List[Tuple[str, int, FrozenSet[str]]] = (
        dataclasses.field(default_factory=list))


@dataclasses.dataclass
class ClassModel:
    """Everything the lockset pass inferred about one class."""

    name: str
    path: str
    lineno: int
    lock_attrs: Set[str] = dataclasses.field(default_factory=set)
    sync_attrs: Set[str] = dataclasses.field(default_factory=set)
    thread_targets: Set[str] = dataclasses.field(default_factory=set)
    creates_thread: bool = False
    #: attr -> class name, from annotated `__init__` params / AnnAssign
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    mutable_attrs: Set[str] = dataclasses.field(default_factory=set)
    methods: Dict[str, MethodSummary] = dataclasses.field(default_factory=dict)
    #: method -> guaranteed entry lockset (filled by `entry_locksets`)
    entry: Dict[str, FrozenSet[str]] = dataclasses.field(default_factory=dict)

    @property
    def concurrent(self) -> bool:
        return bool(self.lock_attrs) or self.creates_thread

    def is_entry(self, method: str) -> bool:
        return (not method.startswith("_")
                or (method.startswith("__") and method.endswith("__"))
                or method in self.thread_targets)


# -------------------------------------------------------------- AST helpers


def _self_attr_path(node: ast.AST, selfname: str) -> Optional[str]:
    """'cond' for self.cond, 'store.cond' for self.store.cond, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name) and node.id == selfname:
        return ".".join(reversed(parts))
    return None


def _factory_name(value: ast.AST) -> Optional[str]:
    """'Lock' for threading.Lock() / Lock(); None for anything else."""
    if isinstance(value, ast.Call):
        f = value.func
        if isinstance(f, ast.Attribute):
            return f.attr
        if isinstance(f, ast.Name):
            return f.id
    return None


def _ann_name(ann: Optional[ast.AST]) -> Optional[str]:
    """Last component of an annotation ('ParameterStore' for both the bare
    name and a dotted/stringified form); None when unannotated."""
    if ann is None:
        return None
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split(".")[-1].strip()
    return None


def _selfname(fn: ast.FunctionDef) -> Optional[str]:
    args = fn.args.posonlyargs + fn.args.args
    return args[0].arg if args else None


# ----------------------------------------------------------- model building


class _MethodScanner:
    """Recursive scan of one method body tracking the locally held lockset."""

    def __init__(self, model: ClassModel, summary: MethodSummary,
                 selfname: str):
        self.model = model
        self.sum = summary
        self.selfname = selfname

    def _is_lockish(self, path: str) -> bool:
        # single-component paths must be known lock attrs; dotted paths
        # (another object's lock, e.g. self.store.cond) are trusted as locks
        # when used as a bare `with` context — files/devices enter via calls.
        return path in self.model.lock_attrs or "." in path

    def _record_access(self, attr: str, kind: str, node: ast.AST,
                       held: FrozenSet[str]):
        self.sum.accesses.append(Access(
            attr=attr, method=self.sum.name, kind=kind,
            line=node.lineno, col=node.col_offset, held=held))

    def _write_target(self, target: ast.AST, held: FrozenSet[str]):
        """Classify an assignment/deletion target; returns True if handled."""
        if isinstance(target, ast.Attribute):
            path = _self_attr_path(target, self.selfname)
            if path is not None and "." not in path:
                self._record_access(path, "write", target, held)
                return True
        elif isinstance(target, ast.Subscript):
            path = _self_attr_path(target.value, self.selfname)
            if path is not None and "." not in path:
                self._record_access(path, "write", target, held)
            self.scan(target.slice, held)
            return True
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                if not self._write_target(elt, held):
                    self.scan(elt, held)
            return True
        return False

    def scan(self, node: ast.AST, held: FrozenSet[str]):
        if isinstance(node, ast.With):
            acquired = []
            for item in node.items:
                expr = item.context_expr
                if isinstance(expr, ast.Attribute):
                    path = _self_attr_path(expr, self.selfname)
                    if path is not None and self._is_lockish(path):
                        self.sum.acquisitions.append((path, expr.lineno, held))
                        acquired.append(path)
                        continue
                self.scan(expr, held)
                if item.optional_vars is not None:
                    self._write_target(item.optional_vars, held)
            inner = held | frozenset(acquired)
            for stmt in node.body:
                self.scan(stmt, inner)
            return
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if not self._write_target(t, held):
                    self.scan(t, held)
            if isinstance(node, ast.AugAssign):
                # aug-assign reads the old value too; the write record covers
                # the lockset requirement, no separate read needed
                pass
            if getattr(node, "value", None) is not None:
                self.scan(node.value, held)
            return
        if isinstance(node, ast.Delete):
            for t in node.targets:
                if not self._write_target(t, held):
                    self.scan(t, held)
            return
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                recv = _self_attr_path(f.value, self.selfname)
                if recv is not None and "." not in recv and recv:
                    if f.attr in _CONTAINER_MUTATORS:
                        self._record_access(recv, "write", f.value, held)
                    elif recv not in self.model.lock_attrs | self.model.sync_attrs:
                        self._record_access(recv, "read", f.value, held)
                elif (isinstance(f.value, ast.Name)
                        and f.value.id == self.selfname):
                    self.sum.calls.append((f.attr, node.lineno, held))
                else:
                    self.scan(f.value, held)
            else:
                self.scan(f, held)
            for a in node.args:
                self.scan(a, held)
            for kw in node.keywords:
                self.scan(kw.value, held)
            return
        if isinstance(node, ast.Attribute):
            path = _self_attr_path(node, self.selfname)
            if (path is not None and "." not in path
                    and isinstance(node.ctx, ast.Load)):
                if path not in self.model.lock_attrs | self.model.sync_attrs:
                    self._record_access(path, "read", node, held)
                return
            # dotted self.a.b read: the inner self.a is the interesting access
            if path is not None:
                first = path.split(".")[0]
                if first not in self.model.lock_attrs | self.model.sync_attrs:
                    self._record_access(first, "read", node, held)
                return
            self.scan(node.value, held)
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            # nested callables inherit the current lockset: the dominant use
            # here is `cond.wait_for(lambda: ...)`, whose predicate runs
            # under the re-acquired lock
            body = node.body if isinstance(node.body, list) else [node.body]
            for stmt in body:
                self.scan(stmt, held)
            return
        for child in ast.iter_child_nodes(node):
            self.scan(child, held)


def _inventory_class(cls: ast.ClassDef, path: str) -> ClassModel:
    """Pass 1: attribute inventory (locks / sync / thread targets / mutable)
    + pass 2: per-method access scan with local locksets."""
    model = ClassModel(name=cls.name, path=path, lineno=cls.lineno)
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    # pass 1 — inventory
    mutated_outside_init: Set[str] = set()
    for fn in methods:
        selfname = _selfname(fn)
        if selfname is None:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                fac = _factory_name(node)
                if fac == "Thread":
                    model.creates_thread = True
                    for kw in node.keywords:
                        if kw.arg == "target":
                            tpath = _self_attr_path(kw.value, selfname)
                            if tpath and "." not in tpath:
                                model.thread_targets.add(tpath)
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in _CONTAINER_MUTATORS):
                    rpath = _self_attr_path(f.value, selfname)
                    if (rpath and "." not in rpath
                            and fn.name != "__init__"):
                        mutated_outside_init.add(rpath)
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = list(node.targets)
            for t in targets:
                apath = None
                if isinstance(t, ast.Attribute):
                    apath = _self_attr_path(t, selfname)
                elif isinstance(t, ast.Subscript):
                    apath = _self_attr_path(t.value, selfname)
                if apath is None or "." in apath:
                    continue
                if isinstance(node, ast.Assign):
                    fac = _factory_name(node.value)
                    if fac in _LOCK_FACTORIES:
                        model.lock_attrs.add(apath)
                        continue
                    if fac in _SYNC_FACTORIES:
                        model.sync_attrs.add(apath)
                        continue
                if fn.name != "__init__":
                    mutated_outside_init.add(apath)
                if (fn.name == "__init__" and isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Name)):
                    ann = _init_param_type(fn, node.value.id)
                    if ann:
                        model.attr_types[apath] = ann
            if (isinstance(node, ast.AnnAssign)
                    and isinstance(node.target, ast.Attribute)):
                apath = _self_attr_path(node.target, selfname)
                ann = _ann_name(node.annotation)
                if apath and "." not in apath and ann:
                    model.attr_types.setdefault(apath, ann)
    model.mutable_attrs = (mutated_outside_init
                           - model.lock_attrs - model.sync_attrs)

    # pass 2 — access scan
    for fn in methods:
        selfname = _selfname(fn)
        if selfname is None:
            continue
        summary = MethodSummary(name=fn.name, lineno=fn.lineno)
        scanner = _MethodScanner(model, summary, selfname)
        for stmt in fn.body:
            scanner.scan(stmt, frozenset())
        model.methods[fn.name] = summary
    return model


def _init_param_type(init: ast.FunctionDef, param: str) -> Optional[str]:
    for arg in (init.args.posonlyargs + init.args.args
                + init.args.kwonlyargs):
        if arg.arg == param:
            return _ann_name(arg.annotation)
    return None


def collect_models(source: str, path: str) -> List[ClassModel]:
    """Parse one module and build a `ClassModel` per concurrent class."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            model = _inventory_class(node, path.replace(os.sep, "/"))
            if model.concurrent:
                out.append(model)
    return out


# -------------------------------------------------------- entry lockset prop


def entry_locksets(model: ClassModel) -> Dict[str, FrozenSet[str]]:
    """Guaranteed-held-at-entry lockset per method: entry points get the
    empty set; a helper gets the intersection over its intra-class call
    sites of (caller entry | held-at-call); orphans are conservative-empty.
    Monotone-decreasing fixpoint, so cycles terminate."""
    entry: Dict[str, FrozenSet[str]] = {
        m: frozenset() for m in model.methods if model.is_entry(m)}
    changed = True
    while changed:
        changed = False
        for m, summ in model.methods.items():
            if m not in entry:
                continue
            base = entry[m]
            for callee, _line, held in summ.calls:
                if callee not in model.methods or model.is_entry(callee):
                    continue
                contrib = base | held
                if callee not in entry:
                    entry[callee] = contrib
                    changed = True
                elif entry[callee] - contrib:
                    entry[callee] &= contrib
                    changed = True
    for m in model.methods:
        entry.setdefault(m, frozenset())
    model.entry = entry
    return entry


# ------------------------------------------------------------- the discipline


def _fmt_lockset(locks: FrozenSet[str]) -> str:
    return "{" + ", ".join(sorted(locks)) + "}" if locks else "no lock"


def check_lock_discipline(model: ClassModel,
                          lines: List[str]) -> List[Finding]:
    """lock-shared-unlocked / lock-inconsistent findings for one class."""
    entry = entry_locksets(model)
    findings: List[Finding] = []
    for attr in sorted(model.mutable_attrs):
        sites = []
        for m, summ in model.methods.items():
            if m == "__init__":
                continue
            for acc in summ.accesses:
                if acc.attr == attr:
                    sites.append((acc, entry[m] | acc.held))
        if not sites:
            continue
        common = frozenset.intersection(*[eff for _, eff in sites])
        if common:
            continue
        unlocked = [(acc, eff) for acc, eff in sites if not eff]
        if unlocked:
            acc, _ = next(((a, e) for a, e in unlocked if a.kind == "write"),
                          unlocked[0])
            others = frozenset().union(*[eff for _, eff in sites])
            hint = (f" (other sites hold {_fmt_lockset(others)})"
                    if others else "")
            msg = (f"{model.name}.{attr} is shared across threads but "
                   f"`{acc.method}` {acc.kind}s it with no lock held{hint}; "
                   f"every access to a shared attribute must hold a common "
                   f"lock")
            findings.append(_finding("lock-shared-unlocked", model.path,
                                     acc.line, acc.col, msg, lines))
        else:
            per = sorted({f"{acc.method}:{_fmt_lockset(eff)}"
                          for acc, eff in sites})
            acc = sites[0][0]
            msg = (f"{model.name}.{attr} is accessed under locks with no "
                   f"common member ({'; '.join(per)}); two different locks "
                   f"do not exclude each other")
            findings.append(_finding("lock-inconsistent", model.path,
                                     acc.line, acc.col, msg, lines))
    return findings


# ---------------------------------------------------------- lock-order graph


def _lock_node(model: ClassModel, lockname: str) -> str:
    """Global identity of a lock: 'ParameterStore.cond' both for the store's
    own `self.cond` and for `Chief`'s `self.store.cond` (resolved through
    the annotated `__init__` parameter)."""
    parts = lockname.split(".")
    if len(parts) == 1:
        return f"{model.name}.{lockname}"
    owner = model.attr_types.get(parts[0])
    if owner:
        return f"{owner}.{'.'.join(parts[1:])}"
    return f"{model.name}.{lockname}"


def lock_order_graph(models: Sequence[ClassModel]
                     ) -> Dict[Tuple[str, str], Tuple[str, int]]:
    """(held, acquired) -> one witness (path, line) per ordered lock pair,
    including acquisitions reached through transitive self-calls."""
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for model in models:
        entry = model.entry or entry_locksets(model)
        # transitive closure: locks a method may acquire, directly or via
        # self-calls (monotone-increasing fixpoint)
        closure: Dict[str, Set[str]] = {
            m: {name for name, _l, _h in s.acquisitions}
            for m, s in model.methods.items()}
        changed = True
        while changed:
            changed = False
            for m, summ in model.methods.items():
                for callee, _line, _held in summ.calls:
                    extra = closure.get(callee, set()) - closure[m]
                    if extra:
                        closure[m] |= extra
                        changed = True
        for m, summ in model.methods.items():
            for name, line, held in summ.acquisitions:
                eff = entry[m] | held
                node = _lock_node(model, name)
                for h in eff:
                    hn = _lock_node(model, h)
                    if hn != node:
                        edges.setdefault((hn, node), (model.path, line))
            for callee, line, held in summ.calls:
                eff = entry[m] | held
                if not eff or callee not in model.methods:
                    continue
                for name in closure.get(callee, ()):
                    node = _lock_node(model, name)
                    for h in eff:
                        hn = _lock_node(model, h)
                        if hn != node:
                            edges.setdefault((hn, node), (model.path, line))
    return edges


def find_cycles(edges: Dict[Tuple[str, str], Tuple[str, int]]
                ) -> List[List[str]]:
    """Cycles in the lock-order graph (each reported once, as a node list
    `[a, b, ..., a]`), via DFS from each node in sorted order."""
    adj: Dict[str, List[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    cycles: List[List[str]] = []
    seen_cycles: Set[FrozenSet[str]] = set()

    def dfs(start: str, node: str, path: List[str], onpath: Set[str]):
        for nxt in sorted(adj.get(node, ())):
            if nxt == start:
                key = frozenset(path)
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cycles.append(path + [start])
            elif nxt not in onpath and nxt > start:
                # only walk nodes > start so each cycle is found from its
                # smallest node exactly once
                dfs(start, nxt, path + [nxt], onpath | {nxt})

    for n in sorted(adj):
        dfs(n, n, [n], {n})
    return cycles


def check_lock_order(models: Sequence[ClassModel],
                     sources: Dict[str, List[str]]) -> List[Finding]:
    edges = lock_order_graph(models)
    findings = []
    for cyc in find_cycles(edges):
        path, line = edges[(cyc[0], cyc[1])]
        msg = (f"lock-ordering cycle {' -> '.join(cyc)}: two threads taking "
               f"these locks in opposite orders can deadlock; fix a global "
               f"acquisition order")
        findings.append(_finding("lock-order-cycle", path, line, 0, msg,
                                 sources.get(path, [])))
    return findings


# ------------------------------------------------------------------- driver


def _finding(rule: str, path: str, line: int, col: int, msg: str,
             lines: List[str]) -> Finding:
    text = lines[line - 1].strip() if 0 < line <= len(lines) else ""
    return Finding(rule=rule, path=path, line=line, col=col, message=msg,
                   line_text=text)


def analyze_source(source: str, path: str
                   ) -> Tuple[List[Finding], List[ClassModel]]:
    """Lockset + order analysis of one module (the unit-test entry point).
    Inline `# lint: allow[...]` tags are honored."""
    models = collect_models(source, path)
    lines = source.splitlines()
    findings: List[Finding] = []
    for m in models:
        findings.extend(check_lock_discipline(m, lines))
    findings.extend(check_lock_order(models, {m.path: lines for m in models}))
    return ([f for f in findings if not _inline_allowed(f, lines)], models)


def run_locks(paths: Sequence[str]
              ) -> Tuple[List[Finding], List[ClassModel]]:
    """Analyze every .py file under `paths`. The lock-order graph is built
    globally so cross-class edges (Chief holding its own lock while taking
    the store's) order against the store's internal nesting."""
    models: List[ClassModel] = []
    sources: Dict[str, List[str]] = {}
    findings: List[Finding] = []
    for fp in iter_py_files(paths):
        with open(fp, encoding="utf-8") as fh:
            source = fh.read()
        lines = source.splitlines()
        for m in collect_models(source, fp):
            models.append(m)
            sources[m.path] = lines
            findings.extend(
                f for f in check_lock_discipline(m, lines)
                if not _inline_allowed(f, lines))
    findings.extend(
        f for f in check_lock_order(models, sources)
        if not _inline_allowed(f, sources.get(f.path, [])))
    return findings, models


def report(models: Sequence[ClassModel]) -> str:
    """Human-readable discovery table: what the pass found and protects."""
    out = []
    for m in sorted(models, key=lambda m: (m.path, m.lineno)):
        out.append(f"{m.path}:{m.lineno}: class {m.name}")
        out.append(f"  locks: {sorted(m.lock_attrs) or '-'}"
                   f"  sync: {sorted(m.sync_attrs) or '-'}"
                   f"  thread targets: {sorted(m.thread_targets) or '-'}")
        for attr in sorted(m.mutable_attrs):
            locksets = sorted({
                _fmt_lockset((m.entry or {}).get(a.method, frozenset())
                             | a.held)
                for s in m.methods.values() for a in s.accesses
                if a.attr == attr and s.name != "__init__"})
            out.append(f"  shared {attr}: {', '.join(locksets) or 'init-only'}")
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse
    import sys

    from repro.analysis import baseline as B

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.locks",
        description="repo-wide static lockset + lock-order analysis")
    ap.add_argument("paths", nargs="*", default=None)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--report", action="store_true",
                    help="print the discovery table (classes, locks, "
                         "shared attrs with their locksets)")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path {p!r}", file=sys.stderr)
            return 2
    findings, models = run_locks(paths)

    baseline_path = args.baseline
    if baseline_path is None and os.path.isfile(B.BASELINE_NAME):
        baseline_path = B.BASELINE_NAME
    if baseline_path:
        findings, _stale = B.apply_baseline(
            findings, B.load_baseline(baseline_path))

    if args.report:
        print(report(models))
    for f in findings:
        print(f.format())
    if findings:
        print(f"\n{len(findings)} lockset finding(s).", file=sys.stderr)
        return 1
    print(f"locks: {len(models)} concurrent class(es) clean")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
