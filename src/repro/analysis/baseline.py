"""Baseline suppression for the lint (`repro.analysis.baseline`).

The baseline is the committed list of *accepted* findings — legacy
occurrences that are correct but match a rule's pattern (the compile-window
timing syncs in the trainloop, for example). Each entry names its rule, file,
the stripped source line it matches, how many identical occurrences it
covers, and WHY it is accepted. Suppressed, not silenced: the reasons live in
the committed file, `--update-baseline` regenerates it mechanically, and a
stale entry (the code it covered is gone) is reported so the file shrinks
with the debt instead of accreting.

Matching is by (rule, path-suffix, stripped line text) so entries survive
line moves and unrelated edits but break — loudly — when the flagged line
itself changes.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence, Tuple

from repro.analysis.lint import Finding

BASELINE_NAME = "analysis-baseline.json"


def _key(rule: str, path: str, line_text: str) -> Tuple[str, str, str]:
    return (rule, path.replace(os.sep, "/"), line_text.strip())


def load_baseline(path: str) -> List[dict]:
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    entries = data["entries"] if isinstance(data, dict) else data
    for e in entries:
        e.setdefault("count", 1)
        e.setdefault("reason", "")
    return entries


def save_baseline(path: str, findings: Sequence[Finding],
                  reason: str = "TODO: justify or fix"):
    """Write every current finding as an accepted entry (identical findings
    collapse into one entry with a count). Starting point for triage — each
    entry's reason should be edited to say why it is accepted."""
    grouped: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        grouped[_key(f.rule, f.path, f.line_text)] = (
            grouped.get(_key(f.rule, f.path, f.line_text), 0) + 1)
    entries = [{"rule": r, "path": p, "line_text": t, "count": n,
                "reason": reason}
               for (r, p, t), n in sorted(grouped.items())]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"_comment": "Accepted lint findings (DESIGN.md §12). "
                               "Every entry needs a reason; shrink me.",
                   "entries": entries}, fh, indent=2)
        fh.write("\n")


def apply_baseline(findings: Sequence[Finding], entries: Sequence[dict]
                   ) -> Tuple[List[Finding], List[dict]]:
    """Split findings into (unsuppressed, stale_entries). A baseline entry
    absorbs up to `count` findings whose (rule, path-suffix, line text)
    match; entries with unused budget are stale — their code changed or was
    fixed — and should be pruned from the committed file."""
    budget: Dict[Tuple[str, str, str], int] = {}
    for e in entries:
        budget[_key(e["rule"], e["path"], e["line_text"])] = (
            budget.get(_key(e["rule"], e["path"], e["line_text"]), 0)
            + int(e["count"]))
    remaining: List[Finding] = []
    for f in findings:
        matched = None
        for (rule, path, text), left in budget.items():
            if left <= 0 or rule != f.rule or text != f.line_text.strip():
                continue
            fp = f.path.replace(os.sep, "/")
            if fp == path or fp.endswith("/" + path) or path.endswith("/" + fp):
                matched = (rule, path, text)
                break
        if matched is None:
            remaining.append(f)
        else:
            budget[matched] -= 1
    stale = []
    for e in entries:
        k = _key(e["rule"], e["path"], e["line_text"])
        if budget.get(k, 0) > 0:
            stale.append(e)
            budget[k] = 0  # report an entry once even if count > 1 unused
    return remaining, stale
