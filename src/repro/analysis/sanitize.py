"""Opt-in runtime race sanitizer (`repro.analysis.sanitize`, DESIGN.md §13).

The static lockset pass (`repro.analysis.locks`) proves what the AST can
see; this module watches what actually happens. When `REPRO_TSAN=1`,
`install()` patches the *module-level* `threading` binding of the repo's own
concurrency modules (never the stdlib's — instrumenting `queue`/`logging`
internals would drown the signal) with a facade whose `Lock` / `RLock` /
`Condition` / `Thread` are instrumented wrappers, and wraps `__setattr__` of
the concurrent classes. Recorded per thread:

  * the lock acquisition order — every (held, acquired) pair becomes an edge
    in a global lock-order table; observing both (A, B) and (B, A) is a
    lock-order inversion (two such threads can deadlock);
  * every attribute write with the writer's current lockset — the Eraser
    discipline: a field starts *exclusive* to its first-writing thread
    (construction is race-free by publication), turns *shared* when a second
    thread writes it, and from then on the intersection of write locksets
    must stay non-empty. An empty intersection is an unlocked shared write;
  * a thread exiting while still holding an instrumented lock.

Report wire format (one line per finding, stable for CI grepping):

    TSAN lock-order-inversion: <A> -> <B> at <site> conflicts with <B> -> <A> at <site>
    TSAN unlocked-shared-write: <Class>.<attr> written by <thread> with no common lock at <site>
    TSAN thread-exit-holding-lock: <thread> exited holding <lock>

Locks are named by their creation site (`Lock@path:line`), so reports read
against the source. `report()` returns the findings; the pytest session
fixture (tests/conftest.py) asserts it is empty at teardown, and `install()`
registers an atexit printer for non-pytest entry points (the dist smoke).
Everything is inert unless `REPRO_TSAN=1` — zero overhead in normal runs.
"""
from __future__ import annotations

import atexit
import functools
import os
import sys
import threading as _real
from typing import Dict, FrozenSet, List, Optional, Tuple

#: module path -> class names whose attribute writes are tracked
INSTRUMENTED: Dict[str, Tuple[str, ...]] = {
    "repro.dist.store": ("ParameterStore",),
    "repro.dist.chief": ("Chief",),
    "repro.data.prefetch": ("ChunkPrefetcher",),
    "repro.checkpoint.writer": ("AsyncCheckpointer",),
    "repro.resilience.supervisor": ("Supervisor", "LeaseTable"),
}


def enabled() -> bool:
    return os.environ.get("REPRO_TSAN", "") == "1"


def _site(depth: int = 2) -> str:
    f = sys._getframe(depth)
    return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"


# ------------------------------------------------------------- the registry


class _Registry:
    """Global acquisition-order table + per-thread held stack + Eraser
    write states. One instance per install(); thread-safe via its own
    (real, uninstrumented) lock."""

    def __init__(self):
        self._mu = _real.Lock()
        self._tl = _real.local()
        self._edges: Dict[Tuple[str, str], str] = {}   # (held, acq) -> site
        self._reports: List[str] = []
        self._seen: set = set()

    # --- held-lock stack (thread-local; [lock, name, reentry count]) ---

    def _held(self) -> list:
        h = getattr(self._tl, "held", None)
        if h is None:
            h = self._tl.held = []
        return h

    def lockset(self) -> FrozenSet[str]:
        return frozenset(name for _l, name, _n in self._held())

    def on_acquire(self, lock, name: str, site: str) -> None:
        held = self._held()
        for rec in held:
            if rec[0] is lock:
                rec[2] += 1          # reentrant re-acquire: no new edges
                return
        with self._mu:
            for _l, hname, _n in held:
                if hname == name:
                    # two locks from one creation site (e.g. two store
                    # instances): aggregated to one node, not orderable
                    continue
                edge, rev = (hname, name), (name, hname)
                if rev in self._edges:
                    key = ("inv", frozenset((edge, rev)))
                    if key not in self._seen:
                        self._seen.add(key)
                        self._reports.append(
                            f"TSAN lock-order-inversion: {hname} -> {name} "
                            f"at {site} conflicts with {name} -> {hname} "
                            f"at {self._edges[rev]}")
                self._edges.setdefault(edge, site)
        held.append([lock, name, 1])

    def on_release(self, lock) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is lock:
                held[i][2] -= 1
                if held[i][2] == 0:
                    del held[i]
                return

    def on_thread_exit(self) -> None:
        held = self._held()
        if held:
            with self._mu:
                for _l, name, _n in held:
                    self._reports.append(
                        f"TSAN thread-exit-holding-lock: "
                        f"{_real.current_thread().name} exited holding {name}")
            del held[:]

    # --- Eraser write states (stored on the instance, GC'd with it) ---

    def on_write(self, obj, attr: str, site: str) -> None:
        if attr == "_tsan_state_":
            return
        states = obj.__dict__.get("_tsan_state_")
        if states is None:
            states = {}
            object.__setattr__(obj, "_tsan_state_", states)
        tid = _real.get_ident()
        st = states.get(attr)
        if st is None:
            states[attr] = {"tid": tid}              # exclusive(first thread)
            return
        if "ls" not in st:
            if st["tid"] == tid:
                return                               # still exclusive
            st["ls"] = self.lockset()                # -> shared
        else:
            st["ls"] = st["ls"] & self.lockset()
        if not st["ls"]:
            key = ("usw", type(obj).__name__, attr)
            with self._mu:
                if key not in self._seen:
                    self._seen.add(key)
                    self._reports.append(
                        f"TSAN unlocked-shared-write: "
                        f"{type(obj).__name__}.{attr} written by "
                        f"{_real.current_thread().name} with no common lock "
                        f"at {site}")

    # --- reporting ---

    def report(self) -> List[str]:
        with self._mu:
            return list(self._reports)

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self._reports.clear()
            self._seen.clear()


# ------------------------------------------------------------ the wrappers


class _TsanLock:
    """Instrumented mutual-exclusion lock (Lock or RLock inner)."""

    def __init__(self, inner, registry: _Registry, name: str):
        self._inner = inner
        self._reg = registry
        self._name = name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._reg.on_acquire(self, self._name, _site())
        return got

    def release(self) -> None:
        self._reg.on_release(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self._inner.acquire()
        self._reg.on_acquire(self, self._name, _site())
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class _TsanCondition:
    """Instrumented Condition. The underlying lock stays 'held' across
    `wait` in the registry's view — conservative for ordering, exact for
    write locksets (a waiter is blocked, and `wait_for` predicates run
    under the re-acquired lock)."""

    def __init__(self, inner, registry: _Registry, name: str):
        self._inner = inner
        self._reg = registry
        self._name = name

    def acquire(self, *a, **kw) -> bool:
        got = self._inner.acquire(*a, **kw)
        if got:
            self._reg.on_acquire(self, self._name, _site())
        return got

    def release(self) -> None:
        self._reg.on_release(self)
        self._inner.release()

    def __enter__(self):
        self._inner.__enter__()
        self._reg.on_acquire(self, self._name, _site())
        return self

    def __exit__(self, *exc):
        self._reg.on_release(self)
        return self._inner.__exit__(*exc)

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()


class _Facade:
    """Drop-in for an instrumented module's `threading` global. Factories
    return wrappers named by creation site; everything else (Event,
    current_thread, main_thread, ...) passes through to the real module."""

    def __init__(self, registry: _Registry):
        self._reg = registry

    def Lock(self):
        return _TsanLock(_real.Lock(), self._reg, f"Lock@{_site()}")

    def RLock(self):
        return _TsanLock(_real.RLock(), self._reg, f"RLock@{_site()}")

    def Condition(self, lock=None):
        inner = _real.Condition(getattr(lock, "_inner", lock))
        return _TsanCondition(inner, self._reg, f"Condition@{_site()}")

    def Thread(self, *args, **kwargs):
        target = kwargs.get("target")
        if target is not None:
            reg = self._reg

            @functools.wraps(target)
            def run(*a, **kw):
                try:
                    return target(*a, **kw)
                finally:
                    reg.on_thread_exit()

            kwargs = dict(kwargs, target=run)
        return _real.Thread(*args, **kwargs)

    def __getattr__(self, name):
        return getattr(_real, name)


# ----------------------------------------------------------- install / report


_installed: Optional[dict] = None
_registry = _Registry()


def instrument_class(cls) -> None:
    """Route `cls` attribute writes through the Eraser write tracker.
    Used by `install()` on the repo's concurrent classes; also the unit-test
    entry point for racy fixture classes."""
    if getattr(cls, "_tsan_instrumented_", False):
        return
    orig = cls.__setattr__

    def __setattr__(self, name, value):
        _registry.on_write(self, name, _site())
        orig(self, name, value)

    cls._tsan_orig_setattr_ = orig
    cls.__setattr__ = __setattr__
    cls._tsan_instrumented_ = True


def uninstrument_class(cls) -> None:
    if getattr(cls, "_tsan_instrumented_", False):
        cls.__setattr__ = cls._tsan_orig_setattr_
        del cls._tsan_orig_setattr_
        cls._tsan_instrumented_ = False


def install() -> None:
    """Patch the instrumented modules' `threading` binding and class
    `__setattr__`s. Idempotent; must run before the objects under test are
    constructed (the pytest session fixture and CLI entry points do)."""
    global _installed
    if _installed is not None:
        return
    import importlib

    facade = _Facade(_registry)
    saved = {}
    for modname, classnames in INSTRUMENTED.items():
        mod = importlib.import_module(modname)
        saved[modname] = mod.threading
        mod.threading = facade
        for cn in classnames:
            instrument_class(getattr(mod, cn))
    _installed = saved
    atexit.register(_atexit_report)


def uninstall() -> None:
    global _installed
    if _installed is None:
        return
    import importlib

    for modname, orig in _installed.items():
        mod = importlib.import_module(modname)
        mod.threading = orig
        for cn in INSTRUMENTED[modname]:
            uninstrument_class(getattr(mod, cn))
    _installed = None


def report() -> List[str]:
    """The findings recorded so far (empty == clean)."""
    return _registry.report()


def reset() -> None:
    _registry.reset()


def _atexit_report() -> None:
    findings = _registry.report()
    if findings:
        print("\n".join(findings), file=sys.stderr)
        print(f"REPRO_TSAN: {len(findings)} finding(s)", file=sys.stderr)


def maybe_install() -> bool:
    """`install()` iff REPRO_TSAN=1; returns whether the sanitizer is on.
    The one-liner for entry points: `sanitize.maybe_install()`."""
    if enabled():
        install()
        return True
    return False
