"""Static checks for the `repro.dist` wire protocol and lock discipline
(`repro.analysis.protocol`, DESIGN.md §12).

The async parameter server's correctness rests on two invariants no unit
test of a single process can see:

  * the hello/pull/push/step/bye verb grammar — chief and worker must agree
    on the alphabet and the legal orderings (DESIGN.md §10's protocol table).
    `VERB_GRAMMAR` + the per-mode FSMs encode the table; `check_sequence`
    validates a concrete conversation trace against it, and `audit_verbs`
    statically extracts every verb `chief.py`/`worker.py` put on the wire (or
    dispatch on) and proves the sources speak exactly the grammar — a typo'd
    verb or an unhandled message shows up here, not as a hung socket;

  * lock discipline in `ParameterStore` — the store is the one mutable object
    shared by every connection thread, serialized by a single condition lock.
    `audit_lock_discipline` classifies the store's mutable attributes (any
    attribute assigned or container-mutated outside `__init__`), then walks
    every method proving each mutable access happens under `with self.cond:`
    — directly, or transitively via callers that hold the lock (the
    `_apply_locked` convention). A public method touching mutable state
    lock-free, or an internal helper reachable lock-free, is a violation.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

# ------------------------------------------------------------- the grammar

#: who may put which verb on the wire
VERB_GRAMMAR = {
    "worker": frozenset({"hello", "pull", "push", "step", "bye"}),
    "chief": frozenset({"welcome", "work", "done", "applied"}),
}

#: (state, verb) -> state; the interleaved wire conversation of ONE worker
#: connection, both directions. See dist/protocol.py's message table.
REPLAY_FSM = {
    ("init", "hello"): "greeted",
    ("greeted", "welcome"): "ready",
    ("ready", "pull"): "pulled",
    ("pulled", "work"): "working",
    ("pulled", "done"): "drained",
    ("working", "push"): "pushed",
    ("pushed", "applied"): "ready",
    ("drained", "bye"): "closed",
}
LIVE_FSM = {
    ("init", "hello"): "greeted",
    ("greeted", "welcome"): "ready",
    ("ready", "step"): "stepped",      # push-and-pull fused; g may be None
    ("stepped", "work"): "ready",
    ("stepped", "done"): "drained",
    ("drained", "bye"): "closed",
}
_FSMS = {"replay": REPLAY_FSM, "live": LIVE_FSM}


@dataclasses.dataclass(frozen=True)
class ProtocolViolation:
    """One illegal transition (or unknown verb) in a conversation trace."""

    index: int
    verb: str
    state: str
    allowed: Tuple[str, ...]

    def format(self) -> str:
        ok = ", ".join(self.allowed) or "<nothing: conversation over>"
        return (f"message[{self.index}] {self.verb!r} illegal in state "
                f"{self.state!r} (allowed: {ok})")


def check_sequence(verbs: Sequence[str], mode: str = "replay",
                   require_closed: bool = True) -> List[ProtocolViolation]:
    """Validate an interleaved wire trace (both directions) against the
    verb state machine of `mode`. Returns the violations; empty == legal.
    `require_closed` additionally demands the conversation ends in the
    closed state (bye exchanged)."""
    try:
        fsm = _FSMS[mode]
    except KeyError:
        raise ValueError(f"mode must be one of {sorted(_FSMS)}, got {mode!r}")
    state = "init"
    violations: List[ProtocolViolation] = []
    for i, verb in enumerate(verbs):
        nxt = fsm.get((state, verb))
        if nxt is None:
            allowed = tuple(sorted(v for (s, v) in fsm if s == state))
            violations.append(ProtocolViolation(
                index=i, verb=verb, state=state, allowed=allowed))
            # stay in state: report every downstream illegality, not just one
        else:
            state = nxt
    if require_closed and not violations and state != "closed":
        allowed = tuple(sorted(v for (s, v) in fsm if s == state))
        violations.append(ProtocolViolation(
            index=len(verbs), verb="<end>", state=state, allowed=allowed))
    return violations


# ------------------------------------------------ static source extraction


def _sent_verbs(tree: ast.AST) -> Set[str]:
    """String literals leading any tuple handed to a .send(...) call —
    covers plain tuples, conditional expressions and ("work",) + out."""
    verbs: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "send"):
            continue
        for arg in node.args:
            for sub in ast.walk(arg):
                if (isinstance(sub, ast.Tuple) and sub.elts
                        and isinstance(sub.elts[0], ast.Constant)
                        and isinstance(sub.elts[0].value, str)):
                    verbs.add(sub.elts[0].value)
    return verbs


def _dispatched_verbs(tree: ast.AST, alphabet: Set[str]) -> Set[str]:
    """Verbs a source compares a received message head against (== or !=),
    restricted to the protocol alphabet (mode strings etc. are not verbs)."""
    verbs: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        for comp in [node.left] + list(node.comparators):
            if (isinstance(comp, ast.Constant) and isinstance(comp.value, str)
                    and comp.value in alphabet):
                verbs.add(comp.value)
    return verbs


def audit_verbs(root: Optional[str] = None,
                sources: Optional[Dict[str, str]] = None) -> List[str]:
    """Prove chief.py and worker.py speak exactly the verb grammar.

    Pass `root` (a tree containing repro/dist/) to read the real sources, or
    `sources` = {"chief": <src>, "worker": <src>} for fixtures. Checks:
      * each side sends exactly its half of the alphabet (a typo'd or novel
        verb on the wire fails here);
      * the chief dispatches on every worker verb (an unhandled request
        would hang a socket, or hit the unknown-verb ValueError at runtime).
    Returns human-readable violation strings; empty == conformant.
    """
    if sources is None:
        if root is None:
            raise ValueError("audit_verbs needs a source root or a sources dict")
        sources = {}
        for name in ("chief", "worker"):
            path = _find_dist_file(root, f"{name}.py")
            if path is None:
                return [f"cannot locate dist/{name}.py under {root}"]
            with open(path, encoding="utf-8") as fh:
                sources[name] = fh.read()
    trees = {name: ast.parse(src) for name, src in sources.items()}
    alphabet = set(VERB_GRAMMAR["worker"]) | set(VERB_GRAMMAR["chief"])
    violations: List[str] = []
    for side, peer in (("worker", "chief"), ("chief", "worker")):
        sent = _sent_verbs(trees[side])
        expected = set(VERB_GRAMMAR[side])
        for verb in sorted(sent - expected):
            violations.append(
                f"{side}.py sends {verb!r}, not a {side} verb in the grammar "
                f"(allowed: {', '.join(sorted(expected))})")
        for verb in sorted(expected - sent):
            violations.append(
                f"{side}.py never sends {verb!r}; the {peer} will wait for "
                f"a message that cannot arrive")
    handled = _dispatched_verbs(trees["chief"], alphabet)
    for verb in sorted(set(VERB_GRAMMAR["worker"]) - handled):
        violations.append(
            f"chief.py never dispatches on worker verb {verb!r}; the request "
            f"would fall through to the unknown-verb error")
    return violations


def _find_dist_file(root: str, filename: str) -> Optional[str]:
    direct = os.path.join(root, "repro", "dist", filename)
    if os.path.isfile(direct):
        return direct
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in ("__pycache__", ".git")]
        if filename in filenames and os.path.basename(dirpath) == "dist":
            return os.path.join(dirpath, filename)
    return None


# -------------------------------------------------------- lock discipline


@dataclasses.dataclass(frozen=True)
class LockViolation:
    """A mutable-attribute access reachable without the store lock."""

    method: str
    attr: str
    line: int
    why: str

    def format(self) -> str:
        return f"{self.method}:{self.line}: self.{self.attr} — {self.why}"


class _MethodInfo:
    def __init__(self, name: str):
        self.name = name
        # (attr, locked, lineno) for every self.<mutable-attr> touch
        self.accesses: List[Tuple[str, bool, int]] = []
        # (callee, locked, lineno) for every self.<method>() call
        self.calls: List[Tuple[str, bool, int]] = []


def _collect_class(tree: ast.AST, classname: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == classname:
            return node
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


_CONTAINER_MUTATORS = {
    "append", "extend", "insert", "remove", "clear", "pop", "popleft",
    "appendleft", "update", "add", "discard", "setdefault", "popitem",
}


def audit_lock_discipline(root: Optional[str] = None, *,
                          source: Optional[str] = None,
                          path: Optional[str] = None,
                          classname: str = "ParameterStore",
                          lock_attrs: Sequence[str] = ("cond", "lock"),
                          exempt: Sequence[str] = ("__init__",),
                          ) -> List[LockViolation]:
    """Prove every mutable-attribute access of `classname` is lock-covered.

    Mutable attributes are inferred: anything assigned (plain, augmented,
    subscript or del) or container-mutated outside `__init__`. An access is
    covered when it sits inside `with self.cond:` (any name in `lock_attrs`),
    or when the enclosing method is only ever reachable through call sites
    that hold the lock (`_apply_locked` and its helpers). Violations:

      * a public (non-underscore) method touching mutable state lock-free —
        public methods are entry points and must take the lock themselves;
      * an internal helper with a lock-free mutable access that is reachable
        from a public method without passing a lock acquisition, or that has
        no intra-class call sites at all (nothing proves its callers lock).
    """
    if source is None:
        if path is None:
            if root is None:
                raise ValueError("audit_lock_discipline needs root, source "
                                 "or path")
            path = _find_dist_file(root, "store.py")
            if path is None:
                return [LockViolation("<module>", "", 0,
                                      f"cannot locate dist/store.py under {root}")]
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
    tree = ast.parse(source)
    cls = _collect_class(tree, classname)
    if cls is None:
        return [LockViolation("<module>", "", 0,
                              f"class {classname} not found")]

    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    # ---- pass 1: infer the mutable attribute set
    mutable: Set[str] = set()
    for m in methods:
        if m.name == "__init__":
            continue
        for node in ast.walk(m):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    attr = _self_attr(t)
                    if attr:
                        mutable.add(attr)
                    if isinstance(t, (ast.Subscript, ast.Starred)):
                        attr = _self_attr(t.value)
                        if attr:
                            mutable.add(attr)
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    if isinstance(t, ast.Subscript):
                        attr = _self_attr(t.value)
                        if attr:
                            mutable.add(attr)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in _CONTAINER_MUTATORS):
                attr = _self_attr(node.func.value)
                if attr:
                    mutable.add(attr)
    mutable -= set(lock_attrs)

    # ---- pass 2: per-method accesses and intra-class calls, lock-scoped
    infos: Dict[str, _MethodInfo] = {}

    def scan(node: ast.AST, info: _MethodInfo, locked: bool):
        if isinstance(node, ast.With):
            holds = any(_self_attr(item.context_expr) in lock_attrs
                        or (isinstance(item.context_expr, ast.Call)
                            and _self_attr(item.context_expr.func) in lock_attrs)
                        for item in node.items)
            for child in ast.iter_child_nodes(node):
                scan(child, info, locked or holds)
            return
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            callee = _self_attr(node.func)
            if callee is not None:
                info.calls.append((callee, locked, node.lineno))
        attr = _self_attr(node)
        if attr in mutable:
            info.accesses.append((attr, locked, node.lineno))
        for child in ast.iter_child_nodes(node):
            scan(child, info, locked)

    for m in methods:
        info = _MethodInfo(m.name)
        for stmt in m.body:
            scan(stmt, info, False)
        infos[m.name] = info

    # ---- pass 3: reachability — can a lock-free path reach the access?
    call_sites: Dict[str, List[Tuple[str, bool]]] = {}
    for caller, info in infos.items():
        for callee, locked, _ in info.calls:
            if callee in infos:
                call_sites.setdefault(callee, []).append((caller, locked))

    def unlocked_exposure(name: str, seen: Set[str]) -> Optional[Tuple[str, int]]:
        """First lock-free mutable access reachable from `name` entered
        without the lock (directly or via lock-free intra-class calls)."""
        if name in seen:
            return None
        seen.add(name)
        info = infos[name]
        for attr, locked, line in info.accesses:
            if not locked:
                return (attr, line)
        for callee, locked, line in info.calls:
            if locked or callee not in infos:
                continue
            hit = unlocked_exposure(callee, seen)
            if hit is not None:
                return hit
        return None

    violations: List[LockViolation] = []
    for name, info in infos.items():
        if name in exempt:
            continue
        exposure = unlocked_exposure(name, set())
        if exposure is None:
            continue
        attr, line = exposure
        if not name.startswith("_"):
            violations.append(LockViolation(
                method=name, attr=attr, line=line,
                why=f"public entry point reaches self.{attr} without "
                    f"holding the store lock"))
        else:
            sites = call_sites.get(name, [])
            if not sites:
                violations.append(LockViolation(
                    method=name, attr=attr, line=line,
                    why=f"helper touches self.{attr} lock-free and has no "
                        f"intra-class call sites proving its callers lock"))
            # helpers WITH call sites are judged through their callers'
            # exposure (the caller either locks or is itself flagged)
    return violations
