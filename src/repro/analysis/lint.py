"""AST lint for JAX hazards (`repro.analysis.lint`, DESIGN.md §12).

Every class of bug the first seven PRs fixed by hand maps onto a static
pattern; this module machine-checks them over `src/`:

  host-sync-in-hot-loop  host-device synchronization (`.item()`, `float()`,
                         `np.asarray`/`np.array`, `jax.device_get`,
                         `block_until_ready`) inside a configured hot scope —
                         the per-token serve path, the per-chunk fit loop.
                         One *batched* transfer per step is the accepted
                         shape; it carries an inline allow with its reason.
  jit-in-loop            `jax.jit` / `pl.pallas_call` constructed inside a
                         syntactic loop body: every iteration builds a fresh
                         callable, so the compilation cache never hits
                         (the retrace regressions of PR 5).
  traced-mutation        Python-side mutation of captured state inside a
                         traced function (a jit target or a function nested
                         in one): appends to closed-over lists, attribute /
                         subscript stores on parameters or captured objects.
                         Runs at trace time only — silently stale on cache
                         hits, duplicated on retraces.
  f32-in-f64-path        a `float32` dtype literal in an f64-parity-critical
                         module (`engine/delaysim.py`, `dist/*`,
                         `kernels/guided_update/*`). The one legitimate form
                         — `promote_types(dtype, float32)`, which promotes
                         and never demotes — is recognized and allowed.
  missing-donate         `jax.jit(...)` without `donate_argnums` in the
                         carry-threaded modules (trainloop / serve engine /
                         delaysim): a non-donated carry doubles train-state
                         memory and defeats in-place buffer reuse.
  x64-unscoped-jnp       `jnp` usage in `dist/*` outside a
                         `with enable_x64():` scope — the store's strategy
                         hooks only preserve float64 parity because every
                         jnp round-trip is x64-scoped (DESIGN.md §10).
  lock-not-with          bare `.acquire()` / `.release()` instead of
                         `with lock:` — an exception between the pair leaks
                         the lock forever. The sanitizer's instrumentation
                         shims are the accepted (baselined) exception.

Suppression is explicit, never silent:

  * an inline `# lint: allow[rule-id] reason` on the flagged line (or the
    line above) documents an accepted occurrence at the site;
  * the committed baseline file (`analysis-baseline.json`, see
    `repro.analysis.baseline`) carries the legacy exceptions — e.g. the
    compile-window timing syncs in the trainloop — each with a reason.

`python -m repro.analysis src/` runs the lint plus the dist protocol audits
and exits nonzero on any unsuppressed finding, printing `path:line:col:
rule-id: message`.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

# ----------------------------------------------------------------- findings


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint hit. `line_text` (the stripped source line) is the baseline
    fingerprint: stable under line moves, invalidated by edits."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    line_text: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"


# ------------------------------------------------------------ configuration


@dataclasses.dataclass
class LintConfig:
    """Which modules each rule applies to. Paths are matched as suffixes
    (file patterns) or substrings (patterns ending in '/'). `hot_scopes`
    maps a module to {function qualname: "all" | "loops"} — "all" treats the
    whole function body as hot (per-token serve methods), "loops" only its
    syntactic loop bodies (the fit loop's function also does one-time
    setup/teardown that may legitimately sync)."""

    hot_scopes: Dict[str, Dict[str, str]] = dataclasses.field(default_factory=dict)
    f64_parity_modules: Tuple[str, ...] = ()
    donate_modules: Tuple[str, ...] = ()
    x64_modules: Tuple[str, ...] = ()


DEFAULT_CONFIG = LintConfig(
    hot_scopes={
        "repro/serve/engine.py": {
            "ServeEngine.step": "all",            # per-token decode dispatch
            "ServeEngine._prefill_into": "all",   # per-request admission
            "ServeEngine._accept": "all",         # per-token bookkeeping
        },
        "repro/engine/trainloop.py": {
            "fit": "loops",          # the chunk dispatch loop
            "step_records": "all",   # per-dispatch metrics materialization
        },
    },
    f64_parity_modules=(
        "repro/engine/delaysim.py",
        "repro/dist/",
        "repro/kernels/guided_update/",
    ),
    donate_modules=(
        "repro/engine/trainloop.py",
        "repro/serve/engine.py",
        "repro/engine/delaysim.py",
    ),
    x64_modules=("repro/dist/",),
)

#: method names whose bare call is a device->host synchronization
_SYNC_METHODS = {"item", "block_until_ready"}
#: (module alias, attr) call pairs that synchronize
_SYNC_CALLS = {
    ("np", "asarray"), ("np", "array"),
    ("numpy", "asarray"), ("numpy", "array"),
    ("jax", "device_get"), ("jax", "block_until_ready"),
}
#: list/set/dict/deque mutators that leak state out of a trace
_MUTATING_METHODS = {
    "append", "extend", "insert", "remove", "clear", "pop", "popleft",
    "appendleft", "update", "add", "discard", "setdefault", "popitem",
}


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _module_match(path: str, patterns: Sequence[str]) -> bool:
    p = _norm(path)
    for pat in patterns:
        if pat.endswith("/"):
            if pat in p:
                return True
        elif p.endswith(pat):
            return True
    return False


def _scope_table(path: str, config: LintConfig) -> Dict[str, str]:
    p = _norm(path)
    for pat, scopes in config.hot_scopes.items():
        if p.endswith(pat):
            return scopes
    return {}


# ----------------------------------------------------------------- visitor


def _call_target(node: ast.Call) -> Tuple[Optional[str], Optional[str]]:
    """('np', 'asarray') for np.asarray(...), (None, 'float') for float(...)."""
    f = node.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        return f.value.id, f.attr
    if isinstance(f, ast.Attribute):
        return None, f.attr
    if isinstance(f, ast.Name):
        return None, f.id
    return None, None


def _is_jit_call(node: ast.Call) -> bool:
    base, attr = _call_target(node)
    return attr == "jit" and base in (None, "jax")


def _is_pallas_call(node: ast.Call) -> bool:
    _, attr = _call_target(node)
    return attr == "pallas_call"


def _bound_names(fn: ast.AST) -> set:
    """Names bound inside a function (params, assignments, loop/with/except
    targets, comprehensions, nested defs, imports) — everything else a Name
    refers to is captured from an enclosing scope."""
    bound = set()
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = fn.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            bound.add(arg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            if node is not fn:
                bound.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                bound.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
    return bound


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, config: LintConfig):
        self.path = _norm(path)
        self.lines = source.splitlines()
        self.config = config
        self.findings: List[Finding] = []
        self.scopes = _scope_table(path, config)
        self.f64_module = _module_match(path, config.f64_parity_modules)
        self.donate_module = _module_match(path, config.donate_modules)
        self.x64_module = _module_match(path, config.x64_modules)
        self._class_stack: List[str] = []
        self._fn_stack: List[ast.AST] = []
        self._loop_depth = 0
        self._hot_mode: List[str] = []        # active hot-scope modes
        self._x64_depth = 0
        self._traced_depth = 0                # inside a jit-target function
        self._traced_bound: List[set] = []    # locals of each traced frame
        self._promote_spans: List[Tuple[int, int]] = []
        self._jit_names: set = set()

    # ---------------------------------------------------------------- emit

    def _emit(self, rule: str, node: ast.AST, message: str):
        line = getattr(node, "lineno", 1)
        text = self.lines[line - 1].strip() if line - 1 < len(self.lines) else ""
        self.findings.append(Finding(rule=rule, path=self.path, line=line,
                                     col=getattr(node, "col_offset", 0),
                                     message=message, line_text=text))

    # ------------------------------------------------------------- prepass

    def prepass(self, tree: ast.Module):
        """Collect (a) names of functions handed to jax.jit / lax.scan, so
        their bodies count as traced; (b) promote_types call spans, inside
        which float32 literals are the accepted promotion idiom."""
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            base, attr = _call_target(node)
            if attr == "promote_types":
                self._promote_spans.append(
                    (node.lineno, node.end_lineno or node.lineno))
            if _is_jit_call(node) and node.args:
                for sub in ast.walk(node.args[0]):
                    if isinstance(sub, ast.Name):
                        self._jit_names.add(sub.id)
            if attr == "scan" and base in ("lax", None) and node.args:
                for sub in ast.walk(node.args[0]):
                    if isinstance(sub, ast.Name):
                        self._jit_names.add(sub.id)

    # ------------------------------------------------------------ scaffolds

    def visit_ClassDef(self, node: ast.ClassDef):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _qualname(self, name: str) -> str:
        return ".".join(self._class_stack + [name]) if self._class_stack else name

    def _is_traced_def(self, node) -> bool:
        if node.name in self._jit_names:
            return True
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                if _is_jit_call(dec):
                    return True
                base, attr = _call_target(dec)
                if attr == "partial" and dec.args:
                    first = dec.args[0]
                    if isinstance(first, (ast.Attribute, ast.Name)):
                        b, a = _call_target(ast.Call(func=first, args=[], keywords=[]))
                        if a == "jit" and b in (None, "jax"):
                            return True
            elif isinstance(dec, (ast.Attribute, ast.Name)):
                b, a = _call_target(ast.Call(func=dec, args=[], keywords=[]))
                if a == "jit" and b in (None, "jax"):
                    return True
        return False

    def visit_FunctionDef(self, node: ast.FunctionDef):
        qual = self._qualname(node.name)
        mode = self.scopes.get(qual)
        traced = self._is_traced_def(node) or self._traced_depth > 0
        self._fn_stack.append(node)
        if mode:
            self._hot_mode.append(mode)
        if traced:
            self._traced_depth += 1
            self._traced_bound.append(_bound_names(node))
        saved_loop = self._loop_depth
        self._loop_depth = 0
        self.generic_visit(node)
        self._loop_depth = saved_loop
        if traced:
            self._traced_depth -= 1
            self._traced_bound.pop()
        if mode:
            self._hot_mode.pop()
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _visit_loop(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = visit_While = visit_AsyncFor = _visit_loop

    def visit_With(self, node: ast.With):
        x64 = any(
            isinstance(item.context_expr, ast.Call)
            and _call_target(item.context_expr)[1] == "enable_x64"
            for item in node.items)
        if x64:
            self._x64_depth += 1
        self.generic_visit(node)
        if x64:
            self._x64_depth -= 1

    # ----------------------------------------------------------- the rules

    def _in_hot_scope(self) -> bool:
        if not self._hot_mode:
            return False
        mode = self._hot_mode[-1]
        return mode == "all" or (mode == "loops" and self._loop_depth > 0)

    def _traced_local(self, name: str) -> bool:
        """Is `name` bound inside the innermost traced function?"""
        return bool(self._traced_bound) and name in self._traced_bound[-1]

    def visit_Call(self, node: ast.Call):
        base, attr = _call_target(node)
        # host-sync-in-hot-loop
        if self._in_hot_scope():
            if attr in _SYNC_METHODS and isinstance(node.func, ast.Attribute):
                self._emit("host-sync-in-hot-loop", node,
                           f".{attr}() forces a host-device sync in a hot "
                           f"scope; batch transfers into one jax.device_get "
                           f"per step/chunk")
            elif (base, attr) in _SYNC_CALLS:
                self._emit("host-sync-in-hot-loop", node,
                           f"{base}.{attr}(...) synchronizes device->host in "
                           f"a hot scope; batch transfers into one "
                           f"jax.device_get per step/chunk")
            elif base is None and attr == "float" and isinstance(node.func, ast.Name):
                self._emit("host-sync-in-hot-loop", node,
                           "float(...) on a device value blocks in a hot "
                           "scope; keep scalars on device or batch the "
                           "transfer")
        # lock-not-with
        if (attr in ("acquire", "release")
                and isinstance(node.func, ast.Attribute)):
            self._emit("lock-not-with", node,
                       f"bare .{attr}() instead of `with lock:` — an "
                       f"exception between acquire and release leaks the "
                       f"lock and deadlocks every later taker; only "
                       f"instrumentation shims may do this (baselined)")
        # jit-in-loop
        if self._loop_depth > 0 and (_is_jit_call(node) or _is_pallas_call(node)):
            what = "pl.pallas_call" if _is_pallas_call(node) else "jax.jit"
            self._emit("jit-in-loop", node,
                       f"{what} constructed inside a loop body retraces every "
                       f"iteration (fresh callable, cold cache); hoist it out "
                       f"or memoize")
        # missing-donate
        if (self.donate_module and _is_jit_call(node)
                and not any(kw.arg in ("donate_argnums", "donate_argnames")
                            for kw in node.keywords)):
            self._emit("missing-donate", node,
                       "jax.jit without donate_argnums in a carry-threaded "
                       "module: a non-donated carry doubles train-state "
                       "memory across dispatches")
        # traced-mutation: captured-object mutators
        if (self._traced_depth and attr in _MUTATING_METHODS
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and not self._traced_local(node.func.value.id)):
            self._emit("traced-mutation", node,
                       f"{node.func.value.id}.{attr}(...) mutates captured "
                       f"state inside a traced function; runs at trace time "
                       f"only (stale on cache hits, doubled on retraces)")
        self.generic_visit(node)

    def _check_store_target(self, target: ast.AST):
        if not self._traced_depth:
            return
        if isinstance(target, ast.Attribute) and isinstance(target.value, ast.Name):
            name = target.value.id
            if not self._traced_local(name) or self._is_param(name):
                self._emit("traced-mutation", target,
                           f"attribute store on `{name}` inside a traced "
                           f"function is a Python-side effect the compiled "
                           f"program never sees")
        elif isinstance(target, ast.Subscript) and isinstance(target.value, ast.Name):
            name = target.value.id
            if not self._traced_local(name) or self._is_param(name):
                self._emit("traced-mutation", target,
                           f"subscript store on `{name}` inside a traced "
                           f"function mutates host state at trace time; use "
                           f"`.at[...].set(...)`")

    def _is_param(self, name: str) -> bool:
        if not self._fn_stack:
            return False
        fn = self._fn_stack[-1]
        a = fn.args
        params = {x.arg for x in a.posonlyargs + a.args + a.kwonlyargs}
        if a.vararg:
            params.add(a.vararg.arg)
        if a.kwarg:
            params.add(a.kwarg.arg)
        return name in params

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._check_store_target(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_store_target(node.target)
        if (self._traced_depth and isinstance(node.target, ast.Name)
                and not self._traced_local(node.target.id)):
            self._emit("traced-mutation", node,
                       f"augmented assignment to captured `{node.target.id}` "
                       f"inside a traced function leaks trace-time state")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            self._check_store_target(node.target)
        self.generic_visit(node)

    def _f32_allowed(self, node: ast.AST) -> bool:
        line = getattr(node, "lineno", 0)
        return any(a <= line <= b for a, b in self._promote_spans)

    def visit_Attribute(self, node: ast.Attribute):
        if (self.f64_module and node.attr == "float32"
                and isinstance(node.value, ast.Name)
                and node.value.id in ("np", "jnp", "numpy")
                and not self._f32_allowed(node)):
            self._emit("f32-in-f64-path", node,
                       f"{node.value.id}.float32 literal in an f64-parity-"
                       f"critical module; derive the dtype from the weights "
                       f"(promote_types) or it silently truncates the f64 "
                       f"trajectory")
        self.generic_visit(node)

    def visit_Constant(self, node: ast.Constant):
        if (self.f64_module and node.value == "float32"
                and not self._f32_allowed(node)):
            self._emit("f32-in-f64-path", node,
                       "'float32' dtype string in an f64-parity-critical "
                       "module; derive the dtype from the weights")

    def visit_Name(self, node: ast.Name):
        if (self.x64_module and node.id == "jnp"
                and isinstance(node.ctx, ast.Load) and self._x64_depth == 0):
            self._emit("x64-unscoped-jnp", node,
                       "jnp use in repro.dist outside `with enable_x64():` — "
                       "float64 parity only survives the jnp round-trip "
                       "inside an x64 scope (DESIGN.md §10)")


# --------------------------------------------------------------- inline allow


def _inline_allowed(finding: Finding, lines: List[str]) -> bool:
    """`# lint: allow[rule-id] reason` on the finding's line or the line
    above documents an accepted occurrence at the site."""
    tag = f"lint: allow[{finding.rule}]"
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(lines) and tag in lines[ln - 1]:
            return True
    return False


# ------------------------------------------------------------------- driver


def lint_source(source: str, path: str,
                config: LintConfig = DEFAULT_CONFIG) -> List[Finding]:
    """Lint one module's source text. Inline-allowed findings are dropped
    here; baseline suppression happens in `repro.analysis.baseline`."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="syntax-error", path=_norm(path),
                        line=e.lineno or 1, col=e.offset or 0,
                        message=str(e.msg), line_text="")]
    linter = _Linter(path, source, config)
    linter.prepass(tree)
    linter.visit(tree)
    lines = source.splitlines()
    return [f for f in linter.findings if not _inline_allowed(f, lines)]


def iter_py_files(paths: Sequence[str]):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def run_lint(paths: Sequence[str],
             config: LintConfig = DEFAULT_CONFIG) -> List[Finding]:
    """Lint every .py file under `paths` (files or directory roots)."""
    findings: List[Finding] = []
    for fp in iter_py_files(paths):
        with open(fp, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), fp, config))
    return findings


RULES = {
    "host-sync-in-hot-loop": "host-device sync call inside a configured hot scope",
    "jit-in-loop": "jax.jit / pl.pallas_call constructed inside a loop body",
    "traced-mutation": "Python-side mutation of captured state in a traced function",
    "f32-in-f64-path": "float32 dtype literal in an f64-parity-critical module",
    "missing-donate": "jax.jit without donate_argnums in a carry-threaded module",
    "x64-unscoped-jnp": "jnp use in repro.dist outside a `with enable_x64()` scope",
    "lock-not-with": "bare .acquire()/.release() instead of `with lock:`",
}
