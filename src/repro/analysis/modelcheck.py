"""Protocol model checker for `repro.dist` (`repro.analysis.modelcheck`,
DESIGN.md §13).

The socket pair exercises ONE interleaving per run; this module explores
them systematically. An in-memory chief + N simulated workers step through
the verb protocol (`REPLAY_FSM` / `LIVE_FSM`) as a nondeterministic
transition system, and a bounded DFS with sleep-set pruning (Godefroid)
enumerates every maximal schedule up to a depth bound — including the
kill / restart / elastic-join / drop events of `dist/scenarios.py`.

The models mirror the store's grant disciplines, not its arithmetic:

  * replay — per-worker dispatch queues from a `DelaySchedule`-shaped table
    `(t, worker, fetch_version)`; a pull blocks until
    `version >= fetch_version`, a push until `version == t` (the store's
    `wait_for` conditions become action-enabledness).
  * live — free-running: `step` applies in arrival order, nondeterministic
    drop branches, `late` counting past the budget, kill/restart events
    closing and reopening connections, elastic joins adding workers.

Invariant catalogue (each an executable predicate; see DESIGN.md §13 for
how to add one):

  version-monotone       every apply advances `version` by exactly one
                         (state check: version == number of applies)
  applied-exactly-once   every granted replay dispatch applies once —
                         no lost pushes, no double applies
  staleness-observed     each recorded staleness equals
                         applied_version - read_version
  schedule-order         replay's observed staleness sequence is exactly
                         the schedule's `t - fetch_version` column
  watchdog-termination   liveness: a state with no enabled action is
                         legal only when the watchdog would fire (all
                         workers dead) or the run completed its budget —
                         a stuck state with a live worker is a lost wakeup
  trace-legal            every connection's verb trace satisfies
                         `protocol.check_sequence` (closed connections
                         must reach `bye`; killed ones must be legal
                         prefixes)
  rollback-bounded       recovery: divergence rollbacks never exceed
                         `max_rollbacks` without the run going fatal —
                         remediation must not loop forever
  respawn-capped         recovery: a supervised worker is respawned at
                         most `max_respawns` times, then evicted — no
                         zombie respawn loops

The `RecoveryModel` (DESIGN.md §14) extends the live discipline with the
self-healing layer's semantics: sentinel-rejected pushes (a bad worker's
gradients never bump the version — the exactly-once/monotone core of the
rollback design), consecutive-rejection quarantine, bounded divergence
rollbacks, and capped supervisor respawns.

Every invariant has at least one seeded-bug fixture (`BUGS`) proving the
harness would catch its violation: nonmonotone, double-apply,
staleness-skew, grant-early, lost-wakeup, ghost-done, wrong-verb,
reject-bumps-version, rollback-unbounded, zombie-respawn.

CLI: `python -m repro.analysis.modelcheck` explores the stock config suite
(>= 10k interleavings at 2 workers, depth-bounded), then proves each
seeded bug is caught; nonzero exit on any invariant violation, uncaught
bug, or path shortfall. `make modelcheck` / `make check` wire it into CI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.analysis.protocol import check_sequence

# ------------------------------------------------------------------ actions


@dataclasses.dataclass(frozen=True)
class Action:
    """One enabled transition. `local` actions touch only their worker's
    state (compute, bye) — the independence relation sleep sets prune on."""

    label: str
    wid: int
    local: bool = False

    @property
    def key(self) -> Tuple[str, int]:
        return (self.label, self.wid)


def _independent(a: Tuple[str, int], b: Tuple[str, int],
                 local_labels: FrozenSet[str]) -> bool:
    """Two actions commute when they belong to different workers and at
    least one never touches the shared store."""
    return (a[1] != b[1]
            and (a[0] in local_labels or b[0] in local_labels))


@dataclasses.dataclass
class Violation:
    invariant: str
    detail: str
    path: Tuple[Tuple[str, int], ...]

    def format(self) -> str:
        trail = " ".join(f"{l}@{w}" for l, w in self.path)
        return f"{self.invariant}: {self.detail}\n  schedule: {trail}"


@dataclasses.dataclass
class Stats:
    states: int = 0
    paths: int = 0          # maximal executions: completed + stuck + truncated
    completed: int = 0
    stuck: int = 0
    truncated: int = 0
    pruned: int = 0         # subtrees skipped by sleep sets
    violations: List[Violation] = dataclasses.field(default_factory=list)


# ----------------------------------------------------------------- explorer


_LOCAL_LABELS = frozenset({"compute", "bye"})
_MAX_VIOLATIONS = 5


def explore(model, max_depth: int = 80,
            max_paths: Optional[int] = 500_000) -> Stats:
    """Bounded DFS over every schedule of `model` with sleep-set pruning.
    Counts maximal executions and collects invariant violations (with the
    offending action schedule as a counterexample)."""
    stats = Stats()
    path: List[Tuple[str, int]] = []

    def violate(inv: str, detail: str):
        if len(stats.violations) < _MAX_VIOLATIONS:
            stats.violations.append(Violation(inv, detail, tuple(path)))

    def rec(state, depth: int, sleep: FrozenSet[Tuple[str, int]]):
        if max_paths is not None and stats.paths >= max_paths:
            return
        stats.states += 1
        bad = model.invariant(state)
        if bad:
            violate(*bad)
            stats.paths += 1
            return
        acts = model.actions(state)
        if not acts:
            stats.paths += 1
            if model.is_final(state):
                stats.completed += 1
                bad = model.at_end(state)
            else:
                stats.stuck += 1
                bad = model.at_stuck(state)
            if bad:
                violate(*bad)
            return
        enabled = [a for a in acts if a.key not in sleep]
        if not enabled:
            stats.pruned += 1   # covered by a sibling ordering
            return
        if depth >= max_depth:
            stats.paths += 1
            stats.truncated += 1
            bad = model.at_stuck(state, truncated=True)
            if bad:
                violate(*bad)
            return
        explored: List[Tuple[str, int]] = []
        for a in enabled:
            child_sleep = frozenset(
                b for b in (set(sleep) | set(explored))
                if _independent(a.key, b, _LOCAL_LABELS))
            path.append(a.key)
            rec(model.apply(state, a), depth + 1, child_sleep)
            path.pop()
            explored.append(a.key)

    rec(model.initial(), 0, frozenset())
    return stats


# ------------------------------------------------------------- replay model

# worker phases
_READY, _GRANTED, _COMPUTED, _DRAINED, _CLOSED = (
    "ready", "granted", "computed", "drained", "closed")

# state tuple layout (replay):
#   (version, applied_counts, staleness, workers)
#   staleness: tuple of (t, recorded_s, served_read_version) per apply
#   workers: tuple per wid of (phase, queue_index, served_v, trace)


class ReplayModel:
    """The replay grant discipline over a schedule table
    `[(t, worker, fetch_version), ...]` (t = arrival step, ascending).
    `bug` seeds a deliberate defect (see BUGS)."""

    mode = "replay"

    def __init__(self, schedule: Sequence[Tuple[int, int, int]],
                 n_workers: int = 2, bug: Optional[str] = None):
        for t, (tt, w, fv) in enumerate(schedule):
            if tt != t or fv > t or w >= n_workers:
                raise ValueError(f"bad schedule row {t}: {(tt, w, fv)}")
        self.schedule = tuple(schedule)
        self.n_workers = n_workers
        self.bug = bug
        self.queues: Tuple[Tuple[Tuple[int, int], ...], ...] = tuple(
            tuple((t, fv) for t, w, fv in schedule if w == wid)
            for wid in range(n_workers))

    def initial(self):
        workers = tuple((_READY, 0, -1, ("hello", "welcome"))
                        for _ in range(self.n_workers))
        return (0, (0,) * len(self.schedule), (), workers)

    def actions(self, state) -> List[Action]:
        version, _applied, _stal, workers = state
        acts: List[Action] = []
        for wid, (phase, qi, _sv, _trace) in enumerate(workers):
            q = self.queues[wid]
            if phase == _READY:
                if qi >= len(q):
                    acts.append(Action("pull", wid))       # -> done/drained
                else:
                    t, fv = q[qi]
                    gate = (version == fv if self.bug == "lost-wakeup"
                            else version >= fv)
                    if self.bug == "grant-early" or gate:
                        acts.append(Action("pull", wid))
            elif phase == _GRANTED:
                acts.append(Action("compute", wid, local=True))
            elif phase == _COMPUTED:
                t, fv = q[qi]
                if version == t:
                    acts.append(Action("push", wid))
            elif phase == _DRAINED:
                acts.append(Action("bye", wid, local=True))
        return acts

    def apply(self, state, a: Action):
        version, applied, stal, workers = state
        phase, qi, sv, trace = workers[a.wid]
        q = self.queues[a.wid]
        if a.label == "pull":
            if qi >= len(q):
                w2 = (_DRAINED, qi, -1, trace + ("pull", "done"))
            else:
                # serve the weights AS OF the scheduled fetch version; the
                # grant-early bug serves whatever exists at pull time
                served = min(q[qi][1], version)
                w2 = (_GRANTED, qi, served, trace + ("pull", "work"))
            return (version, applied, stal,
                    workers[:a.wid] + (w2,) + workers[a.wid + 1:])
        if a.label == "compute":
            w2 = (_COMPUTED, qi, sv, trace)
            return (version, applied, stal,
                    workers[:a.wid] + (w2,) + workers[a.wid + 1:])
        if a.label == "bye":
            w2 = (_CLOSED, qi, sv, trace + ("bye",))
            return (version, applied, stal,
                    workers[:a.wid] + (w2,) + workers[a.wid + 1:])
        # push: the apply path. A double-apply bug applies the same granted
        # push twice (the retry-after-timeout failure), advancing version
        # twice — monotone holds, exactly-once does not.
        t, _fv = q[qi]
        n = 2 if self.bug == "double-apply" else 1
        applied = applied[:t] + (applied[t] + n,) + applied[t + 1:]
        bump = n
        if self.bug == "nonmonotone" and t == 1:
            bump = 2               # lost notify coalesces two version bumps
        version = version + bump
        s = t - sv
        if self.bug == "staleness-skew":
            s = max(0, s - 1)
        stal = stal + ((t, s, sv),)
        w2 = (_READY, qi + 1, -1, trace + ("push", "applied"))
        return (version, applied, stal,
                workers[:a.wid] + (w2,) + workers[a.wid + 1:])

    # ---- invariants

    def invariant(self, state):
        version, applied, stal, _workers = state
        if version != sum(applied):
            return ("version-monotone",
                    f"version={version} after {sum(applied)} applies — "
                    f"an apply must advance the version by exactly one")
        for t, n in enumerate(applied):
            if n > 1:
                return ("applied-exactly-once",
                        f"dispatch t={t} applied {n} times")
        for t, s, rv in stal:
            if s != t - rv:
                return ("staleness-observed",
                        f"dispatch t={t} recorded staleness {s}, but "
                        f"applied_version - read_version = {t - rv}")
        return None

    def is_final(self, state) -> bool:
        return all(w[0] == _CLOSED for w in state[3])

    def at_end(self, state):
        version, applied, stal, workers = state
        if any(n != 1 for n in applied):
            missing = [t for t, n in enumerate(applied) if n == 0]
            return ("applied-exactly-once",
                    f"run completed with unapplied dispatches {missing}")
        want = tuple((t, t - fv) for t, _w, fv in self.schedule)
        got = tuple((t, s) for t, s, _rv in stal)
        if got != want:
            return ("schedule-order",
                    f"staleness sequence {got} != schedule column {want}")
        for _phase, _qi, _sv, trace in workers:
            bad = check_sequence(trace, self.mode, require_closed=True)
            if bad:
                return ("trace-legal", bad[0].format())
        return None

    def at_stuck(self, state, truncated: bool = False):
        if truncated:
            return None     # depth bound, not a deadlock
        version, _applied, _stal, workers = state
        blocked = [wid for wid, w in enumerate(workers) if w[0] != _CLOSED]
        return ("watchdog-termination",
                f"deadlock at version={version}: workers {blocked} blocked "
                f"with no enabled action (the watchdog would abort the run)")


# --------------------------------------------------------------- live model

# extra live phases
_FRESH, _HASPARAMS, _DEAD = "fresh", "has_params", "dead"

# state tuple layout (live):
#   (version, late, drops, applies, events_fired, workers)
#   workers: tuple per wid of (phase, read_v, trace, closed_traces)
#   closed_traces: tuple of (trace, was_killed)


class LiveModel:
    """The live (free-running) discipline: `step` fuses push+pull, drops
    and late pushes are counted, kill/restart/join events fire
    nondeterministically once their version threshold is reached."""

    mode = "live"

    def __init__(self, total: int, n_workers: int = 2, max_drops: int = 0,
                 events: Sequence[Tuple[str, int, int]] = (),
                 bug: Optional[str] = None):
        self.total = int(total)
        self.n_workers = n_workers
        self.max_drops = int(max_drops)
        self.events = tuple(events)      # (op, wid, at_version)
        self.bug = bug

    def initial(self):
        workers = tuple((_FRESH, -1, ("hello", "welcome"), ())
                        for _ in range(self.n_workers))
        return (0, 0, 0, 0, (False,) * len(self.events), workers)

    def _budget_done(self, version: int) -> bool:
        if self.bug == "ghost-done":
            return version >= self.total - 1
        return version >= self.total

    def actions(self, state) -> List[Action]:
        version, _late, drops, _applies, fired, workers = state
        acts: List[Action] = []
        for wid, (phase, _rv, _trace, _closed) in enumerate(workers):
            if phase == _FRESH:
                acts.append(Action("step0", wid))
            elif phase == _HASPARAMS:
                acts.append(Action("compute", wid, local=True))
            elif phase == _COMPUTED:
                acts.append(Action("push", wid))
                if drops < self.max_drops and not self._budget_done(version):
                    acts.append(Action("drop", wid))
            elif phase == _DRAINED:
                acts.append(Action("bye", wid, local=True))
        for i, (op, wid, at_v) in enumerate(self.events):
            if fired[i] or version < at_v:
                continue
            if op == "kill" and wid < len(workers) and \
                    workers[wid][0] not in (_DEAD, _CLOSED):
                acts.append(Action(f"kill[{i}]", wid))
            elif op == "restart" and wid < len(workers) and \
                    workers[wid][0] == _DEAD:
                acts.append(Action(f"restart[{i}]", wid))
            elif op == "join":
                acts.append(Action(f"join[{i}]", len(workers)))
        return acts

    def _replace(self, workers, wid, w2):
        return workers[:wid] + (w2,) + workers[wid + 1:]

    def apply(self, state, a: Action):
        version, late, drops, applies, fired, workers = state
        label = a.label
        if label.startswith(("kill[", "restart[", "join[")):
            i = int(label[label.index("[") + 1:-1])
            fired = fired[:i] + (True,) + fired[i + 1:]
            if label.startswith("kill"):
                phase, rv, trace, closed = workers[a.wid]
                w2 = (_DEAD, -1, (), closed + ((trace, True),))
                return (version, late, drops, applies, fired,
                        self._replace(workers, a.wid, w2))
            if label.startswith("restart"):
                w2 = (_FRESH, -1, ("hello", "welcome"), workers[a.wid][3])
                return (version, late, drops, applies, fired,
                        self._replace(workers, a.wid, w2))
            # join: a brand-new worker
            return (version, late, drops, applies, fired,
                    workers + ((_FRESH, -1, ("hello", "welcome"), ()),))
        phase, rv, trace, closed = workers[a.wid]
        if label == "step0":               # g=None: pure pull
            if self._budget_done(version):
                w2 = (_DRAINED, -1, trace + ("step", "done"), closed)
            else:
                w2 = (_HASPARAMS, version, trace + ("step", "work"), closed)
            return (version, late, drops, applies, fired,
                    self._replace(workers, a.wid, w2))
        if label == "compute":
            return (version, late, drops, applies, fired,
                    self._replace(workers, a.wid, (_COMPUTED, rv, trace,
                                                   closed)))
        if label == "bye":
            w2 = (_CLOSED, rv, trace + ("bye",), closed)
            return (version, late, drops, applies, fired,
                    self._replace(workers, a.wid, w2))
        if label == "drop":                # scenario-dropped push
            drops += 1
            w2 = (_HASPARAMS, version, trace + ("step", "work"), closed)
            return (version, late, drops, applies, fired,
                    self._replace(workers, a.wid, w2))
        # push (step with a gradient)
        if self._budget_done(version):
            late += 1
            reply = "work" if self.bug == "wrong-verb" else "done"
            w2 = (_DRAINED, rv, trace + ("step", reply), closed)
            return (version, late, drops, applies, fired,
                    self._replace(workers, a.wid, w2))
        applies += 1
        version += 1
        if self._budget_done(version):
            w2 = (_DRAINED, rv, trace + ("step", "done"), closed)
        else:
            w2 = (_HASPARAMS, version, trace + ("step", "work"), closed)
        return (version, late, drops, applies, fired,
                self._replace(workers, a.wid, w2))

    # ---- invariants

    def invariant(self, state):
        version, _late, _drops, applies, _fired, workers = state
        if version != applies:
            return ("version-monotone",
                    f"version={version} after {applies} applies")
        if version > self.total:
            return ("version-monotone",
                    f"version={version} exceeded the step budget "
                    f"{self.total}")
        for wid, (phase, rv, _t, _c) in enumerate(workers):
            if phase in (_HASPARAMS, _COMPUTED) and not 0 <= rv <= version:
                return ("staleness-observed",
                        f"worker {wid} holds read_version={rv} outside "
                        f"[0, {version}] — staleness would be negative")
        return None

    def is_final(self, state) -> bool:
        return all(w[0] in (_CLOSED, _DEAD) for w in state[5])

    def at_end(self, state):
        version, _late, _drops, _applies, _fired, workers = state
        alive_done = [w for w in workers if w[0] == _CLOSED]
        if alive_done and version < self.total:
            return ("watchdog-termination",
                    f"run ended at version={version} < budget {self.total} "
                    f"with live workers told 'done' — the chief drained "
                    f"them early")
        for _phase, _rv, trace, closed_traces in workers:
            for tr, killed in closed_traces + ((trace, False),):
                if not tr:
                    continue
                bad = check_sequence(tr, self.mode,
                                     require_closed=not killed)
                if bad:
                    return ("trace-legal", bad[0].format())
        return None

    def at_stuck(self, state, truncated: bool = False):
        if truncated:
            return None
        version, _late, _drops, _applies, _fired, workers = state
        alive = [wid for wid, w in enumerate(workers)
                 if w[0] not in (_DEAD, _CLOSED)]
        if alive:
            return ("watchdog-termination",
                    f"lost wakeup at version={version}: live workers "
                    f"{alive} blocked forever (watchdog abort, not a "
                    f"clean finish)")
        return None    # all dead: the watchdog fires; a legal termination


# ------------------------------------------------------------ recovery model

# recovery worker tuple: (phase, read_v, consecutive_rejections)


class RecoveryModel:
    """The self-healing extension of the live discipline (DESIGN.md §14).

    `bad` workers push non-finite gradients: the sentinel REJECTS those
    pushes — counted, never applied, version untouched — and quarantines a
    worker after `quarantine_after` consecutive rejections (modeled as the
    worker draining out). `diverge_at` version thresholds fire divergence
    events: each costs one rollback; exceeding `max_rollbacks` must flip the
    run fatal (everything drains) instead of remediating forever. Dead
    workers (kill events) are respawned by the supervisor at most
    `max_respawns` times, then evicted.

    Seeded bugs: "reject-bumps-version" (a rejected push still advances the
    version), "rollback-unbounded" (divergence never goes fatal),
    "zombie-respawn" (eviction ignores the respawn cap).
    """

    mode = "live"

    def __init__(self, total: int, n_workers: int = 2, bad: Sequence[int] = (),
                 quarantine_after: int = 2, max_rollbacks: int = 1,
                 max_respawns: int = 1,
                 events: Sequence[Tuple[str, int, int]] = (),
                 diverge_at: Sequence[int] = (), bug: Optional[str] = None):
        self.total = int(total)
        self.n_workers = n_workers
        self.bad = frozenset(bad)
        self.quarantine_after = int(quarantine_after)
        self.max_rollbacks = int(max_rollbacks)
        self.max_respawns = int(max_respawns)
        self.events = tuple(events)          # ("kill", wid, at_version)
        self.diverge_at = tuple(diverge_at)  # version thresholds, fire once
        self.bug = bug

    # state: (version, applies, rejected, rollbacks, fatal,
    #         fired, dfired, workers, respawns)

    def initial(self):
        workers = tuple((_FRESH, -1, 0) for _ in range(self.n_workers))
        return (0, 0, 0, 0, False,
                (False,) * len(self.events), (False,) * len(self.diverge_at),
                workers, (0,) * self.n_workers)

    def _done(self, version: int, fatal: bool) -> bool:
        return fatal or version >= self.total

    def actions(self, state) -> List[Action]:
        version, _ap, _rej, _rb, fatal, fired, dfired, workers, respawns = state
        acts: List[Action] = []
        for wid, (phase, _rv, _consec) in enumerate(workers):
            if phase == _FRESH:
                acts.append(Action("step0", wid))
            elif phase == _HASPARAMS:
                acts.append(Action("compute", wid, local=True))
            elif phase == _COMPUTED:
                acts.append(Action("push", wid))
            elif phase == _DRAINED:
                acts.append(Action("bye", wid, local=True))
            elif phase == _DEAD:
                if self.bug == "zombie-respawn" or \
                        respawns[wid] < self.max_respawns:
                    acts.append(Action("respawn", wid))
        for i, (op, wid, at_v) in enumerate(self.events):
            if not fired[i] and version >= at_v and op == "kill" and \
                    wid < len(workers) and \
                    workers[wid][0] not in (_DEAD, _CLOSED):
                acts.append(Action(f"kill[{i}]", wid))
        for i, at_v in enumerate(self.diverge_at):
            if not dfired[i] and version >= at_v and not fatal:
                acts.append(Action(f"diverge[{i}]", 0))
        return acts

    def _set(self, workers, wid, w2):
        return workers[:wid] + (w2,) + workers[wid + 1:]

    def apply(self, state, a: Action):
        version, applies, rejected, rollbacks, fatal, fired, dfired, \
            workers, respawns = state
        label = a.label
        if label.startswith("kill["):
            i = int(label[label.index("[") + 1:-1])
            fired = fired[:i] + (True,) + fired[i + 1:]
            return (version, applies, rejected, rollbacks, fatal, fired,
                    dfired, self._set(workers, a.wid, (_DEAD, -1, 0)),
                    respawns)
        if label.startswith("diverge["):
            i = int(label[label.index("[") + 1:-1])
            dfired = dfired[:i] + (True,) + dfired[i + 1:]
            rollbacks += 1
            if self.bug != "rollback-unbounded" and \
                    rollbacks > self.max_rollbacks:
                fatal = True    # remediation budget exhausted: abort the run
            return (version, applies, rejected, rollbacks, fatal, fired,
                    dfired, workers, respawns)
        if label == "respawn":
            respawns = respawns[:a.wid] + (respawns[a.wid] + 1,) + \
                respawns[a.wid + 1:]
            return (version, applies, rejected, rollbacks, fatal, fired,
                    dfired, self._set(workers, a.wid, (_FRESH, -1, 0)),
                    respawns)
        phase, rv, consec = workers[a.wid]
        done = self._done(version, fatal)
        if label == "step0":
            w2 = (_DRAINED, -1, consec) if done else \
                (_HASPARAMS, version, consec)
        elif label == "compute":
            w2 = (_COMPUTED, rv, consec)
        elif label == "bye":
            w2 = (_CLOSED, rv, consec)
        else:  # push
            if done:
                w2 = (_DRAINED, rv, consec)          # late: answered "done"
            elif a.wid in self.bad:
                rejected += 1
                consec += 1
                if self.bug == "reject-bumps-version":
                    version += 1   # the seeded defect: reject still bumps
                if consec >= self.quarantine_after:
                    w2 = (_DRAINED, rv, consec)      # quarantined
                else:
                    w2 = (_HASPARAMS, version, consec)
            else:
                applies += 1
                version += 1
                w2 = (_DRAINED, rv, 0) if self._done(version, fatal) else \
                    (_HASPARAMS, version, 0)
        return (version, applies, rejected, rollbacks, fatal, fired, dfired,
                self._set(workers, a.wid, w2), respawns)

    # ---- invariants

    def invariant(self, state):
        version, applies, _rej, rollbacks, fatal, _f, _df, workers, \
            respawns = state
        if version != applies:
            return ("version-monotone",
                    f"version={version} after {applies} applies — a "
                    f"rejected push must NOT advance the version")
        if version > self.total:
            return ("version-monotone",
                    f"version={version} exceeded the step budget "
                    f"{self.total}")
        if rollbacks > self.max_rollbacks and not fatal:
            return ("rollback-bounded",
                    f"{rollbacks} rollbacks exceed "
                    f"max_rollbacks={self.max_rollbacks} without the run "
                    f"going fatal — remediation would loop forever")
        for wid, n in enumerate(respawns):
            if n > self.max_respawns:
                return ("respawn-capped",
                        f"worker {wid} respawned {n} times past "
                        f"max_respawns={self.max_respawns} — eviction "
                        f"failed")
        for wid, (phase, rv, _c) in enumerate(workers):
            if phase in (_HASPARAMS, _COMPUTED) and not 0 <= rv <= version:
                return ("staleness-observed",
                        f"worker {wid} holds read_version={rv} outside "
                        f"[0, {version}]")
        return None

    def is_final(self, state) -> bool:
        return all(w[0] in (_CLOSED, _DEAD) for w in state[7])

    def at_end(self, state):
        version, _ap, _rej, _rb, fatal, _f, _df, workers, _rs = state
        if fatal or version >= self.total:
            return None
        closed_good = [wid for wid, w in enumerate(workers)
                       if w[0] == _CLOSED and wid not in self.bad]
        if closed_good:
            return ("watchdog-termination",
                    f"healthy workers {closed_good} were drained at "
                    f"version={version} < budget {self.total} with no "
                    f"fatal condition")
        return None

    def at_stuck(self, state, truncated: bool = False):
        if truncated:
            return None
        version, _ap, _rej, _rb, _fatal, _f, _df, workers, _rs = state
        alive = [wid for wid, w in enumerate(workers)
                 if w[0] not in (_DEAD, _CLOSED)]
        if alive:
            return ("watchdog-termination",
                    f"lost wakeup at version={version}: live workers "
                    f"{alive} blocked forever")
        return None


# ------------------------------------------------------------ config suites


def _schedule(pattern: Sequence[Tuple[int, int]]) -> List[Tuple[int, int, int]]:
    """[(worker, staleness), ...] -> schedule rows (t, worker, fetch_v)."""
    return [(t, w, max(0, t - s)) for t, (w, s) in enumerate(pattern)]


#: the stock exploration suite (2 workers, depth-bounded); tuned so the
#: total path count clears the 10k acceptance floor with headroom
SUITE: List[Tuple[str, "object"]] = [
    ("replay/interleaved", ReplayModel(_schedule(
        [(0, 0), (1, 0), (0, 1), (1, 2), (0, 1), (1, 1),
         (0, 2), (1, 1), (0, 1), (1, 2)]))),
    ("replay/bursty", ReplayModel(_schedule(
        [(0, 0), (0, 1), (1, 0), (1, 2), (1, 1), (0, 3),
         (0, 1), (1, 1), (1, 2), (0, 1)]))),
    ("live/plain", LiveModel(total=6, n_workers=2)),
    ("live/drops", LiveModel(total=4, n_workers=2, max_drops=2)),
    ("live/kill-restart", LiveModel(
        total=5, n_workers=2,
        events=[("kill", 1, 1), ("restart", 1, 2)])),
    ("live/elastic-join", LiveModel(
        total=4, n_workers=2, events=[("join", 0, 1)])),
    ("recovery/sentinel-quarantine", RecoveryModel(
        total=4, n_workers=2, bad=(1,), quarantine_after=2)),
    ("recovery/rollback-respawn", RecoveryModel(
        total=4, n_workers=2, events=[("kill", 1, 1)],
        diverge_at=(2,), max_rollbacks=1, max_respawns=1)),
]

#: seeded-bug fixtures: every invariant has at least one proving the
#: checker catches its violation
BUGS: List[Tuple[str, str, "object"]] = [
    ("nonmonotone", "version-monotone", ReplayModel(
        _schedule([(0, 0), (1, 1), (0, 1), (1, 1)]), bug="nonmonotone")),
    ("double-apply", "applied-exactly-once", ReplayModel(
        _schedule([(0, 0), (1, 1), (0, 1), (1, 1)]), bug="double-apply")),
    ("staleness-skew", "staleness-observed", ReplayModel(
        _schedule([(0, 0), (1, 1), (0, 1), (1, 1)]), bug="staleness-skew")),
    # w0's second dispatch fetches v4, which cannot exist right after its
    # first push (version 1) — serving early grants stale-by-3 weights
    ("grant-early", "schedule-order", ReplayModel(
        _schedule([(0, 0), (1, 0), (1, 0), (1, 0), (1, 0), (0, 1)]),
        bug="grant-early")),
    ("lost-wakeup", "watchdog-termination", ReplayModel(
        _schedule([(0, 0), (1, 2), (0, 1), (1, 2)]), bug="lost-wakeup")),
    ("ghost-done", "watchdog-termination", LiveModel(
        total=3, n_workers=2, bug="ghost-done")),
    ("wrong-verb", "trace-legal", LiveModel(
        total=2, n_workers=2, bug="wrong-verb")),
    ("reject-bumps-version", "version-monotone", RecoveryModel(
        total=3, n_workers=2, bad=(1,), bug="reject-bumps-version")),
    # three divergence events against a budget of one rollback: the correct
    # model flips fatal on the second, the seeded one remediates forever
    ("rollback-unbounded", "rollback-bounded", RecoveryModel(
        total=4, n_workers=2, diverge_at=(1, 1, 1), max_rollbacks=1,
        bug="rollback-unbounded")),
    ("zombie-respawn", "respawn-capped", RecoveryModel(
        total=3, n_workers=2, events=[("kill", 1, 1)], max_respawns=0,
        bug="zombie-respawn")),
]


def run_suite(max_depth: int = 80, max_paths: Optional[int] = 500_000
              ) -> Dict[str, Stats]:
    return {name: explore(model, max_depth=max_depth, max_paths=max_paths)
            for name, model in SUITE}


def run_selfcheck(max_depth: int = 80) -> List[Tuple[str, str, bool, str]]:
    """(bug, invariant, caught?, detail) per seeded fixture. `caught` means
    the exploration reported at least one violation OF THAT invariant."""
    out = []
    for bug, inv, model in BUGS:
        stats = explore(model, max_depth=max_depth, max_paths=50_000)
        hits = [v for v in stats.violations if v.invariant == inv]
        detail = hits[0].format() if hits else (
            stats.violations[0].format() if stats.violations
            else "no violation reported")
        out.append((bug, inv, bool(hits), detail))
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.modelcheck",
        description="systematic interleaving exploration of the dist "
                    "protocol with executable invariants")
    ap.add_argument("--min-paths", type=int, default=10_000,
                    help="fail unless at least this many interleavings "
                         "were explored across the suite")
    ap.add_argument("--max-depth", type=int, default=80)
    ap.add_argument("--max-paths", type=int, default=500_000,
                    help="per-config exploration cap")
    ap.add_argument("--no-selfcheck", action="store_true",
                    help="skip the seeded-bug fixtures")
    args = ap.parse_args(argv)

    failures = 0
    total_paths = 0
    print(f"{'config':24s} {'paths':>8s} {'states':>9s} {'stuck':>6s} "
          f"{'pruned':>7s}  invariants")
    for name, stats in run_suite(max_depth=args.max_depth,
                                 max_paths=args.max_paths).items():
        total_paths += stats.paths
        verdict = "OK" if not stats.violations else "VIOLATED"
        print(f"{name:24s} {stats.paths:8d} {stats.states:9d} "
              f"{stats.stuck:6d} {stats.pruned:7d}  {verdict}")
        for v in stats.violations:
            print(f"  {v.format()}")
            failures += 1

    print(f"\ntotal interleavings explored: {total_paths}")
    if total_paths < args.min_paths:
        print(f"FAIL: expected >= {args.min_paths} interleavings")
        failures += 1

    if not args.no_selfcheck:
        print("\nseeded-bug fixtures (each invariant must be catchable):")
        for bug, inv, caught, detail in run_selfcheck(
                max_depth=args.max_depth):
            mark = "caught" if caught else "MISSED"
            print(f"  {bug:16s} -> {inv:22s} {mark}")
            if not caught:
                print(f"    {detail}")
                failures += 1

    return 1 if failures else 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
