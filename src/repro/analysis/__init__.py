"""`repro.analysis` — JAX-aware static analysis & trace audits (DESIGN.md §12).

Three layers of machine-checked enforcement for the hazard classes the first
seven PRs fixed by hand:

  * `repro.analysis.lint` — AST rules over `src/` (host syncs in hot loops,
    jit-in-loop retrace hazards, trace-time mutation of captured state, f32
    literals in f64-parity modules, missing carry donation, unscoped-x64 jnp
    in dist), with inline allows and a committed baseline. CLI:
    `python -m repro.analysis src/` (wired as `make lint`, gated in CI).
  * `repro.analysis.trace` — runtime auditors: `assert_traces` (the reusable
    retrace counter), `audit_dtypes` (jaxpr-walking f64->f32 demotion
    finder) and `audit_donation` (non-donated large dispatch buffers).
  * `repro.analysis.protocol` — the dist verb-grammar FSM (`check_sequence`,
    `audit_verbs`) and the `ParameterStore` lock-discipline pass
    (`audit_lock_discipline`).

PR 9 adds the concurrency correctness layer (DESIGN.md §13):

  * `repro.analysis.locks` — repo-wide static lockset analysis + lock-order
    graph over every concurrent class (`run_locks`, `analyze_source`).
  * `repro.analysis.sanitize` — the opt-in runtime race sanitizer
    (`REPRO_TSAN=1`): instrumented lock/thread wrappers reporting lock-order
    inversions and unlocked shared writes.
  * `repro.analysis.modelcheck` — systematic interleaving exploration of the
    dist protocol (bounded DFS + sleep sets) with executable invariants and
    seeded-bug fixtures (`explore`, `ReplayModel`, `LiveModel`).
"""
from repro.analysis.baseline import apply_baseline, load_baseline, save_baseline
from repro.analysis.lint import (
    DEFAULT_CONFIG,
    Finding,
    LintConfig,
    RULES,
    lint_source,
    run_lint,
)
from repro.analysis.protocol import (
    LIVE_FSM,
    REPLAY_FSM,
    VERB_GRAMMAR,
    LockViolation,
    ProtocolViolation,
    audit_lock_discipline,
    audit_verbs,
    check_sequence,
)
from repro.analysis.locks import (
    LOCK_RULES,
    ClassModel,
    analyze_source,
    lock_order_graph,
    run_locks,
)
from repro.analysis.modelcheck import (
    BUGS,
    SUITE,
    LiveModel,
    ReplayModel,
    Stats,
    Violation,
    explore,
)
from repro.analysis.trace import (
    DonationReport,
    DtypeViolation,
    TraceCountError,
    assert_no_demotion,
    assert_traces,
    audit_donation,
    audit_dtypes,
)

__all__ = [
    "DEFAULT_CONFIG", "Finding", "LintConfig", "RULES", "lint_source",
    "run_lint", "apply_baseline", "load_baseline", "save_baseline",
    "VERB_GRAMMAR", "REPLAY_FSM", "LIVE_FSM", "ProtocolViolation",
    "LockViolation", "check_sequence", "audit_verbs",
    "audit_lock_discipline", "TraceCountError", "assert_traces",
    "DtypeViolation", "audit_dtypes", "assert_no_demotion",
    "DonationReport", "audit_donation",
    "LOCK_RULES", "ClassModel", "analyze_source", "lock_order_graph",
    "run_locks", "BUGS", "SUITE", "LiveModel", "ReplayModel", "Stats",
    "Violation", "explore",
]
