"""CLI: `python -m repro.analysis [paths ...]` (DESIGN.md §12, `make lint`).

Runs the AST lint plus the repo-wide lockset/lock-order pass
(`repro.analysis.locks`) over the given files/trees (default: `src/`),
applies the committed baseline plus inline allows, then — when the scanned
tree contains `repro/dist/` — the static protocol audits (verb grammar
conformance and ParameterStore lock discipline). Prints
`path:line:col: rule-id: message` per finding and exits 1 on anything
unsuppressed, 0 on a clean tree.

  --baseline FILE      baseline path (default: ./analysis-baseline.json
                       when present)
  --update-baseline    rewrite the baseline from the current findings
                       (reasons become TODOs to triage) and exit 0
  --no-protocol        skip the dist protocol/lock audits
  --no-locks           skip the repo-wide lockset pass
  --list-rules         print the rule catalogue and exit
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import baseline as B
from repro.analysis import protocol as P
from repro.analysis.lint import RULES, run_lint
from repro.analysis.locks import LOCK_RULES, run_locks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis over the repro source tree")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directory roots to scan (default: src/)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default: ./{B.BASELINE_NAME} if "
                         f"present)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--no-protocol", action="store_true",
                    help="skip the dist protocol/lock audits")
    ap.add_argument("--no-locks", action="store_true",
                    help="skip the repo-wide lockset pass")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in {**RULES, **LOCK_RULES}.items():
            print(f"{rule:24s} {desc}")
        return 0

    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path {p!r}", file=sys.stderr)
            return 2

    findings = run_lint(paths)
    if not args.no_locks:
        findings += run_locks(paths)[0]

    baseline_path = args.baseline
    if baseline_path is None and os.path.isfile(B.BASELINE_NAME):
        baseline_path = B.BASELINE_NAME

    if args.update_baseline:
        out = baseline_path or B.BASELINE_NAME
        B.save_baseline(out, findings)
        print(f"wrote {len(findings)} finding(s) to {out}; edit each "
              f"entry's reason before committing")
        return 0

    stale = []
    if baseline_path:
        findings, stale = B.apply_baseline(findings,
                                           B.load_baseline(baseline_path))

    failures = 0
    for f in findings:
        print(f.format())
        failures += 1

    if not args.no_protocol:
        scan_roots = [p for p in paths]
        has_dist = any(
            os.path.basename(fp) == "store.py" and "dist" in fp.split(os.sep)
            for fp in _walk_names(scan_roots))
        if has_dist:
            for msg in P.audit_verbs(root=paths[0]):
                print(f"repro/dist: protocol-verbs: {msg}")
                failures += 1
            for v in P.audit_lock_discipline(root=paths[0]):
                print(f"repro/dist/store.py: lock-discipline: {v.format()}")
                failures += 1

    for e in stale:
        print(f"note: stale baseline entry ({e['rule']} @ {e['path']}: "
              f"{e['line_text']!r}) — the code it covered changed; prune it",
              file=sys.stderr)

    if failures:
        print(f"\n{failures} finding(s). Fix, add an inline "
              f"`# lint: allow[rule-id] reason`, or baseline with "
              f"--update-baseline (then justify each entry).",
              file=sys.stderr)
        return 1
    return 0


def _walk_names(paths):
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs if d not in ("__pycache__", ".git")]
                for f in files:
                    yield os.path.join(root, f)


if __name__ == "__main__":
    sys.exit(main())
