"""Trace-time auditors (`repro.analysis.trace`, DESIGN.md §12).

Three runtime complements to the AST lint, each checking a property the lint
can only approximate syntactically:

  * `assert_traces(n, *targets)` — the reusable retrace counter. PR 5 proved
    the guided_fused step traces `forward_train` exactly once with a bespoke
    monkeypatch; this generalizes that machinery: a target is either a
    jit-wrapped function (counted via its compilation-cache growth — one new
    cache entry per trace) or a `(holder, "attr")` pair whose function is
    temporarily wrapped to count executions (a traced function's Python body
    runs once per trace). The block must produce exactly `n` traces in total.

  * `audit_dtypes(fn, *args)` — walks the jaxpr of `fn` (recursing into
    scan/cond/pjit/custom-call sub-jaxprs) and reports every equation where a
    float64 input meets a narrower float output. This is the machine check
    for the DESIGN.md §11 class of bug: an f32-casting fold silently
    truncating the f64 parity trajectory.

  * `audit_donation(args, donate_argnums)` — reports the non-donated
    arguments of a dispatch that are large enough to matter. The chunked
    trainloop donates its (params, gstate) carry end-to-end; this auditor is
    how a test proves that, and how a future loop's forgotten
    `donate_argnums` shows up as named buffers with byte sizes instead of a
    silent 2x memory footprint.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, List, Sequence, Tuple


class TraceCountError(AssertionError):
    """Raised by assert_traces when the observed trace count differs."""


class _Tracker:
    """Live trace-count across the targets of one assert_traces block."""

    def __init__(self):
        self._jitted: List[Tuple[Any, int]] = []   # (fn, cache size at enter)
        self._wrapped: List[List[int]] = []        # mutable call counters
        self.labels: List[str] = []

    @property
    def count(self) -> int:
        total = sum(fn._cache_size() - start for fn, start in self._jitted)
        total += sum(c[0] for c in self._wrapped)
        return total

    def breakdown(self) -> str:
        parts = []
        for (fn, start), label in zip(self._jitted,
                                      self.labels[: len(self._jitted)]):
            parts.append(f"{label}: {fn._cache_size() - start} new cache entries")
        for c, label in zip(self._wrapped, self.labels[len(self._jitted):]):
            parts.append(f"{label}: {c[0]} trace-time calls")
        return "; ".join(parts) or "no targets"


@contextlib.contextmanager
def assert_traces(n: int, *targets):
    """Assert exactly `n` traces happen across `targets` inside the block.

    Targets:
      * a jit-wrapped function (``jax.jit`` result): counted by compilation-
        cache growth — cache hits are free, every new (shape, dtype) trace
        adds one;
      * ``(holder, "attr")``: ``holder.attr`` is wrapped for the duration of
        the block and each execution counts — the PR 5 idiom for proving a
        model function is traced once inside a step, now reusable.

    Yields the tracker (``tracker.count`` is live) and raises
    `TraceCountError` with a per-target breakdown on mismatch.
    """
    if not targets:
        raise ValueError("assert_traces needs at least one target "
                         "(a jitted fn or a (holder, 'attr') pair)")
    tracker = _Tracker()
    jit_targets, wrap_targets = [], []
    for t in targets:
        if isinstance(t, tuple) and len(t) == 2 and isinstance(t[1], str):
            wrap_targets.append(t)
        elif hasattr(t, "_cache_size"):
            jit_targets.append(t)
        else:
            raise TypeError(
                f"assert_traces target {t!r} is neither a jit-wrapped "
                f"function (no _cache_size) nor a (holder, 'attr') pair")
    for fn in jit_targets:
        tracker._jitted.append((fn, fn._cache_size()))
        tracker.labels.append(getattr(fn, "__name__", repr(fn)))
    patched = []
    try:
        for holder, attr in wrap_targets:
            original = getattr(holder, attr)
            counter = [0]

            def wrapper(*a, __original=original, __counter=counter, **kw):
                __counter[0] += 1
                return __original(*a, **kw)

            setattr(holder, attr, wrapper)
            patched.append((holder, attr, original))
            tracker._wrapped.append(counter)
            tracker.labels.append(f"{getattr(holder, '__name__', holder)}.{attr}")
        yield tracker
        got = tracker.count
        if got != n:
            raise TraceCountError(
                f"expected exactly {n} trace(s), observed {got} "
                f"({tracker.breakdown()})")
    finally:
        for holder, attr, original in patched:
            setattr(holder, attr, original)


# ------------------------------------------------------------- dtype audit


@dataclasses.dataclass(frozen=True)
class DtypeViolation:
    """One jaxpr equation where float64 meets a narrower float output."""

    primitive: str
    path: str            # nesting chain, e.g. "pjit/scan"
    in_dtypes: Tuple[str, ...]
    out_dtypes: Tuple[str, ...]

    def format(self) -> str:
        return (f"{self.path or '<top>'}: {self.primitive} demotes "
                f"{'/'.join(self.in_dtypes)} -> {'/'.join(self.out_dtypes)}")


_NARROW = ("float32", "bfloat16", "float16")


def _subjaxprs(value):
    import jax

    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def _walk_jaxpr(jaxpr, path: str, out: List[DtypeViolation]):
    for eqn in jaxpr.eqns:
        ins = [str(v.aval.dtype) for v in eqn.invars
               if hasattr(getattr(v, "aval", None), "dtype")]
        outs = [str(v.aval.dtype) for v in eqn.outvars
                if hasattr(getattr(v, "aval", None), "dtype")]
        if any(d == "float64" for d in ins) and any(d in _NARROW for d in outs):
            out.append(DtypeViolation(
                primitive=eqn.primitive.name, path=path,
                in_dtypes=tuple(ins), out_dtypes=tuple(outs)))
        sub_path = f"{path}/{eqn.primitive.name}" if path else eqn.primitive.name
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                _walk_jaxpr(sub, sub_path, out)


def audit_dtypes(fn, *args, **kwargs) -> List[DtypeViolation]:
    """Trace `fn(*args, **kwargs)` and report every equation (at any nesting
    depth — scan bodies, cond branches, inner pjits) where a float64 input
    produces a float32/bf16/f16 output. Empty list == the f64 trajectory
    survives end to end."""
    import jax

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    out: List[DtypeViolation] = []
    _walk_jaxpr(closed.jaxpr, "", out)
    return out


def assert_no_demotion(fn, *args, **kwargs):
    """`audit_dtypes` that raises, listing each offending equation."""
    violations = audit_dtypes(fn, *args, **kwargs)
    if violations:
        raise AssertionError(
            "float64 reaches narrower float ops:\n  "
            + "\n  ".join(v.format() for v in violations))


# ---------------------------------------------------------- donation audit


@dataclasses.dataclass(frozen=True)
class DonationReport:
    """One non-donated dispatch argument above the size threshold."""

    argnum: int
    name: str
    nbytes: int

    def format(self) -> str:
        return (f"arg {self.argnum} ({self.name}): {self.nbytes} bytes "
                f"not donated")


def _tree_nbytes(tree) -> int:
    import jax

    total = 0
    for leaf in jax.tree.leaves(tree):
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and hasattr(leaf, "size"):
            total += int(leaf.size) * dtype.itemsize
    return total


def audit_donation(args: Sequence, donate_argnums: Sequence[int] = (),
                   min_bytes: int = 1 << 16,
                   names: Sequence[str] = None) -> List[DonationReport]:
    """Report the arguments of a dispatch that are NOT donated yet carry at
    least `min_bytes` of array data. `donate_argnums` mirrors the jax.jit
    argument; `names` (optional, parallel to `args`) labels the report.
    Data batches legitimately show up here (they are consumed, not carried);
    a params/opt-state carry showing up means the loop holds two copies of
    the train state."""
    donated = set(donate_argnums)
    reports = []
    for i, a in enumerate(args):
        if i in donated:
            continue
        nbytes = _tree_nbytes(a)
        if nbytes >= min_bytes:
            name = names[i] if names and i < len(names) else f"arg{i}"
            reports.append(DonationReport(argnum=i, name=name, nbytes=nbytes))
    return reports
