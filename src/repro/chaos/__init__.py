"""Seeded chaos harness for the self-healing training stack (DESIGN.md §14)."""
from repro.chaos.inject import (  # noqa: F401
    ChaosPlan,
    slow_disk,
    truncate_newest,
)
