"""Seeded fault injection for the self-healing layer (`repro.chaos`).

A `ChaosPlan` is a declarative, fully deterministic description of the faults
one run must survive — which worker dies at which store version, which
connection the chief drops, which worker's gradients go NaN or explode, when
the newest checkpoint gets torn. The plan is data, not callbacks, so the same
plan reproduces the same fault sequence on every run with the same seed and
can be shipped to worker processes inside the chief's `welcome` meta
(`worker_meta()`).

Fault surfaces and where each is injected:

  * kills          — launcher: SIGKILL the worker process at a store version
  * resets         — chief: drop the TCP connection mid-stream (RST-like)
  * corrupt_frame  — worker: send one garbage frame (bytes head, no verb)
  * nan_grad       — worker: every gradient non-finite from a version on
  * boom_grad      — worker: gradients * 1e12 (finite but divergent)
  * truncate_at    — launcher: truncate the newest checkpoint archive
  * slow_disk_s    — `slow_disk()` patch: every archive write sleeps first

The chaos test suite (tests/test_chaos.py, `make chaos`) asserts that runs
under each plan auto-recover: they complete, land within loss tolerance of a
fault-free reference, and `Report.dist` records the remediation that did it
(rejections/quarantines/rollbacks/respawns) — DESIGN.md §14.
"""
from __future__ import annotations

import contextlib
import os
import time
from dataclasses import dataclass


def _as_table(pairs) -> dict:
    """((wid, at_version), ...) | {wid: at_version} -> {int: int}."""
    if not pairs:
        return {}
    items = pairs.items() if isinstance(pairs, dict) else pairs
    return {int(w): int(v) for w, v in items}


@dataclass(frozen=True)
class ChaosPlan:
    """One deterministic fault schedule. Thresholds are store VERSIONS, not
    wall-clock times, so plans are timing-independent and reproducible."""

    seed: int = 0
    kills: tuple = ()            # ((wid, at_version), ...) SIGKILL the process
    resets: tuple = ()           # ((wid, at_version), ...) chief drops the conn
    nan_grad: tuple = ()         # ((wid, at_version), ...) persistent NaN pushes
    boom_grad: tuple = ()        # ((wid, at_version), ...) persistent 1e12x pushes
    corrupt_frame: tuple = ()    # ((wid, at_version), ...) one garbage frame
    truncate_at: int | None = None   # tear the newest archive at this version
    slow_disk_s: float = 0.0     # per-archive write latency (use slow_disk())

    def worker_meta(self) -> dict | None:
        """The worker-side slice of the plan, shipped in the chief's welcome
        meta as `meta["chaos"]` (None when no worker-side faults)."""
        out = {}
        for kind in ("nan_grad", "boom_grad", "corrupt_frame"):
            table = _as_table(getattr(self, kind))
            if table:
                out[kind] = table
        return out or None

    def kill_events(self) -> dict:
        return _as_table(self.kills)

    def reset_events(self) -> tuple:
        return tuple((int(w), int(v)) for w, v in _as_table(self.resets).items())


def truncate_newest(ckpt_dir: str, keep_fraction: float = 0.5):
    """Tear the newest manifest-recorded archive in place (keep the leading
    `keep_fraction` of its bytes) WITHOUT touching the manifest — exactly the
    on-disk state a power loss mid-write on a non-atomic filesystem leaves
    behind. Returns (step, path) of the torn archive, or None when the dir
    has no entries yet."""
    from repro.checkpoint.npz import manifest_entries

    entries = manifest_entries(ckpt_dir)
    if not entries:
        return None
    entry = entries[0]
    path = os.path.join(ckpt_dir, entry["file"])
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, int(size * keep_fraction)))
    except FileNotFoundError:
        return None
    return entry["step"], path


@contextlib.contextmanager
def slow_disk(delay_s: float):
    """Patch every checkpoint archive write to sleep `delay_s` first — the
    slow-disk writer fault. Covers both the direct `npz.write_archive`
    callers and `checkpoint.writer`'s imported reference."""
    from repro.checkpoint import npz, writer

    real = npz.write_archive

    def slow_write(ckpt_dir, step, flat):
        time.sleep(delay_s)
        return real(ckpt_dir, step, flat)

    npz.write_archive = slow_write
    writer.write_archive = slow_write
    try:
        yield
    finally:
        npz.write_archive = real
        writer.write_archive = real
