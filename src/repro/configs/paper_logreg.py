"""The paper's own experimental model: logistic regression trained with
(g)S/ASGD on tabular UCI-style datasets (Sharma 2021, Section 5)."""
from repro.configs.base import ModelConfig

# Represented degenerately in ModelConfig terms; the paper-repro pipeline uses
# repro.core.parameter_server directly with a LogisticRegression model.
CONFIG = ModelConfig(
    name="paper-logreg",
    arch_type="dense",
    n_layers=1,
    d_model=8,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=2,
    param_dtype="float32",
    compute_dtype="float32",
    citation="doi:10.1016/j.asoc.2021.107084",
)
