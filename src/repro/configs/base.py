"""Model / run configuration.

One `ModelConfig` describes any architecture in the assigned pool (dense, MoE,
SSM, hybrid, audio-encoder, VLM). Per-arch files in this package instantiate it
with the exact assigned hyperparameters and cite their source.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    topk: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # shared dense ffn alongside experts (qwen3 style shared expert): 0 = none
    d_shared_ff: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # layer pattern: 1 = mLSTM, 0 = sLSTM; tiled across n_layers
    pattern: tuple = (1, 0)
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # attention
    rope_theta: float = 1e6
    sliding_window: int = 0          # 0 = full attention
    causal: bool = True              # False for encoder-only (hubert)

    # hybrid (jamba): attention mixer every `attn_every` layers (else mamba);
    # MoE ffn every `moe_every` layers (else dense d_ff)
    attn_every: int = 0
    moe_every: int = 0

    # modality frontend stubs
    n_patches: int = 0               # vlm: number of precomputed patch embeddings
    audio_frontend: bool = False     # audio: input is frame embeddings, not tokens

    # ffn style: gated SwiGLU (llama lineage) vs plain GELU MLP (GPT/BERT)
    mlp_gated: bool = True

    # KV-cache storage: "native" (compute dtype) | "int8" (per-token-head
    # absmax quantization; ~2x cache memory at serve time)
    kv_cache_dtype: str = "native"

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # which attention implementation ("xla" for dry-run lowering, "pallas" on TPU)
    attn_impl: str = "xla"
    # remat policy for the scanned layer stack: "none" | "full" | "dots"
    remat: str = "full"

    def __post_init__(self):
        assert self.d_model % self.n_heads == 0, (self.name, self.d_model, self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_decode(self) -> bool:
        return self.causal

    def supports_long_context(self) -> bool:
        """True if decode at 500k tokens is sub-quadratic/bounded-memory."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_is_attn(self, i: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.arch_type == "hybrid":
            # jamba: 1 attention layer per `attn_every` (offset mid-period)
            return i % self.attn_every == self.attn_every // 2
        return True

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.arch_type == "hybrid":
            return i % self.moe_every == self.moe_every - 1
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 256, vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (see brief: <=4 experts)."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        d_model = max(d_model, n_heads * 32)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, n_experts=4, topk=min(self.moe.topk, 2), d_shared_ff=0)
        period = max(self.attn_every, self.moe_every, 1)
        n_layers = max(n_layers, period if self.arch_type == "hybrid" else n_layers)
        if self.xlstm is not None:
            n_layers = max(n_layers, len(self.xlstm.pattern))
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=4 * d_model if self.d_ff else 0,
            vocab_size=vocab,
            moe=moe,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
