"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2 arch); conv/mel
frontend stubbed as frame embeddings; masked-prediction over 504-unit codebook.
[arXiv:2106.07447]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mlp_gated=False,
    causal=False,            # encoder-only: bidirectional, no decode shapes
    audio_frontend=True,
    citation="arXiv:2106.07447",
)
