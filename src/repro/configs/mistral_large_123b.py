"""mistral-large-123b [dense] — Mistral Large 2 (123B).
[hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    arch_type="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    sliding_window=8192,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
)
