"""Architecture registry. Each assigned architecture has its own module with the
exact hyperparameters from the assignment (citations in brackets)."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig, XLSTMConfig  # noqa: F401

ARCH_IDS = [
    "llava_next_mistral_7b",
    "granite_20b",
    "minicpm_2b",
    "grok_1_314b",
    "xlstm_350m",
    "jamba_1_5_large_398b",
    "qwen3_moe_235b_a22b",
    "hubert_xlarge",
    "mistral_large_123b",
    "yi_9b",
    # the paper's own experimental model
    "paper_logreg",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS if a != "paper_logreg"}
