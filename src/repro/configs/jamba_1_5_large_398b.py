"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE 16
experts top-2 on every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, topk=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,            # 1 attention layer per 8 (1:7 attn:mamba)
    moe_every=2,             # MoE ffn on every other layer
    citation="arXiv:2403.19887",
)
