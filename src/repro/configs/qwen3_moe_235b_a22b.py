"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, fine-grained experts
(d_ff=1536 per expert). [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    moe=MoEConfig(n_experts=128, topk=8),
    sliding_window=8192,
    citation="hf:Qwen/Qwen3-30B-A3B",
)
