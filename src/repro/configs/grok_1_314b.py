"""grok-1-314b [moe] — xAI Grok-1, 8 experts top-2. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(n_experts=8, topk=2),
    sliding_window=8192,
    citation="hf:xai-org/grok-1",
)
