"""llava-next-mistral-7b [vlm] — LLaVA-NeXT with Mistral-7B backbone, anyres
tiling. Backbone only; vision tower is a stub supplying patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_patches=576,           # one anyres base tile of 24x24 patches (stubbed)
    rope_theta=1e6,
    sliding_window=8192,     # long_500k variant; mistral lineage supports SWA
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
