"""granite-20b [dense] — IBM Granite 20B code model, llama architecture with
multi-query attention (kv=1). [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,        # BigCode/GPT-style 2-matrix GELU MLP
    sliding_window=8192,     # enables long_500k; full attention otherwise
    citation="arXiv:2405.04324",
)
