"""xlstm-350m [ssm] — xLSTM with alternating sLSTM + mLSTM blocks.
[arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                  # xLSTM blocks carry their own projections
    vocab_size=50304,
    xlstm=XLSTMConfig(pattern=(1, 0)),  # (mLSTM, sLSTM) alternating
    citation="arXiv:2405.04517",
)
