"""minicpm-2b [dense] — MiniCPM 2.4B, llama-like, trained with the WSD
(warmup-stable-decay) schedule which repro.optim.schedules implements.
[arXiv:2404.06395]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    arch_type="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    sliding_window=8192,
    citation="arXiv:2404.06395",
)
