"""Worker supervision (`repro.resilience`, DESIGN.md §14).

Before this layer the only way a dead worker came back was a declarative
`Scenario` event that happened to say "restart" — a fault injector doubling
as the recovery path. The `Supervisor` makes recovery unconditional: a
polling thread owns the launcher's worker processes, detects death (process
exit, or a silent hang via heartbeat leases), respawns under capped
exponential backoff with jitter, and evicts a worker whose respawn streak
exhausts the budget — the run then finishes on whoever is still pushing.

Lease discipline (`LeaseTable`): every message a worker sends refreshes its
lease in the chief's connection thread; the supervisor treats a live process
with an expired lease as hung and kills it, which converts the hang into the
death path it already handles. Leases are opt-in (`spec.dist_lease_s`, 0 =
off) because wall-clock expiry on a loaded CI box would evict honest slow
workers; process-death detection is always on.

State machine per supervised worker (DESIGN.md §14 has the diagram):

    RUNNING --proc exit / lease expiry--> DOWN (streak += 1)
    DOWN --streak <= max_respawns, backoff elapsed--> RESPAWNED
    DOWN --streak >  max_respawns--> EVICTED (terminal)
    RESPAWNED --healthy (lease touch, or immediately without leases)-->
        RUNNING (streak resets, recovery time recorded)

Thread safety: `LeaseTable` has its own lock (touched from chief connection
threads); every mutable Supervisor attribute is guarded by `_lock`, shared
by the poll thread and the launcher's control calls. The only nesting is
Supervisor._lock -> LeaseTable._lock, so the lock order is acyclic.
"""
from __future__ import annotations

import random
import threading
import time


class LeaseTable:
    """Last-heartbeat table: chief connection threads `touch`, the
    supervisor asks `expired` / `touched_since`."""

    def __init__(self, lease_s: float):
        self.lease_s = float(lease_s)
        self._lock = threading.Lock()
        self._last: dict = {}            # wid -> monotonic() of last message

    def touch(self, wid: int):
        with self._lock:
            self._last[wid] = time.monotonic()

    def drop(self, wid: int):
        with self._lock:
            self._last.pop(wid, None)

    def expired(self, wid: int, now: float = None) -> bool:
        """True when `wid` has a lease and it ran out (never-seen workers are
        NOT expired: they may still be connecting)."""
        if not self.lease_s:
            return False
        now = time.monotonic() if now is None else now
        with self._lock:
            last = self._last.get(wid)
        return last is not None and now - last > self.lease_s

    def touched_since(self, wid: int, t: float) -> bool:
        with self._lock:
            last = self._last.get(wid)
        return last is not None and last > t

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._last)


class Supervisor:
    """Owns the spawned worker processes of one live run and keeps them
    alive: respawn on death (capped exponential backoff + jitter), kill on
    lease expiry, evict after `max_respawns` consecutive failures.

        sup = Supervisor(spawn_fn, n_workers=2, max_respawns=3)
        sup.start()            # spawns the initial fleet + the poll thread
        ...
        sup.close()            # stop polling, kill + clean up every process

    `spawn_fn(wid)` returns a process handle with `alive()/kill()/cleanup()`
    (the launcher's `_WorkerProc`); `wid=None` spawns an elastic joiner.
    """

    def __init__(self, spawn_fn, n_workers: int, max_respawns: int = 3,
                 leases: LeaseTable = None, backoff_base: float = 0.05,
                 backoff_cap: float = 1.0, poll_s: float = 0.02,
                 seed: int = 0):
        self.spawn_fn = spawn_fn
        self.n_workers = int(n_workers)
        self.max_respawns = int(max_respawns)
        self.leases = leases
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.poll_s = float(poll_s)
        self._rng = random.Random(seed * 9973 + 17)
        self._lock = threading.Lock()      # guards every mutable attr below
        self._procs: dict = {}             # wid -> process handle
        self._extra: list = []             # elastic joiners (chief-owned wids)
        self._streak: dict = {}            # wid -> consecutive failures
        self._down_since: dict = {}        # wid -> monotonic() death detected
        self._respawn_at: dict = {}        # wid -> earliest respawn time
        self._heal_from: dict = {}         # wid -> (down_since, respawned_at)
        self._evicted: list = []           # terminal wids (stderr kept)
        self._respawns = 0
        self._expiries = 0                 # lease-expiry kills
        self._recoveries: list = []        # (wid, seconds death -> healthy)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="dist-supervisor")

    # ------------------------------------------------------------- lifecycle

    def start(self):
        with self._lock:
            for wid in range(self.n_workers):
                self._procs[wid] = self.spawn_fn(wid)
        self._thread.start()

    def stop_polling(self):
        """Stop healing WITHOUT killing the fleet — the launcher calls this
        the moment the step budget is met, so workers exiting on 'done' are
        not mistaken for failures and respawned into a drained run."""
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    def close(self):
        """Stop the poll thread, then kill and clean up every process (the
        launcher's finally — also the path that keeps `test_no_leaked_threads`
        honest)."""
        self.stop_polling()
        with self._lock:
            procs = list(self._procs.values()) + list(self._extra)
        for p in procs:
            if p.alive():
                p.kill()
            p.cleanup()

    def _run(self):
        while not self._stop.wait(self.poll_s):
            self.poll()

    # ----------------------------------------------------------- supervision

    def _backoff(self, streak: int) -> float:
        base = min(self.backoff_cap, self.backoff_base * (2 ** (streak - 1)))
        return base * (1.0 + self._rng.random())   # full jitter: 1x..2x

    def poll(self, now: float = None):
        """One supervision pass (the poll thread's body; callable directly
        from tests). Detects deaths/expiries, respawns, records recoveries."""
        now = time.monotonic() if now is None else now
        with self._lock:
            for wid in list(self._procs):
                if wid in self._evicted:
                    continue
                proc = self._procs[wid]
                if proc.alive():
                    if self.leases is not None and self.leases.expired(wid, now):
                        # hung, not dead: convert to the death path
                        self._expiries += 1
                        self.leases.drop(wid)
                        proc.kill()
                    elif wid in self._heal_from:
                        down, spawned = self._heal_from[wid]
                        if self.leases is None or \
                                self.leases.touched_since(wid, spawned):
                            self._recoveries.append((wid, now - down))
                            self._streak[wid] = 0
                            del self._heal_from[wid]
                    continue
                if wid not in self._down_since:
                    self._down_since[wid] = now
                    self._heal_from.pop(wid, None)
                    self._streak[wid] = self._streak.get(wid, 0) + 1
                    if self._streak[wid] > self.max_respawns:
                        self._evicted.append(wid)
                        continue
                    self._respawn_at[wid] = now + self._backoff(self._streak[wid])
                elif now >= self._respawn_at.get(wid, now):
                    proc.cleanup()
                    self._procs[wid] = self.spawn_fn(wid)
                    self._respawns += 1
                    self._heal_from[wid] = (self._down_since.pop(wid), now)
                    self._respawn_at.pop(wid, None)

    # ---------------------------------------------------- launcher control

    def kill(self, wid: int):
        """Fault injection: SIGKILL the process; the poll loop heals it."""
        with self._lock:
            if wid in self._procs:
                self._procs[wid].kill()

    def respawn_now(self, wid: int):
        """Scenario 'restart': deliberate kill + immediate replacement (no
        backoff, no streak — this is an injected op, not a failure)."""
        with self._lock:
            if wid in self._procs:
                self._procs[wid].kill()
                self._procs[wid].cleanup()
            self._procs[wid] = self.spawn_fn(wid)
            self._respawns += 1
            self._down_since.pop(wid, None)
            self._respawn_at.pop(wid, None)
            self._heal_from.pop(wid, None)

    def spawn_extra(self):
        """Scenario 'join': an elastic worker (chief assigns its wid); extras
        are drained and cleaned up but not respawned."""
        with self._lock:
            self._extra.append(self.spawn_fn(None))

    # -------------------------------------------------------------- queries

    def procs(self) -> list:
        with self._lock:
            return list(self._procs.values()) + list(self._extra)

    def stderr_tails(self, n: int = 5) -> dict:
        with self._lock:
            items = list(self._procs.items())
        return {w: p.stderr_tail(n) for w, p in items}

    def stats(self) -> dict:
        with self._lock:
            return {
                "respawns": self._respawns,
                "lease_expiries": self._expiries,
                "evicted": list(self._evicted),
                "recoveries": [(w, round(s, 4)) for w, s in self._recoveries],
            }
