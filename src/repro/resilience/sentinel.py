"""Divergence sentinel (`repro.resilience`, DESIGN.md §14).

Delay-compensated training diverges exactly where the paper's problem lives:
a stale push lands on parameters it was not computed against, and one
non-finite or exploding gradient poisons W for every worker that pulls after
it. The sentinel screens BEFORE the apply on both execution paths:

  * mesh — `wrap_step_sentinel` fuses the screen into the train step itself,
    so the chunked `lax.scan` carry only ever threads screened states: a
    rejected step keeps the previous (params, gstate) via `jnp.where` and
    reports `metrics["rejected"]=1`. Everything stays on device; the fit
    loop accumulates the rejection count lazily and syncs once after the
    loop (no host sync in the hot path).
  * dist chief — `GradScreen` vets each worker's push under the store lock
    (numpy float64, the chief's native arithmetic): non-finite gradients are
    always rejected; at level "full" a gradient whose l2 norm exceeds
    `factor x` the EMA of accepted norms is rejected too. Consecutive
    rejections quarantine the worker for `quarantine_steps` versions — it
    still gets served fresh params (it may recover), its pushes just stop
    reaching W.

`DivergenceDetector` is the post-apply backstop the screens cannot provide:
a finite-but-poisoned update shows up as a validation-loss explosion one
apply later, and the store answers with a rollback to the last verified
snapshot (see `ParameterStore._rollback_locked`).

Thread safety: GradScreen/DivergenceDetector mutate plain attributes and are
only ever called by the store with `store.cond` held — they deliberately own
no lock of their own (one lock discipline, the store's).
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: accepted pushes before the norm EMA is trusted as a rejection threshold
NORM_WARMUP = 5


@dataclasses.dataclass(frozen=True)
class SentinelPolicy:
    """The spec's resilience knobs, resolved once (see ExperimentSpec)."""

    level: str = ""                # "" | "finite" | "full"
    factor: float = 10.0
    rollback: bool = False
    max_rollbacks: int = 3
    lr_backoff: float = 0.5
    quarantine_steps: int = 0
    quarantine_after: int = 3

    @classmethod
    def from_spec(cls, spec) -> "SentinelPolicy":
        return cls(level=spec.sentinel, factor=spec.sentinel_factor,
                   rollback=spec.rollback, max_rollbacks=spec.max_rollbacks,
                   lr_backoff=spec.lr_backoff,
                   quarantine_steps=spec.quarantine_steps,
                   quarantine_after=spec.quarantine_after)

    @property
    def screening(self) -> bool:
        return bool(self.level)

    @property
    def norm_screen(self) -> bool:
        return self.level == "full"


class GradScreen:
    """Per-worker gradient screening for the chief's push path.

    NOT internally locked: the store calls `admit` under its own condition
    lock, which also serializes the counters this object keeps."""

    def __init__(self, policy: SentinelPolicy):
        self.policy = policy
        self.norm_ema = 0.0
        self.accepts = 0
        self.rejections: dict = {}          # wid -> rejected pushes
        self.reasons: dict = {}             # reason -> count
        self.consecutive: dict = {}         # wid -> consecutive rejections
        self.quarantined_until: dict = {}   # wid -> version the ban lifts at
        self.quarantines = 0

    def admit(self, wid: int, g: np.ndarray, version: int):
        """None -> apply the push; otherwise the rejection reason (already
        counted). `version` is the store version the verdict is made at."""
        if version < self.quarantined_until.get(wid, -1):
            self._count(wid, "quarantined")
            return "quarantined"
        if not np.all(np.isfinite(g)):
            return self._reject(wid, version, "non-finite")
        if self.policy.norm_screen:
            n = float(np.linalg.norm(g))
            if self.accepts >= NORM_WARMUP and \
                    n > self.policy.factor * max(self.norm_ema, 1e-12):
                return self._reject(wid, version, "norm-exploded")
            self.norm_ema = (0.9 * self.norm_ema + 0.1 * n
                             if self.accepts else n)
        self.accepts += 1
        self.consecutive[wid] = 0
        return None

    def quarantine(self, wid: int, version: int):
        """Ban `wid`'s pushes until version + quarantine_steps (also the
        store's remedy after a rollback attributed to this worker)."""
        if self.policy.quarantine_steps:
            self.quarantined_until[wid] = version + self.policy.quarantine_steps
            self.quarantines += 1
            self.consecutive[wid] = 0

    def _reject(self, wid: int, version: int, reason: str) -> str:
        self._count(wid, reason)
        self.consecutive[wid] = self.consecutive.get(wid, 0) + 1
        if self.consecutive[wid] >= self.policy.quarantine_after:
            self.quarantine(wid, version)
        return reason

    def _count(self, wid: int, reason: str):
        self.rejections[wid] = self.rejections.get(wid, 0) + 1
        self.reasons[reason] = self.reasons.get(reason, 0) + 1

    def counters(self) -> dict:
        return {
            "rejections": sum(self.rejections.values()),
            "rejections_by_worker": dict(self.rejections),
            "rejection_reasons": dict(self.reasons),
            "quarantines": self.quarantines,
        }


class DivergenceDetector:
    """Post-apply trajectory check: the validation loss after an apply must
    stay finite and below `factor x` the best loss seen — a finite but
    poisoned update (huge-yet-representable gradient) trips here, one apply
    after it slipped past the per-push screen."""

    def __init__(self, factor: float):
        self.factor = float(factor)
        self.best = np.inf

    def update(self, avg: float) -> bool:
        """Record one post-apply validation loss; True -> diverged."""
        if not np.isfinite(avg):
            return True
        if np.isfinite(self.best) and avg > self.factor * max(self.best, 1e-12):
            return True
        self.best = min(self.best, float(avg))
        return False


def wrap_step_sentinel(step_fn, level: str, factor: float):
    """Fuse screening into a mesh train step: `guarded(params, gstate, batch)`
    runs `step_fn` and keeps its output only when the step is sane —
    otherwise the previous carry is re-threaded (the batch is consumed, the
    update is not). Adds `metrics["rejected"]` (0/1 int32) so the fit loop
    can account rejections without leaving the device.

    level "finite" checks the step loss; "full" additionally checks every
    updated-parameter leaf and rejects a loss above `factor x |prev_avg_loss|`
    (the GuidedState's previous verification loss; its inf init passes the
    first steps via the isfinite gate).
    """
    import jax
    import jax.numpy as jnp

    def guarded(params, gstate, batch):
        p2, g2, m = step_fn(params, gstate, batch)
        loss = m["loss"]
        ok = jnp.isfinite(loss)
        if level == "full":
            for leaf in jax.tree_util.tree_leaves(p2):
                ok = ok & jnp.all(jnp.isfinite(leaf))
            prev = gstate.prev_avg_loss
            spike = jnp.isfinite(prev) & (
                loss > jnp.float32(factor) * jnp.abs(prev).astype(loss.dtype))
            ok = ok & ~spike
        keep = lambda new, old: jnp.where(ok, new, old)
        p_out = jax.tree_util.tree_map(keep, p2, params)
        g_out = jax.tree_util.tree_map(keep, g2, gstate)
        m = dict(m)
        m["rejected"] = (~ok).astype(jnp.int32)
        return p_out, g_out, m

    return guarded
