"""`repro.resilience` — the self-healing layer (DESIGN.md §14).

Detection + remediation for diverging or dying training runs:

  * `SentinelPolicy` / `GradScreen` / `DivergenceDetector` /
    `wrap_step_sentinel` — divergence screening fused into the mesh train
    step and the dist chief's push path, with rollback / lr-backoff /
    quarantine remediation (sentinel.py);
  * `LeaseTable` / `Supervisor` — chief-side heartbeat leases and the
    worker-process supervisor: respawn under capped backoff + jitter,
    eviction of persistent stragglers (supervisor.py).

Verified checkpoints (per-entry SHA-256 + fallback-through-history restore)
live in `repro.checkpoint`; the fault injectors driving the chaos suite in
`repro.chaos`; the RecoveryModel proving the remediation protocol safe in
`repro.analysis.modelcheck`.
"""
from repro.resilience.sentinel import (
    DivergenceDetector,
    GradScreen,
    SentinelPolicy,
    wrap_step_sentinel,
)
from repro.resilience.supervisor import LeaseTable, Supervisor

__all__ = [
    "DivergenceDetector",
    "GradScreen",
    "LeaseTable",
    "SentinelPolicy",
    "Supervisor",
    "wrap_step_sentinel",
]
