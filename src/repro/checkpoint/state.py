"""Full-state training snapshots (`repro.checkpoint` v2).

A checkpoint of the mesh trainer is NOT just the parameters: the paper's
guided compensation is *stateful* — consistency scores accumulated over the
current rho-window, the `w_stale` copy the ASGD staleness model compensates
against, the inner optimizer accumulators and any strategy-owned `extra`
pytree. Dropping any of it on restore silently restarts compensation from
scratch, which is exactly the failure mode delay-compensated training exists
to survive. A snapshot therefore covers:

    {"params": <model pytree>,
     "gstate": <GuidedState: step, score, prev losses, w_stale, opt_state, extra>,
     "data":   {"cursor": <batches consumed>}}

The data cursor is the stream position: the synthetic corpus generators are
deterministic functions of (seed, #draws), so replaying `cursor` draws on
resume reproduces the exact rng state — train(N) == train(k) + resume(N-k)
leaf for leaf (tests/test_resume.py locks this per strategy).

Restore is resharding-aware: `train_state_shardings` extends the model's
logical-axis sharding tree (sharding/rules.py) over the whole snapshot —
w_stale and param-structured optimizer accumulators (momentum/rmsprop "m"/"r"
mirrors) reshard exactly like the params; scalars and consistency vectors
replicate — so a snapshot written on `local` restores onto `host`/`prod`
meshes and vice versa.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

from repro.checkpoint.npz import restore, step_path


def snapshot(params, gstate, cursor: int) -> dict:
    """The canonical full-state snapshot tree (also the restore template:
    build it from a freshly initialized train state and restore into it)."""
    return {
        "params": params,
        "gstate": gstate,
        "data": {"cursor": np.asarray(cursor, np.int64)},
    }


def spec_meta(spec) -> dict:
    """Manifest metadata recorded next to every snapshot — enough to rebuild
    the model config (ServeEngine.from_checkpoint) and to eyeball what run a
    checkpoint dir belongs to."""
    return {
        "arch": spec.arch,
        "reduced": spec.reduced,
        "model_overrides": [list(kv) for kv in spec.model_overrides],
        "mode": spec.mode,
        "strategy": spec.strategy,
        "optimizer": spec.optimizer,
        "seed": spec.seed,
        "steps": spec.steps,
    }


def model_config_from_manifest(ckpt_dir: str, step: int = None):
    """Rebuild the ModelConfig a snapshot was trained under from the manifest
    metadata (`spec_meta`): the one authoritative config for restoring that
    snapshot, shared by `ServeEngine.from_checkpoint` and the serve CLI.
    Raises if the manifest records no arch (e.g. a hand-written dir)."""
    from repro.checkpoint.writer import manifest_meta
    from repro.configs import get_config

    meta = manifest_meta(ckpt_dir, step)
    if "arch" not in meta:
        raise ValueError(
            f"checkpoint manifest in {ckpt_dir} records no arch metadata; "
            f"pass the model config explicitly")
    cfg = get_config(meta["arch"])
    if meta.get("reduced"):
        cfg = cfg.reduced()
    overrides = meta.get("model_overrides") or []
    if overrides:
        cfg = cfg.replace(**{k: v for k, v in overrides})
    return cfg


def dist_snapshot(W, version: int, staleness, r=None, lr_scale: float = 1.0) -> dict:
    """Chief-side snapshot of the async parameter server (repro.dist): the
    authoritative weights, the store version, the observed staleness sequence
    so far, plus — for rollback-capable stores (DESIGN.md §14) — the
    optimizer accumulator `r` and the sentinel's current `lr_scale`, so a
    restored state resumes the exact optimizer trajectory. Same manifest
    format as the mesh snapshots (one checkpoint subsystem, §8/§10)."""
    d = {
        "W": np.asarray(W, np.float64),
        "version": np.asarray(version, np.int64),
        "staleness": np.asarray(staleness, np.int64),
        "lr_scale": np.asarray(lr_scale, np.float64),
    }
    if r is not None:
        d["r"] = np.asarray(r, np.float64)
    return {"dist": d}


def _dist_load(path: str, step) -> dict:
    """Decode one chief archive to {name: array}; corruption (truncated zip,
    bad CRC) surfaces as CorruptCheckpointError naming step and path."""
    from repro.checkpoint.npz import CorruptCheckpointError

    try:
        data = np.load(path)
        out = {}
        for key in data.files:
            # keys look like ['dist']/['W']; strip the path syntax
            name = key.split("/")[-1].strip("[]'")
            out[name] = data[key]
        return out
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CorruptCheckpointError(
            f"chief snapshot step {step} at {path} cannot be read "
            f"({type(e).__name__}: {e}): the archive is corrupt or "
            f"truncated") from e


def dist_restore(ckpt_dir: str, step: int = None) -> dict:
    """Load a chief snapshot: {"W", "version", "staleness", ...} as numpy
    arrays (older archives may lack "r"/"lr_scale").

    With step=None this applies both reader-side disciplines of
    `npz.restore_latest`: re-read the manifest when the named step was pruned
    under us (retention race), and fall back through manifest history past
    entries whose SHA-256 or decode fails, to the newest intact step — the
    chief's rollback path (ParameterStore._rollback_locked) relies on this to
    never restore from a torn archive."""
    from repro.checkpoint.npz import (
        CorruptCheckpointError,
        latest_step,
        manifest_entries,
        verify_entry,
    )

    if step is not None:
        return _dist_load(step_path(ckpt_dir, step), step)
    for _ in range(8):
        entries = manifest_entries(ckpt_dir)
        if not entries:
            latest = latest_step(ckpt_dir)
            if latest is None:
                raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
            entries = [{"step": latest,
                        "file": os.path.basename(step_path(ckpt_dir, latest))}]
        tried, raced = [], False
        for entry in entries:
            try:
                verify_entry(ckpt_dir, entry)
                return _dist_load(os.path.join(ckpt_dir, entry["file"]),
                                  entry["step"])
            except FileNotFoundError:
                raced = True  # pruned under us; re-read the manifest
                break
            except CorruptCheckpointError as e:
                tried.append(str(e))
        if raced:
            continue
        raise CorruptCheckpointError(
            f"no intact chief snapshot in {ckpt_dir}: every retained "
            f"manifest entry failed verification — " + " | ".join(tried))
    raise FileNotFoundError(
        f"chief snapshots in {ckpt_dir} kept vanishing across 8 "
        f"manifest reads; the dir is being deleted, not just pruned")


def restore_train_state(ckpt_dir: str, step: int, template: dict, shardings=None) -> dict:
    """Restore a full snapshot into the structure of `template` (a `snapshot()`
    of a freshly initialized train state). `shardings` re-places leaves across
    mesh kinds (see `train_state_shardings`)."""
    return restore(ckpt_dir, step, template, shardings=shardings)


def restore_subtree(ckpt_dir: str, step: int, entry: str, template, shardings=None):
    """Restore ONE top-level entry of a snapshot archive (e.g. entry="params"
    into a model pytree) without materializing the rest — how a serving
    process warm-starts from a training checkpoint. Also accepts v1 archives
    that stored `{entry: tree}` directly, since the key paths coincide."""
    path = step_path(ckpt_dir, step)
    data = np.load(path)
    prefix = f"['{entry}']"
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    available = set(data.files)
    leaves, missing = [], []
    for p, leaf in flat:
        rest = "/".join(str(x) for x in p)
        key = f"{prefix}/{rest}" if rest else prefix
        if key not in available:
            missing.append(key)
            continue
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint {path}: leaf {key!r} has shape {tuple(arr.shape)} "
                f"but the restore template expects {tuple(leaf.shape)} — was "
                f"this snapshot written under a different model config?")
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    if missing:
        have = sorted(k for k in available if k.startswith(prefix))[:8]
        raise ValueError(
            f"checkpoint {path} has no {entry!r} subtree matching the template: "
            f"missing {sorted(missing)[:8]}; archive has {have or 'no such keys'}")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def train_state_shardings(ctx, logical, params, gstate) -> dict:
    """Sharding tree for a full snapshot on `ctx.mesh`, derived from the
    model's logical annotations via the existing `shardings_for` hook.

    Param-structured subtrees (w_stale, momentum/rmsprop/adam accumulators)
    inherit the params' shardings leaf for leaf; everything else (step
    counters, (c,) consistency vectors, strategy extras, the data cursor)
    replicates. This is what makes restore reshard across mesh kinds:
    local -> host -> prod all route through the same logical rules."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.sharding.rules import shardings_for

    if ctx.mesh is None:
        raise ValueError("train_state_shardings needs a distributed ShardCtx "
                         "(ctx.mesh is None); restore with shardings=None instead")
    pshard = shardings_for(logical, params, ctx.mesh, ctx.rules)
    repl = NamedSharding(ctx.mesh, PartitionSpec())
    ptree = jax.tree.structure(params)

    def mirror(sub: Any):
        if jax.tree.structure(sub) == ptree:
            return pshard
        if isinstance(sub, dict):
            return {k: mirror(v) for k, v in sub.items()}
        return jax.tree.map(lambda _: repl, sub)

    gshard = gstate._replace(
        step=repl,
        score=repl,
        prev_worker_loss=repl,
        prev_avg_loss=repl,
        w_stale=mirror(gstate.w_stale),
        opt_state=mirror(gstate.opt_state),
        extra=jax.tree.map(lambda _: repl, gstate.extra),
    )
    return {"params": pshard, "gstate": gshard, "data": {"cursor": repl}}
