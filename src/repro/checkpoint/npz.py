"""Sharding-aware npz checkpointing (orbax is not on the image).

Pytrees are flattened with jax.tree_util key paths as archive keys; restore
rebuilds the tree and (optionally) re-places leaves onto a sharding tree via
jax.device_put — so a checkpoint written on one mesh restores onto another
(the standard resharding-restore pattern, at npz scale).

Layout: <dir>/step_<N>.npz + <dir>/LATEST. Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = leaf
        # numpy has no bfloat16: store as float32, restore() re-casts from the
        # target tree's dtype
        if hasattr(arr, "dtype") and arr.dtype == jax.numpy.bfloat16:
            arr = arr.astype(jax.numpy.float32)
        out[key] = np.asarray(arr)
    return out


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return path


def latest_step(ckpt_dir: str):
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Restore into the structure of `tree_like`. If `shardings` (a matching
    tree of jax.sharding.Sharding) is given, leaves are device_put onto it."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
