"""Sharding-aware npz checkpointing (orbax is not on the image).

Pytrees are flattened with jax.tree_util key paths as archive keys; restore
rebuilds the tree and (optionally) re-places leaves onto a sharding tree via
jax.device_put — so a checkpoint written on one mesh restores onto another
(the standard resharding-restore pattern, at npz scale).

Layout: <dir>/step_<N>.npz. Which step is current is recorded by the
MANIFEST.json written by `repro.checkpoint.writer` (atomic, with retention);
`latest_step` also understands the v1 bare `LATEST` file so old checkpoint
dirs keep restoring. Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = leaf
        # numpy has no bfloat16: store as float32, restore() re-casts from the
        # target tree's dtype (bf16 -> f32 -> bf16 is exact: bf16 values are a
        # subset of f32, so round-trips are bit-preserving)
        if hasattr(arr, "dtype") and arr.dtype == jax.numpy.bfloat16:
            arr = arr.astype(jax.numpy.float32)
        out[key] = np.asarray(arr)
    return out


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def write_archive(ckpt_dir: str, step: int, flat: dict) -> str:
    """Atomically write an already-flattened {key: np.ndarray} archive."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = step_path(ckpt_dir, step)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def save(ckpt_dir: str, step: int, tree) -> str:
    """Low-level synchronous save of one pytree (v1 API). Keeps writing the
    legacy LATEST pointer; full-state training snapshots go through
    `repro.checkpoint.writer` which maintains MANIFEST.json instead."""
    path = write_archive(ckpt_dir, step, _flatten(tree))
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return path


def read_manifest(ckpt_dir: str) -> dict | None:
    p = os.path.join(ckpt_dir, MANIFEST)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def latest_step(ckpt_dir: str):
    """Newest checkpointed step: MANIFEST.json when present (the v2 atomic
    manifest), falling back to the v1 bare LATEST file. None if neither."""
    man = read_manifest(ckpt_dir)
    if man is not None:
        return man.get("latest")
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_latest(ckpt_dir: str, tree_like, shardings=None, attempts: int = 8):
    """Restore the newest snapshot, racing safely against retention.

    The writer's retention pass updates MANIFEST.json *before* unlinking a
    pruned archive, so a reader can never be pointed at a file that is about
    to disappear — but a reader that loaded the manifest just *before* the
    update can still lose the race: its (stale) latest step gets pruned
    between `latest_step` and `np.load`. The fix is reader-side: on
    FileNotFoundError, re-read the manifest (which by then names a newer,
    retained step) and retry. Returns `(step, tree)`; raises
    FileNotFoundError only when the dir has no checkpoints at all or a step
    keeps vanishing `attempts` times (a broken dir, not a race).
    """
    last = None
    for _ in range(attempts):
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
        try:
            return step, restore(ckpt_dir, step, tree_like, shardings=shardings)
        except FileNotFoundError as e:
            # step was pruned under us; the next manifest read sees its
            # replacement (manifest-before-unlink ordering in the writer)
            last = e
    raise FileNotFoundError(
        f"checkpoint archives in {ckpt_dir} kept vanishing across "
        f"{attempts} manifest reads (last: {last}); the dir is being "
        f"deleted, not just pruned")


def _mismatch_error(path: str, missing, unexpected, n_template: int, n_archive: int):
    def fmt(keys):
        keys = sorted(keys)
        head = ", ".join(keys[:8])
        return head + (f", ... ({len(keys)} total)" if len(keys) > 8 else "")

    parts = [f"checkpoint {path} does not match the restore template "
             f"({n_template} template leaves vs {n_archive} archived arrays)"]
    if missing:
        parts.append(f"missing from archive: {fmt(missing)}")
    if unexpected:
        parts.append(f"unexpected in archive: {fmt(unexpected)}")
    parts.append("was this checkpoint written by a different model/strategy/"
                 "optimizer configuration?")
    return ValueError("; ".join(parts))


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Restore into the structure of `tree_like`. If `shardings` (a matching
    tree of jax.sharding.Sharding) is given, leaves are device_put onto it.

    Tree/archive mismatches raise ValueError naming the missing and
    unexpected keys (not a bare KeyError), so a checkpoint written by a
    different config fails with an actionable message."""
    path = step_path(ckpt_dir, step)
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint archive at {path}")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    keys = ["/".join(str(x) for x in p) for p, _ in flat]
    archived = set(data.files)
    missing = [k for k in keys if k not in archived]
    unexpected = sorted(archived - set(keys))
    if missing or unexpected:
        raise _mismatch_error(path, missing, unexpected, len(keys), len(archived))
    leaves = []
    for key, (p, leaf) in zip(keys, flat):
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint {path}: leaf {key!r} has shape {tuple(arr.shape)} "
                f"but the restore template expects {tuple(leaf.shape)}")
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
