"""Sharding-aware npz checkpointing (orbax is not on the image).

Pytrees are flattened with jax.tree_util key paths as archive keys; restore
rebuilds the tree and (optionally) re-places leaves onto a sharding tree via
jax.device_put — so a checkpoint written on one mesh restores onto another
(the standard resharding-restore pattern, at npz scale).

Layout: <dir>/step_<N>.npz. Which step is current is recorded by the
MANIFEST.json written by `repro.checkpoint.writer` (atomic, with retention);
`latest_step` also understands the v1 bare `LATEST` file so old checkpoint
dirs keep restoring. Writes are atomic (tmp + rename).

Verification (DESIGN.md §14): every manifest entry records the archive's
SHA-256 (`sha256` key, hex). `verify_entry` recomputes and compares;
`restore_latest` verifies before restoring and FALLS BACK through manifest
history past corrupt/truncated archives to the newest intact step, so one
torn write (power loss mid-rename on a non-atomic filesystem, a bad disk
sector) costs at most `ckpt_every` steps of progress, never the run. All
corruption surfaces as `CorruptCheckpointError` (a ValueError) naming the
step and path — template mismatches stay plain ValueErrors and do NOT fall
back: restoring an older step cannot fix a wrong model config.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


class CorruptCheckpointError(ValueError):
    """An archive that cannot be trusted: checksum mismatch, truncated or
    undecodable npz. Distinct from a template mismatch (plain ValueError) so
    restore_latest knows when falling back to an older step is sound."""


def file_sha256(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return h.hexdigest()
            h.update(block)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = leaf
        # numpy has no bfloat16: store as float32, restore() re-casts from the
        # target tree's dtype (bf16 -> f32 -> bf16 is exact: bf16 values are a
        # subset of f32, so round-trips are bit-preserving)
        if hasattr(arr, "dtype") and arr.dtype == jax.numpy.bfloat16:
            arr = arr.astype(jax.numpy.float32)
        out[key] = np.asarray(arr)
    return out


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def write_archive(ckpt_dir: str, step: int, flat: dict) -> str:
    """Atomically write an already-flattened {key: np.ndarray} archive."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = step_path(ckpt_dir, step)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def save(ckpt_dir: str, step: int, tree) -> str:
    """Low-level synchronous save of one pytree (v1 API). Keeps writing the
    legacy LATEST pointer; full-state training snapshots go through
    `repro.checkpoint.writer` which maintains MANIFEST.json instead."""
    path = write_archive(ckpt_dir, step, _flatten(tree))
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return path


def read_manifest(ckpt_dir: str) -> dict | None:
    p = os.path.join(ckpt_dir, MANIFEST)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def latest_step(ckpt_dir: str):
    """Newest checkpointed step: MANIFEST.json when present (the v2 atomic
    manifest), falling back to the v1 bare LATEST file. None if neither."""
    man = read_manifest(ckpt_dir)
    if man is not None:
        return man.get("latest")
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def manifest_entries(ckpt_dir: str) -> list:
    """Manifest entries newest-first ([] when there is no manifest)."""
    man = read_manifest(ckpt_dir)
    if man is None:
        return []
    return sorted(man.get("ckpts", []), key=lambda c: c["step"], reverse=True)


def verify_entry(ckpt_dir: str, entry: dict) -> None:
    """Recompute an entry's archive SHA-256 against the manifest record.
    Entries written before checksums were recorded pass vacuously; a
    mismatch raises CorruptCheckpointError naming the step and path."""
    want = entry.get("sha256")
    if want is None:
        return
    path = os.path.join(ckpt_dir, entry["file"])
    got = file_sha256(path)
    if got != want:
        raise CorruptCheckpointError(
            f"checkpoint step {entry['step']} at {path} fails its manifest "
            f"checksum (sha256 {got[:12]} != recorded {want[:12]}): the "
            f"archive is corrupt or truncated")


def restore_latest(ckpt_dir: str, tree_like, shardings=None, attempts: int = 8):
    """Restore the newest INTACT snapshot, racing safely against retention.

    Two reader-side disciplines compose here:

      * retention race — the writer updates MANIFEST.json *before* unlinking
        a pruned archive, so a reader can never be pointed at a file about
        to disappear; a reader whose manifest read lost the race simply
        re-reads it (up to `attempts` times) and sees the retained step.
      * verification fallback — each candidate entry's SHA-256 is checked
        before the restore; a corrupt/truncated archive is skipped and the
        next-older manifest entry tried, down to the oldest retained step.

    Returns `(step, tree)`. Raises FileNotFoundError when the dir has no
    checkpoints (or keeps vanishing — a deleted dir, not a race) and
    CorruptCheckpointError when every retained entry fails verification.
    Template mismatches (plain ValueError) propagate immediately: an older
    snapshot of the wrong config is not a recovery.
    """
    last = None
    for _ in range(attempts):
        entries = manifest_entries(ckpt_dir)
        if not entries:
            # v1 dir: a bare LATEST pointer names the single candidate
            step = latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
            entries = [{"step": step,
                        "file": os.path.basename(step_path(ckpt_dir, step))}]
        tried, raced = [], False
        for entry in entries:
            step = entry["step"]
            try:
                verify_entry(ckpt_dir, entry)
                return step, restore(ckpt_dir, step, tree_like,
                                     shardings=shardings)
            except FileNotFoundError as e:
                # pruned under us; the next manifest read sees its
                # replacement (manifest-before-unlink ordering in the writer)
                last, raced = e, True
                break
            except CorruptCheckpointError as e:
                tried.append(str(e))
        if raced:
            continue
        raise CorruptCheckpointError(
            f"no intact checkpoint in {ckpt_dir}: every retained manifest "
            f"entry failed verification — " + " | ".join(tried))
    raise FileNotFoundError(
        f"checkpoint archives in {ckpt_dir} kept vanishing across "
        f"{attempts} manifest reads (last: {last}); the dir is being "
        f"deleted, not just pruned")


def _mismatch_error(path: str, missing, unexpected, n_template: int, n_archive: int):
    def fmt(keys):
        keys = sorted(keys)
        head = ", ".join(keys[:8])
        return head + (f", ... ({len(keys)} total)" if len(keys) > 8 else "")

    parts = [f"checkpoint {path} does not match the restore template "
             f"({n_template} template leaves vs {n_archive} archived arrays)"]
    if missing:
        parts.append(f"missing from archive: {fmt(missing)}")
    if unexpected:
        parts.append(f"unexpected in archive: {fmt(unexpected)}")
    parts.append("was this checkpoint written by a different model/strategy/"
                 "optimizer configuration?")
    return ValueError("; ".join(parts))


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Restore into the structure of `tree_like`. If `shardings` (a matching
    tree of jax.sharding.Sharding) is given, leaves are device_put onto it.

    Tree/archive mismatches raise ValueError naming the missing and
    unexpected keys (not a bare KeyError), so a checkpoint written by a
    different config fails with an actionable message. Archives that cannot
    even be decoded (truncated file, flipped bytes, bad CRC) raise
    CorruptCheckpointError naming the step and path, instead of leaking
    zipfile/zlib internals."""
    path = step_path(ckpt_dir, step)
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint archive at {path}")
    try:
        data = np.load(path)
        archived = set(data.files)
    except Exception as e:
        raise CorruptCheckpointError(
            f"checkpoint step {step} at {path} cannot be read "
            f"({type(e).__name__}: {e}): the archive is corrupt or "
            f"truncated") from e
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    keys = ["/".join(str(x) for x in p) for p, _ in flat]
    missing = [k for k in keys if k not in archived]
    unexpected = sorted(archived - set(keys))
    if missing or unexpected:
        raise _mismatch_error(path, missing, unexpected, len(keys), len(archived))
    leaves = []
    for key, (p, leaf) in zip(keys, flat):
        try:
            arr = data[key]
        except Exception as e:
            # a flipped byte inside the compressed stream surfaces here as a
            # CRC/zlib error, not at np.load
            raise CorruptCheckpointError(
                f"checkpoint step {step} at {path}: entry {key!r} cannot be "
                f"decoded ({type(e).__name__}: {e}): the archive is corrupt "
                f"or truncated") from e
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint {path}: leaf {key!r} has shape {tuple(arr.shape)} "
                f"but the restore template expects {tuple(leaf.shape)}")
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
