"""Checkpointing: v1 npz pytree archives + the v2 full-state subsystem
(TrainState snapshots, async manifest writer, resharding restore — DESIGN.md §8)."""
from repro.checkpoint.npz import (  # noqa: F401
    CorruptCheckpointError,
    file_sha256,
    latest_step,
    manifest_entries,
    read_manifest,
    restore,
    restore_latest,
    save,
    verify_entry,
)
from repro.checkpoint.state import (  # noqa: F401
    dist_restore,
    dist_snapshot,
    model_config_from_manifest,
    restore_subtree,
    restore_train_state,
    snapshot,
    spec_meta,
    train_state_shardings,
)
from repro.checkpoint.writer import (  # noqa: F401
    AsyncCheckpointer,
    manifest_meta,
    save_train_state,
)
