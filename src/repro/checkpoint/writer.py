"""Async checkpoint writer: device->host on the step boundary, serialization
off the hot path, atomic MANIFEST.json + retention.

The training loop cannot afford to block on np.savez (compression + disk I/O)
every ckpt_every steps, but it also cannot hand the writer live device
buffers: the mesh step is jitted with donated arguments, so the arrays handed
to a callback are reused by the *next* step's dispatch. `AsyncCheckpointer`
therefore splits the save at exactly that boundary:

  * `save(step, tree)` — caller thread — copies device->host (np.asarray per
    leaf; this waits for the step's computation, which IS the step boundary,
    then the transfer) and enqueues the flat host arrays;
  * a single background thread serializes (atomic tmp+rename npz), updates
    MANIFEST.json atomically, and prunes archives beyond `keep_last`.

MANIFEST.json replaces the v1 bare `LATEST` file: one atomic JSON document
recording every retained step with its file and metadata, so a reader never
observes a pointer to a half-written archive and `latest_step` survives any
kill point. Writer errors are captured and re-raised on the next
save/wait/close — a full disk fails the run instead of silently dropping
snapshots.
"""
from __future__ import annotations

import json
import os
import queue
import tempfile
import threading
import time

from repro.checkpoint.npz import (
    MANIFEST,
    _flatten,
    file_sha256,
    read_manifest,
    step_path,
    write_archive,
)


def _write_manifest(ckpt_dir: str, man: dict) -> None:
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(man, f, indent=1)
        os.replace(tmp, os.path.join(ckpt_dir, MANIFEST))
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _update_manifest(ckpt_dir: str, step: int, fname: str, meta: dict,
                     keep_last: int, sha256: str = None) -> None:
    """Append/replace the entry for `step`, advance `latest`, prune beyond
    `keep_last` (0 keeps everything). Called only from the writer thread (or
    the sync path), so updates are serialized. `sha256` is the archive's
    content hash (npz.file_sha256) recorded for restore-time verification."""
    man = read_manifest(ckpt_dir) or {"version": 2, "latest": None, "ckpts": []}
    man["ckpts"] = [c for c in man["ckpts"] if c["step"] != step]
    entry = {"step": step, "file": fname, "time": time.time(), "meta": meta}
    if sha256 is not None:
        entry["sha256"] = sha256
    man["ckpts"].append(entry)
    man["ckpts"].sort(key=lambda c: c["step"])
    pruned = []
    if keep_last and len(man["ckpts"]) > keep_last:
        pruned, man["ckpts"] = man["ckpts"][:-keep_last], man["ckpts"][-keep_last:]
    man["latest"] = man["ckpts"][-1]["step"]
    _write_manifest(ckpt_dir, man)
    for c in pruned:  # after the manifest no longer references them
        try:
            os.unlink(os.path.join(ckpt_dir, c["file"]))
        except FileNotFoundError:
            pass


def manifest_meta(ckpt_dir: str, step=None) -> dict:
    """Metadata recorded with `step` (default: the latest entry)."""
    man = read_manifest(ckpt_dir)
    if man is None or not man.get("ckpts"):
        raise FileNotFoundError(f"no {MANIFEST} with entries in {ckpt_dir}")
    if step is None:
        step = man["latest"]
    for c in man["ckpts"]:
        if c["step"] == step:
            return c.get("meta", {})
    raise ValueError(f"step {step} not in {ckpt_dir}/{MANIFEST}: "
                     f"retained steps {[c['step'] for c in man['ckpts']]}")


def save_train_state(ckpt_dir: str, step: int, tree, meta: dict = None,
                     keep_last: int = 0) -> str:
    """Synchronous full-state save: archive + manifest in the caller's thread.
    The blocking baseline the async writer is benchmarked against; also the
    right call for one-off snapshots outside a training loop."""
    path = write_archive(ckpt_dir, step, _flatten(tree))
    _update_manifest(ckpt_dir, step, os.path.basename(path), dict(meta or {}),
                     keep_last, sha256=file_sha256(path))
    return path


class AsyncCheckpointer:
    """One writer thread + bounded handoff of host-side snapshots.

        ckpt = AsyncCheckpointer(dir, keep_last=3, meta={...})
        ckpt.save(step, snapshot(params, gstate, step))   # ~copy cost only
        ...
        ckpt.close()                                      # drain + join

    `save` on a step already enqueued/written last is a no-op (the final save
    at loop exit dedupes against the last periodic one). The queue depth of 2
    bounds host memory to <= 3 snapshots in flight; if the disk can't keep up
    the training loop backpressures rather than ballooning RAM.
    """

    def __init__(self, ckpt_dir: str, keep_last: int = 3, meta: dict = None):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self.meta = dict(meta or {})
        os.makedirs(ckpt_dir, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._lock = threading.Lock()   # guards _err and _last_step
        self._err: BaseException | None = None
        self._last_step: int | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="ckpt-writer")
        self._thread.start()

    # ------------------------------------------------------------- caller side

    def save(self, step: int, tree, block: bool = False) -> bool:
        """Snapshot `tree` as `step`. Device->host happens here (caller
        thread, step boundary); serialization happens on the writer thread.
        Returns False when deduped (same step as the previous save)."""
        self._raise_pending()
        with self._lock:
            if step == self._last_step:
                return False
            self._last_step = step
        flat = _flatten(tree)  # np.asarray per leaf: sync + copy off device
        self._q.put((step, flat))
        if block:
            self.wait()
        return True

    def wait(self) -> None:
        """Block until every enqueued snapshot is on disk."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain, stop the writer thread, re-raise any pending write error."""
        self._q.join()
        self._q.put(None)
        self._thread.join()
        self._raise_pending()

    def _raise_pending(self):
        with self._lock:
            err, self._err = self._err, None
        if err is not None:
            raise RuntimeError(
                f"checkpoint writer failed for {self.ckpt_dir}") from err

    # ------------------------------------------------------------- writer side

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, flat = item
                path = write_archive(self.ckpt_dir, step, flat)
                _update_manifest(self.ckpt_dir, step, os.path.basename(path),
                                 self.meta, self.keep_last,
                                 sha256=file_sha256(path))
            except BaseException as e:  # surfaced on the caller's next call
                with self._lock:
                    self._err = e
            finally:
                self._q.task_done()
