"""Worker-speed / delay topologies — ONE source of truth.

Per-dispatch compute-time samplers `sampler(worker_id, rng) -> float` shared by

  * the scan simulator's schedule generator (repro.engine.delaysim drives
    core.parameter_server._event_schedule with these to precompute a
    DelaySchedule), and
  * the dist subsystem's fault injector (repro.dist.scenarios scales a real
    worker's per-step sleep by the same draw),

so a `straggler` run means the same worker-speed distribution whether the
delay is simulated inside one lax.scan or produced by actual processes racing
each other. `None` keeps the reference loop's literal draw
(rng.exponential(1.0) + 0.1), preserving rng-stream parity with train_ps.
"seq" and "barrier" are the deterministic topologies of those execution modes
and need no sampler.
"""
from __future__ import annotations

TOPOLOGY_SAMPLERS = {
    "seq": None,
    "barrier": None,
    "exp": None,
    "constant": lambda w, rng: 1.0,
    "heavy_tail": lambda w, rng: 0.1 + rng.pareto(1.5),
    "straggler": lambda w, rng: (10.0 if w == 0 else 1.0) * rng.exponential(1.0) + 0.1,
    "hetero": lambda w, rng: rng.exponential(0.5 * (w + 2)) + 0.1,
}


def _exp_sampler(w: int, rng) -> float:
    """train_ps's literal compute-time draw (the `None` entries above)."""
    return rng.exponential(1.0) + 0.1


def compute_time_sampler(topology: str):
    """The sampler a REAL worker's compute time should follow for `topology`
    (the deterministic seq/barrier topologies fall back to the reference
    exponential draw — they describe arrival ordering, not speed)."""
    try:
        sampler = TOPOLOGY_SAMPLERS[topology]
    except KeyError:
        raise KeyError(
            f"unknown topology {topology!r}; known: {', '.join(TOPOLOGY_SAMPLERS)}"
        ) from None
    return sampler or _exp_sampler
