"""Common utilities: pytree helpers, dtype policy, deterministic RNG splitting."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_size(tree: Pytree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha * x + y"""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: Pytree, b: Pytree):
    parts = jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, parts)


def tree_norm(a: Pytree):
    return jnp.sqrt(tree_dot(a, a))


def global_norm(tree: Pytree):
    return tree_norm(tree)


def split_like(key: jax.Array, tree: Pytree) -> Pytree:
    """One rng key per leaf, structured like `tree`."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def count_params_str(n: int) -> str:
    for unit, div in (("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if n >= div:
            return f"{n / div:.2f}{unit}"
    return str(n)


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Mixed-precision policy: params stored in `param_dtype`, compute in
    `compute_dtype`, reductions/optimizer math in `accum_dtype`."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32

    def cast_compute(self, tree: Pytree) -> Pytree:
        return tree_cast(tree, self.compute_dtype)


POLICY_F32 = DtypePolicy(jnp.float32, jnp.float32, jnp.float32)
POLICY_BF16 = DtypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32)
POLICY_MIXED = DtypePolicy(jnp.float32, jnp.bfloat16, jnp.float32)
