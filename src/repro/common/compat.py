"""Version-compatibility wrappers over fast-moving jax APIs.

The repo targets the image's pinned jax (0.4.x) but is written against the
modern spellings. Everything that moved between 0.4 and 0.5+ funnels through
here so call sites stay on the new API:

  * ``make_mesh(shape, axes)`` — ``jax.make_mesh`` grew an ``axis_types``
    kwarg (and ``jax.sharding.AxisType``) after 0.4.37; older versions build
    auto-typed meshes unconditionally, so the kwarg is simply dropped.
  * ``shard_map(f, mesh, in_specs, out_specs, axis_names)`` — the top-level
    ``jax.shard_map`` (manual axes named via ``axis_names``, everything else
    auto) lands in 0.5+. On 0.4.x we lower onto
    ``jax.experimental.shard_map.shard_map`` with the complementary ``auto``
    set and ``check_rep=False`` (rep-checking rejects auto axes there).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Mesh with every axis in Auto mode, on any supported jax version."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    """Partial-manual shard_map: `axis_names` become manual, the rest stay auto."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, axis_names=set(axis_names)
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False, auto=auto
    )
