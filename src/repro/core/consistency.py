"""Consistency statistics (paper Section 4).

A worker's mini-batch is *consistent* at step t when its own loss delta moves in
the same (descending) direction as the average training loss — i.e. its gradient
"corresponds to the true gradient" despite the parallel-update delay. Workers
whose deltas disagree with the average are the "long jump" victims of Fig. 1.

The score accumulated over a delay-tolerance window rho is:
    +1 + mag * relative-improvement    if both worker and average loss improved
     0                                 otherwise
so ranking prefers workers that improved, tie-broken by how much.
"""
from __future__ import annotations

import jax.numpy as jnp


def consistency_increment(
    worker_loss, prev_worker_loss, avg_loss, prev_avg_loss, magnitude_weight: float = 0.1
):
    """worker_loss: (c,) current per-worker mini-batch losses.
    Returns (c,) score increments in [0, 1 + magnitude_weight]."""
    d_worker = worker_loss - prev_worker_loss
    d_avg = avg_loss - prev_avg_loss
    both_improve = (d_worker < 0) & (d_avg < 0)
    rel = jnp.clip(-d_worker / (jnp.abs(prev_worker_loss) + 1e-8), 0.0, 1.0)
    return jnp.where(both_improve, 1.0 + magnitude_weight * rel, 0.0)
