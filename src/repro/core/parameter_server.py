"""Literal event-driven parameter-server simulation of the paper's algorithms.

Reproduces Figs. 2-7 and 11 faithfully at the paper's scale (logistic
regression on tabular data):

  * SGD   — Fig. 2: sequential mini-batch gradient descent.
  * SSGD  — Fig. 3/4: c workers compute gradients at the same W_t (barrier);
            the server applies the c arrivals one at a time, so arrivals 2..c
            are applied to weights that have already moved — the paper's delay.
  * ASGD  — lock-free: an event queue with random per-worker compute delays;
            each gradient is computed at the W the worker fetched and applied
            whenever it arrives (true heterogeneous staleness).
  * g-    — Fig. 7: the server tracks per-batch consistency (losses of the two
            previously applied batches vs. the verification-set average loss),
            and every rho arrivals replays the stored gradients of the <=4 most
            consistent batches: W -= eta * v(psi_i).
  * SRMSprop / SAdagrad — Fig. 11: the server-side update rule is swapped; the
            guided replay stays plain (exactly as printed in the paper).

Pure numpy; deterministic given a seed. This module is what benchmarks/
paper_tables.py drives to produce Tables 2-5 and Figs. 12-14.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np


# ----------------------------------------------------------- logistic model


class LogisticRegression:
    """Multinomial logistic regression with bias, matching the paper's Section 5
    proof-of-concept model."""

    def __init__(self, n_features: int, n_classes: int, rng: np.random.Generator):
        self.W = 0.01 * rng.standard_normal((n_features + 1, n_classes))

    @staticmethod
    def _aug(X):
        return np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)

    def logits(self, X, W=None):
        W = self.W if W is None else W
        return self._aug(X) @ W

    def loss(self, X, y, W=None):
        z = self.logits(X, W)
        z = z - z.max(axis=1, keepdims=True)
        lse = np.log(np.exp(z).sum(axis=1))
        return float(np.mean(lse - z[np.arange(len(y)), y]))

    def grad(self, X, y, W=None):
        W = self.W if W is None else W
        z = self.logits(X, W)
        z = z - z.max(axis=1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=1, keepdims=True)
        p[np.arange(len(y)), y] -= 1.0
        return self._aug(X).T @ p / len(y)

    def accuracy(self, X, y) -> float:
        return float(np.mean(self.logits(X).argmax(axis=1) == y))


# ------------------------------------------------------------------- config


@dataclasses.dataclass
class PSConfig:
    mode: str = "ssgd"            # seq | ssgd | asgd
    guided: bool = False
    optimizer: str = "sgd"        # sgd | rmsprop | adagrad (server-side rule)
    lr: float = 0.2               # paper Table 1
    epochs: int = 50              # paper Table 1
    rho: int = 10                 # paper Table 1 (delay tolerance = #workers)
    batch_size: int = 16
    max_consistent: int = 4       # paper Section 4
    verification_frac: float = 0.2  # paper Table 1 (training:validation 80:20)
    rmsprop_beta: float = 0.9     # paper Fig. 11
    eps: float = 1e-8
    seed: int = 0

    @property
    def n_workers(self) -> int:
        return 1 if self.mode == "seq" else self.rho  # paper: c = rho


# ------------------------------------------------------------------- server


class _Server:
    """Parameter server: applies gradients with the configured rule and runs
    the guided consistency tracking + replay (Fig. 7 / Fig. 11)."""

    def __init__(self, model: LogisticRegression, cfg: PSConfig, Xv, yv, rng):
        self.model = model
        self.cfg = cfg
        self.Xv, self.yv = Xv, yv
        self.rng = rng
        self.r = np.zeros_like(model.W)  # rmsprop/adagrad accumulator
        self.t = 0
        self.prev_avg_err = np.inf
        self.recent: list = []        # deque of (batch_id, grad, loss_at_apply, X, y)
        self.psi: dict = {}           # batch_id -> (score, grad)
        self.history: list = []       # (t, avg_err) for progression plots

    def _apply(self, grad):
        cfg = self.cfg
        if cfg.optimizer == "sgd":
            self.model.W -= cfg.lr * grad
        elif cfg.optimizer == "rmsprop":
            self.r = cfg.rmsprop_beta * self.r + (1 - cfg.rmsprop_beta) * grad**2
            self.model.W -= cfg.lr * grad / np.sqrt(self.r + cfg.eps)
        elif cfg.optimizer == "adagrad":
            self.r = self.r + grad**2
            self.model.W -= cfg.lr * grad / np.sqrt(self.r + cfg.eps)
        else:
            raise ValueError(cfg.optimizer)

    def receive(self, grad, batch_id, Xb, yb):
        """One arrival at the parameter server (Fig. 4 body / Fig. 7 body)."""
        cfg = self.cfg
        loss_before = self.model.loss(Xb, yb)
        self._apply(grad)
        self.t += 1

        avg_err = self.model.loss(self.Xv, self.yv)  # approximateAvgError()
        self.history.append((self.t, avg_err))
        if not cfg.guided:
            self.prev_avg_err = avg_err
            return

        # collectConsistentBatches(d_i, d_{i-1}, d_{i-2}): a batch is consistent
        # when the step that applied its gradient moved BOTH its own loss and
        # the verification-average loss downward (the gradient "corresponds to
        # the true gradient" despite the delay, Fig. 1). Ranking uses the
        # average-error drop — getMostConsistentBatches(psi, E_t) keys on E_t.
        if np.isfinite(self.prev_avg_err):
            d_avg = avg_err - self.prev_avg_err
            d_own = self.model.loss(Xb, yb) - loss_before
            if d_own < 0 and d_avg < 0:
                score = -d_avg / (abs(self.prev_avg_err) + 1e-12)
                prev = self.psi.get(batch_id)
                if prev is None or score > prev[0]:
                    self.psi[batch_id] = (score, grad)
        self.recent.append((batch_id, grad, loss_before, Xb, yb))
        self.recent = self.recent[-3:]
        self.prev_avg_err = avg_err

        # max delay tolerance reached: replay the most consistent batches
        if self.t % cfg.rho == 0:
            best = sorted(self.psi.items(), key=lambda kv: -kv[1][0])[: cfg.max_consistent]
            for _, (_, g_stored) in best:       # getMostConsistentBatches
                self.model.W -= cfg.lr * g_stored  # plain replay (Fig. 7 line 8)
            self.psi.clear()


# --------------------------------------------------------------- main loops


def _minibatches(X, y, bs, rng):
    idx = rng.permutation(len(X))
    for s in range(0, len(X) - bs + 1, bs):
        sel = idx[s : s + bs]
        yield sel, X[sel], y[sel]


def train_ps(X, y, n_classes: int, cfg: PSConfig, Xtest=None, ytest=None):
    """Run one full training per the paper's protocol. Returns dict of results."""
    rng = np.random.default_rng(cfg.seed)
    n_val = max(8, int(cfg.verification_frac * len(X)))
    vidx = rng.choice(len(X), n_val, replace=False)
    mask = np.ones(len(X), bool)
    mask[vidx] = False
    Xtr, ytr = X[mask], y[mask]
    Xv, yv = X[vidx], y[vidx]

    model = LogisticRegression(X.shape[1], n_classes, rng)
    server = _Server(model, cfg, Xv, yv, rng)
    c = cfg.n_workers

    for _epoch in range(cfg.epochs):
        batches = list(_minibatches(Xtr, ytr, cfg.batch_size, rng))
        if cfg.mode == "seq":
            for bid, (sel, Xb, yb) in enumerate(batches):
                g = model.grad(Xb, yb)
                server.receive(g, (_epoch, bid), Xb, yb)

        elif cfg.mode == "ssgd":
            # barrier rounds: c gradients at the same W, applied sequentially
            # (the final round may be partial when the dataset is small)
            for r0 in range(0, len(batches), c):
                W_snapshot = model.W.copy()
                grads = [
                    (bid, model.grad(Xb, yb, W_snapshot), Xb, yb)
                    for bid, (sel, Xb, yb) in enumerate(batches[r0 : r0 + c], start=r0)
                ]
                for bid, g, Xb, yb in grads:
                    server.receive(g, (_epoch, bid), Xb, yb)

        elif cfg.mode == "asgd":
            # event-driven lock-free simulation with random compute delays
            heap: list = []
            it = iter(enumerate(batches))
            now = 0.0
            for w in range(c):
                try:
                    bid, (sel, Xb, yb) = next(it)
                except StopIteration:
                    break
                delay = rng.exponential(1.0) + 0.1
                heapq.heappush(heap, (now + delay, w, bid, model.W.copy(), Xb, yb))
            while heap:
                t_arr, w, bid, W_fetch, Xb, yb = heapq.heappop(heap)
                g = model.grad(Xb, yb, W_fetch)   # gradient at *stale* weights
                server.receive(g, (_epoch, bid), Xb, yb)
                try:
                    nbid, (sel, nXb, nyb) = next(it)
                except StopIteration:
                    continue
                delay = rng.exponential(1.0) + 0.1
                heapq.heappush(heap, (t_arr + delay, w, nbid, model.W.copy(), nXb, nyb))
        else:
            raise ValueError(cfg.mode)

    out = {
        "train_loss": model.loss(Xtr, ytr),
        "val_loss": model.loss(Xv, yv),
        "history": server.history,
        "model": model,
    }
    if Xtest is not None:
        out["test_accuracy"] = model.accuracy(Xtest, ytest)
    return out


ALGO_NAMES = {
    ("seq", False, "sgd"): "SGD",
    ("seq", True, "sgd"): "gSGD",
    ("ssgd", False, "sgd"): "SSGD",
    ("ssgd", True, "sgd"): "gSSGD",
    ("asgd", False, "sgd"): "ASGD",
    ("asgd", True, "sgd"): "gASGD",
    ("ssgd", False, "rmsprop"): "SRMSprop",
    ("ssgd", True, "rmsprop"): "gSRMSprop",
    ("ssgd", False, "adagrad"): "SAdagrad",
    ("ssgd", True, "adagrad"): "gSAdagrad",
}


def algo_config(name: str, **kw) -> PSConfig:
    """Deprecated shim: prefer repro.engine.ExperimentSpec.for_algo(name),
    which carries the same table and also covers the mesh backend."""
    inv = {v: k for k, v in ALGO_NAMES.items()}
    mode, guided, opt = inv[name]
    return PSConfig(mode=mode, guided=guided, optimizer=opt, **kw)
