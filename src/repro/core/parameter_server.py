"""Literal event-driven parameter-server simulation of the paper's algorithms.

Reproduces Figs. 2-7 and 11 faithfully at the paper's scale (logistic
regression on tabular data):

  * SGD   — Fig. 2: sequential mini-batch gradient descent.
  * SSGD  — Fig. 3/4: c workers compute gradients at the same W_t (barrier);
            the server applies the c arrivals one at a time, so arrivals 2..c
            are applied to weights that have already moved — the paper's delay.
  * ASGD  — lock-free: an event queue with random per-worker compute delays;
            each gradient is computed at the W the worker fetched and applied
            whenever it arrives (true heterogeneous staleness).
  * g-    — Fig. 7: the server tracks per-batch consistency (losses of the two
            previously applied batches vs. the verification-set average loss),
            and every rho arrivals replays the stored gradients of the <=4 most
            consistent batches: W -= eta * v(psi_i).
  * SRMSprop / SAdagrad — Fig. 11: the server-side update rule is swapped; the
            guided replay stays plain (exactly as printed in the paper).

Pure numpy; deterministic given a seed. This loop is the PARITY REFERENCE for
the jitted scan backend (repro.engine.delaysim): `extract_schedule` below
replays its rng protocol recording a `DelaySchedule` (which batch arrives at
each server step, how stale its gradient is) instead of training, and the
scan backend reproduces the trajectory from that table to float64 round-off.
benchmarks/paper_tables.py produces Tables 2-5 / Figs. 12-14 on either
backend (`--backend scan|sim`).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np


# ----------------------------------------------------------- logistic model


class LogisticRegression:
    """Multinomial logistic regression with bias, matching the paper's Section 5
    proof-of-concept model."""

    def __init__(self, n_features: int, n_classes: int, rng: np.random.Generator):
        self.W = 0.01 * rng.standard_normal((n_features + 1, n_classes))

    @staticmethod
    def _aug(X):
        return np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)

    def logits(self, X, W=None):
        W = self.W if W is None else W
        return self._aug(X) @ W

    def loss(self, X, y, W=None):
        z = self.logits(X, W)
        z = z - z.max(axis=1, keepdims=True)
        lse = np.log(np.exp(z).sum(axis=1))
        return float(np.mean(lse - z[np.arange(len(y)), y]))

    def grad(self, X, y, W=None):
        W = self.W if W is None else W
        z = self.logits(X, W)
        z = z - z.max(axis=1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=1, keepdims=True)
        p[np.arange(len(y)), y] -= 1.0
        return self._aug(X).T @ p / len(y)

    def accuracy(self, X, y) -> float:
        return float(np.mean(self.logits(X).argmax(axis=1) == y))

    @classmethod
    def from_weights(cls, W) -> "LogisticRegression":
        """Wrap an externally trained weight matrix (e.g. the scan backend's
        final W) so callers get the same loss/accuracy methods."""
        model = object.__new__(cls)
        model.W = np.asarray(W)
        return model


# ------------------------------------------------------------------- config


@dataclasses.dataclass
class PSConfig:
    mode: str = "ssgd"            # seq | ssgd | asgd
    guided: bool = False
    optimizer: str = "sgd"        # sgd | rmsprop | adagrad (server-side rule)
    lr: float = 0.2               # paper Table 1
    epochs: int = 50              # paper Table 1
    rho: int = 10                 # paper Table 1 (delay tolerance = #workers)
    batch_size: int = 16
    max_consistent: int = 4       # paper Section 4
    verification_frac: float = 0.2  # paper Table 1 (training:validation 80:20)
    rmsprop_beta: float = 0.9     # paper Fig. 11
    eps: float = 1e-8
    seed: int = 0

    @property
    def n_workers(self) -> int:
        return 1 if self.mode == "seq" else self.rho  # paper: c = rho


# ------------------------------------------------------------------- server


class _Server:
    """Parameter server: applies gradients with the configured rule and runs
    the guided consistency tracking + replay (Fig. 7 / Fig. 11)."""

    def __init__(self, model: LogisticRegression, cfg: PSConfig, Xv, yv, rng):
        self.model = model
        self.cfg = cfg
        self.Xv, self.yv = Xv, yv
        self.rng = rng
        self.r = np.zeros_like(model.W)  # rmsprop/adagrad accumulator
        self.t = 0
        self.prev_avg_err = np.inf
        self.recent: list = []        # deque of (batch_id, grad, loss_at_apply, X, y)
        self.psi: dict = {}           # batch_id -> (score, grad)
        self.history: list = []       # (t, avg_err) for progression plots

    def _apply(self, grad):
        cfg = self.cfg
        if cfg.optimizer == "sgd":
            self.model.W -= cfg.lr * grad
        elif cfg.optimizer == "rmsprop":
            self.r = cfg.rmsprop_beta * self.r + (1 - cfg.rmsprop_beta) * grad**2
            self.model.W -= cfg.lr * grad / np.sqrt(self.r + cfg.eps)
        elif cfg.optimizer == "adagrad":
            self.r = self.r + grad**2
            self.model.W -= cfg.lr * grad / np.sqrt(self.r + cfg.eps)
        else:
            raise ValueError(cfg.optimizer)

    def receive(self, grad, batch_id, Xb, yb):
        """One arrival at the parameter server (Fig. 4 body / Fig. 7 body)."""
        cfg = self.cfg
        loss_before = self.model.loss(Xb, yb)
        self._apply(grad)
        self.t += 1

        avg_err = self.model.loss(self.Xv, self.yv)  # approximateAvgError()
        self.history.append((self.t, avg_err))
        if not cfg.guided:
            self.prev_avg_err = avg_err
            return

        # collectConsistentBatches(d_i, d_{i-1}, d_{i-2}): a batch is consistent
        # when the step that applied its gradient moved BOTH its own loss and
        # the verification-average loss downward (the gradient "corresponds to
        # the true gradient" despite the delay, Fig. 1). Ranking uses the
        # average-error drop — getMostConsistentBatches(psi, E_t) keys on E_t.
        if np.isfinite(self.prev_avg_err):
            d_avg = avg_err - self.prev_avg_err
            d_own = self.model.loss(Xb, yb) - loss_before
            if d_own < 0 and d_avg < 0:
                score = -d_avg / (abs(self.prev_avg_err) + 1e-12)
                prev = self.psi.get(batch_id)
                if prev is None or score > prev[0]:
                    self.psi[batch_id] = (score, grad)
        self.recent.append((batch_id, grad, loss_before, Xb, yb))
        self.recent = self.recent[-3:]
        self.prev_avg_err = avg_err

        # max delay tolerance reached: replay the most consistent batches
        if self.t % cfg.rho == 0:
            best = sorted(self.psi.items(), key=lambda kv: -kv[1][0])[: cfg.max_consistent]
            for _, (_, g_stored) in best:       # getMostConsistentBatches
                self.model.W -= cfg.lr * g_stored  # plain replay (Fig. 7 line 8)
            self.psi.clear()


# --------------------------------------------------------------- main loops


def _minibatches(X, y, bs, rng):
    idx = rng.permutation(len(X))
    for s in range(0, len(X) - bs + 1, bs):
        sel = idx[s : s + bs]
        yield sel, X[sel], y[sel]


def train_ps(X, y, n_classes: int, cfg: PSConfig, Xtest=None, ytest=None):
    """Run one full training per the paper's protocol. Returns dict of results."""
    rng = np.random.default_rng(cfg.seed)
    n_val = max(8, int(cfg.verification_frac * len(X)))
    vidx = rng.choice(len(X), n_val, replace=False)
    mask = np.ones(len(X), bool)
    mask[vidx] = False
    Xtr, ytr = X[mask], y[mask]
    Xv, yv = X[vidx], y[vidx]

    model = LogisticRegression(X.shape[1], n_classes, rng)
    server = _Server(model, cfg, Xv, yv, rng)
    c = cfg.n_workers

    for _epoch in range(cfg.epochs):
        batches = list(_minibatches(Xtr, ytr, cfg.batch_size, rng))
        if cfg.mode == "seq":
            for bid, (sel, Xb, yb) in enumerate(batches):
                g = model.grad(Xb, yb)
                server.receive(g, (_epoch, bid), Xb, yb)

        elif cfg.mode == "ssgd":
            # barrier rounds: c gradients at the same W, applied sequentially
            # (the final round may be partial when the dataset is small)
            for r0 in range(0, len(batches), c):
                W_snapshot = model.W.copy()
                grads = [
                    (bid, model.grad(Xb, yb, W_snapshot), Xb, yb)
                    for bid, (sel, Xb, yb) in enumerate(batches[r0 : r0 + c], start=r0)
                ]
                for bid, g, Xb, yb in grads:
                    server.receive(g, (_epoch, bid), Xb, yb)

        elif cfg.mode == "asgd":
            # event-driven lock-free simulation with random compute delays
            heap: list = []
            it = iter(enumerate(batches))
            now = 0.0
            for w in range(c):
                try:
                    bid, (sel, Xb, yb) = next(it)
                except StopIteration:
                    break
                delay = rng.exponential(1.0) + 0.1
                heapq.heappush(heap, (now + delay, w, bid, model.W.copy(), Xb, yb))
            while heap:
                t_arr, w, bid, W_fetch, Xb, yb = heapq.heappop(heap)
                g = model.grad(Xb, yb, W_fetch)   # gradient at *stale* weights
                server.receive(g, (_epoch, bid), Xb, yb)
                try:
                    nbid, (sel, nXb, nyb) = next(it)
                except StopIteration:
                    continue
                delay = rng.exponential(1.0) + 0.1
                heapq.heappush(heap, (t_arr + delay, w, nbid, model.W.copy(), nXb, nyb))
        else:
            raise ValueError(cfg.mode)

    out = {
        "train_loss": model.loss(Xtr, ytr),
        "val_loss": model.loss(Xv, yv),
        "history": server.history,
        "n_steps": server.t,  # actual server steps (authoritative throughput count)
        "model": model,
    }
    if Xtest is not None:
        out["test_accuracy"] = model.accuracy(Xtest, ytest)
    return out


# ------------------------------------------------------- schedule extraction


@dataclasses.dataclass(frozen=True)
class DelaySchedule:
    """Precomputed arrival table for one training run: what the parameter
    server sees at every step, with the delay topology factored out of the
    training loop.

    Row t describes the t-th arrival (0-based server step): the mini-batch it
    carries (`batch_rows[t]` — row indices into the training set) and the
    staleness offset `staleness[t]` = s, meaning the gradient was computed at
    W_{t-s}, the weights as they stood s server steps before the arrival was
    applied. seq is all-zeros, ssgd is the sawtooth 0..c-1 per barrier round,
    asgd comes out of the event-queue simulation with pre-sampled compute
    times (any `delay_sampler` — exponential, constant, heavy-tail, ...).

    The scan backend (repro.engine.delaysim) consumes this table with a ring
    buffer of the last `max_staleness+1` weight states; the numpy event loop
    above stays as the parity reference that defines these semantics.
    """

    batch_rows: np.ndarray   # (T, batch_size) int32, rows into the train set
    staleness: np.ndarray    # (T,) int32, s_t: gradient computed at W_{t-s_t}
    n_workers: int
    topology: str = "exp"
    worker: Optional[np.ndarray] = None  # (T,) int32, which worker delivered
                                         # arrival t (None for pre-dist tables)

    @property
    def n_steps(self) -> int:
        return len(self.staleness)

    @property
    def max_staleness(self) -> int:
        return int(self.staleness.max(initial=0))

    @property
    def fetch_version(self) -> np.ndarray:
        """(T,) server version each arrival's gradient was fetched at:
        f_t = t - s_t (the store had applied f_t updates at fetch time)."""
        return np.arange(self.n_steps, dtype=np.int64) - self.staleness


def _event_schedule(n_batches: int, c: int, rng, delay_sampler, t0: int):
    """One epoch of the ASGD event-queue simulation, gradient math elided.

    Mirrors the `mode == "asgd"` branch of train_ps arrival-for-arrival: same
    heap ordering, same rng draw order (one draw per dispatched batch, drawn
    only after the batch iterator yields). Returns (order, fetch) — the batch
    ids in arrival order and the global server step each gradient's weights
    were fetched at. `t0` is the global step count before this epoch.
    """
    heap: list = []
    it = iter(range(n_batches))
    order, fetch, whom = [], [], []
    t = t0
    for w in range(c):
        bid = next(it, None)
        if bid is None:
            break
        heapq.heappush(heap, (0.0 + delay_sampler(w, rng), w, bid, t0))
    while heap:
        t_arr, w, bid, f = heapq.heappop(heap)
        order.append(bid)
        fetch.append(f)
        whom.append(w)
        t += 1
        nbid = next(it, None)
        if nbid is not None:
            heapq.heappush(heap, (t_arr + delay_sampler(w, rng), w, nbid, t))
    return order, fetch, whom


def _exp_sampler(w: int, rng) -> float:
    """train_ps's literal compute-time draw (keep the rng call identical)."""
    return rng.exponential(1.0) + 0.1


def extract_schedule(cfg: PSConfig, n_train: int, rng, delay_sampler=None,
                     topology: str = "") -> DelaySchedule:
    """Replay train_ps's per-epoch rng protocol, recording arrivals instead of
    training: one `rng.permutation(n_train)` per epoch, then (asgd only) the
    event-queue delay draws in the loop's exact order. Call with an rng in the
    same state train_ps would have after the validation split and model init,
    and the recorded schedule reproduces the reference run arrival-for-arrival.
    """
    c = cfg.n_workers
    bs = cfg.batch_size
    delay_sampler = delay_sampler or _exp_sampler
    rows, stale, whom = [], [], []
    t = 0
    for _epoch in range(cfg.epochs):
        idx = rng.permutation(n_train)
        nb = (n_train - bs) // bs + 1 if n_train >= bs else 0
        epoch_rows = idx[: nb * bs].reshape(nb, bs)
        if cfg.mode == "seq":
            rows.extend(epoch_rows)
            stale += [0] * nb
            whom += [0] * nb
            t += nb
        elif cfg.mode == "ssgd":
            for r0 in range(0, nb, c):
                round_ = epoch_rows[r0:r0 + c]
                rows.extend(round_)
                stale += list(range(len(round_)))
                whom += list(range(len(round_)))
                t += len(round_)
        elif cfg.mode == "asgd":
            order, fetch, workers = _event_schedule(nb, c, rng, delay_sampler, t)
            rows += [epoch_rows[b] for b in order]
            stale += [t + i - f for i, f in enumerate(fetch)]
            whom += workers
            t += len(order)
        else:
            raise ValueError(cfg.mode)
    return DelaySchedule(
        batch_rows=np.asarray(rows, np.int32),
        staleness=np.asarray(stale, np.int32),
        n_workers=c,
        topology=topology or {"seq": "seq", "ssgd": "barrier"}.get(cfg.mode, "exp"),
        worker=np.asarray(whom, np.int32),
    )


def prepare_run(X, y, n_classes: int, cfg: PSConfig, delay_sampler=None,
                topology: str = ""):
    """The data-and-schedule half of train_ps: same rng protocol (validation
    split -> model init -> per-epoch permutations and delay draws), no
    training. Returns (W0, (Xtr, ytr), (Xv, yv), DelaySchedule); feeding these
    to any backend that honours DelaySchedule semantics reproduces the
    train_ps trajectory exactly."""
    rng = np.random.default_rng(cfg.seed)
    n_val = max(8, int(cfg.verification_frac * len(X)))
    vidx = rng.choice(len(X), n_val, replace=False)
    mask = np.ones(len(X), bool)
    mask[vidx] = False
    Xtr, ytr = X[mask], y[mask]
    Xv, yv = X[vidx], y[vidx]
    W0 = 0.01 * rng.standard_normal((X.shape[1] + 1, n_classes))
    schedule = extract_schedule(cfg, len(Xtr), rng, delay_sampler, topology)
    return W0, (Xtr, ytr), (Xv, yv), schedule


ALGO_NAMES = {
    ("seq", False, "sgd"): "SGD",
    ("seq", True, "sgd"): "gSGD",
    ("ssgd", False, "sgd"): "SSGD",
    ("ssgd", True, "sgd"): "gSSGD",
    ("asgd", False, "sgd"): "ASGD",
    ("asgd", True, "sgd"): "gASGD",
    ("ssgd", False, "rmsprop"): "SRMSprop",
    ("ssgd", True, "rmsprop"): "gSRMSprop",
    ("ssgd", False, "adagrad"): "SAdagrad",
    ("ssgd", True, "adagrad"): "gSAdagrad",
}


def algo_config(name: str, **kw) -> PSConfig:
    """Deprecated shim: prefer repro.engine.ExperimentSpec.for_algo(name),
    which carries the same table and also covers the mesh backend."""
    inv = {v: k for k, v in ALGO_NAMES.items()}
    mode, guided, opt = inv[name]
    return PSConfig(mode=mode, guided=guided, optimizer=opt, **kw)
