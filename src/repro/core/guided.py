"""Scalable guided delay-compensated parallel SGD (gS/ASGD) for the TPU mesh.

This is the paper's parameter-server algorithm (Fig. 7) re-derived for SPMD
data-parallel training (see DESIGN.md §3 for the mapping):

  * Each data shard of the mesh is one of the paper's `c` workers.
  * Synchronous mode (SSGD): the gradient all-reduce plays the parameter server.
  * Asynchronous mode (ASGD) is *simulated staleness*: gradients are evaluated
    at `w_stale` — a parameter copy refreshed every `staleness` steps — exactly
    the "gradient computed at W_{t-tau}, applied at W_t" variance structure the
    paper compensates.
  * DC-ASGD (Zheng et al. 2017) is the comparison baseline:
        g~ = g + lambda * g ⊙ g ⊙ (W_t - w_stale).
  * The guided correction: consistency scores (core.consistency) accumulate per
    worker over a window of `rho` steps; at window end the <=4 most consistent
    workers' gradients are re-applied. Because grad(sum_i w_i L_i) = sum_i w_i g_i,
    the replay costs ONE weighted loss term — no stored gradients, no extra
    collective ("fused" mode). "two_pass" mode performs the paper's literal
    second sequential update via lax.cond + a second backward.

All state is a pytree; everything runs inside one jitted train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.consistency import consistency_increment

MODES = ("seq", "ssgd", "asgd", "dc_asgd")


@dataclasses.dataclass(frozen=True)
class GuidedConfig:
    mode: str = "ssgd"            # seq | ssgd | asgd | dc_asgd
    guided: bool = True           # the paper's g- prefix
    rho: int = 10                 # delay tolerance / correction period (paper: 10)
    max_consistent: int = 4       # paper: replay at most 4 mini-batches
    staleness: int = 0            # asgd/dc_asgd: w_stale refresh period (0 -> rho)
    dc_lambda: float = 0.04       # DC-ASGD Taylor coefficient
    correction: str = "fused"     # fused | two_pass
    correction_scale: float = 1.0
    magnitude_weight: float = 0.1

    def __post_init__(self):
        assert self.mode in MODES, self.mode

    @property
    def needs_stale(self) -> bool:
        return self.mode in ("asgd", "dc_asgd")

    @property
    def stale_period(self) -> int:
        return self.staleness or self.rho


class GuidedState(NamedTuple):
    step: jax.Array                 # ()
    score: jax.Array                # (c,)
    prev_worker_loss: jax.Array     # (c,)
    prev_avg_loss: jax.Array        # ()
    w_stale: Any                    # params copy or () when not needed
    opt_state: Any                  # inner optimizer state
    extra: Any = ()                 # strategy-owned state (repro.engine plugins)


def guided_init(gcfg: GuidedConfig, params, opt, n_workers: int) -> GuidedState:
    return GuidedState(
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((n_workers,), jnp.float32),
        prev_worker_loss=jnp.full((n_workers,), jnp.inf, jnp.float32),
        prev_avg_loss=jnp.asarray(jnp.inf, jnp.float32),
        w_stale=jax.tree.map(jnp.copy, params) if gcfg.needs_stale else (),
        opt_state=opt.init(params),
    )


def update_scores(state: GuidedState, gcfg: GuidedConfig, worker_loss, avg_loss):
    """Accumulate this step's consistency increments (resets handled by caller
    at window end)."""
    inc = consistency_increment(
        worker_loss, state.prev_worker_loss, avg_loss, state.prev_avg_loss, gcfg.magnitude_weight
    )
    # first step: prev losses are +inf -> deltas are -inf -> "both improve";
    # suppress by masking non-finite prevs.
    finite = jnp.isfinite(state.prev_worker_loss) & jnp.isfinite(state.prev_avg_loss)
    return state.score + jnp.where(finite, inc, 0.0)


def correction_weights(score, gcfg: GuidedConfig):
    """(c,) normalized weights over the top-k most consistent workers.
    All-zero scores -> zero weights (no correction), mirroring the paper's
    'no consistent batches collected' case."""
    k = min(gcfg.max_consistent, score.shape[0])
    top_vals, top_idx = jax.lax.top_k(score, k)
    w = jnp.zeros_like(score).at[top_idx].set(top_vals)
    total = jnp.sum(w)
    return jnp.where(total > 0, w / jnp.maximum(total, 1e-9), jnp.zeros_like(w))


def is_window_end(step, gcfg: GuidedConfig):
    return jnp.equal(jnp.mod(step + 1, gcfg.rho), 0)


def compensate_dc_asgd(grads, params, w_stale, lam: float):
    """DC-ASGD delay compensation: g + lam * g*g*(W - W_stale)."""

    def one(g, p, pb):
        g32 = g.astype(jnp.float32)
        return (g32 + lam * g32 * g32 * (p.astype(jnp.float32) - pb.astype(jnp.float32))).astype(g.dtype)

    return jax.tree.map(one, grads, params, w_stale)


def refresh_stale(state: GuidedState, gcfg: GuidedConfig, params):
    """Round-robin staleness model: w_stale := params every stale_period steps."""
    if not gcfg.needs_stale:
        return ()
    refresh = jnp.equal(jnp.mod(state.step, gcfg.stale_period), 0)
    return jax.tree.map(lambda ws, p: jnp.where(refresh, p, ws), state.w_stale, params)


def advance(
    state: GuidedState,
    gcfg: GuidedConfig,
    new_opt_state,
    params,
    worker_loss,
    avg_loss,
    extra=None,
    score=None,
) -> GuidedState:
    """Post-update bookkeeping: scores, window reset, stale refresh, step.
    `score` overrides the default consistency accumulation (strategies with
    custom scoring pass their own pre-reset scores); `extra` replaces the
    strategy-owned state (None keeps it)."""
    if score is None:
        score = update_scores(state, gcfg, worker_loss, avg_loss)
    score = jnp.where(is_window_end(state.step, gcfg), jnp.zeros_like(score), score)
    return GuidedState(
        step=state.step + 1,
        score=score,
        prev_worker_loss=worker_loss,
        prev_avg_loss=avg_loss,
        w_stale=refresh_stale(state, gcfg, params),
        opt_state=new_opt_state,
        extra=state.extra if extra is None else extra,
    )
