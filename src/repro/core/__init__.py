"""The paper's primary contribution: guided delay compensation for parallel SGD.

Two implementations, one semantics:
  - core.guided: the scalable TPU-SPMD form used by the distributed trainer
    (consistency-weighted gradient combination, in-graph, O(c) extra state).
  - core.parameter_server: the literal event-driven parameter-server simulation
    (Figs. 3/4/7 of the paper) used for the faithful paper reproduction.
"""
from repro.core.consistency import consistency_increment  # noqa: F401
from repro.core.guided import (  # noqa: F401
    GuidedConfig,
    GuidedState,
    compensate_dc_asgd,
    correction_weights,
    guided_init,
    refresh_stale,
    update_scores,
)
