"""The paper's primary contribution: guided delay compensation for parallel SGD.

Two implementations, one semantics:
  - core.guided: the scalable TPU-SPMD form used by the distributed trainer
    (consistency-weighted gradient combination, in-graph, O(c) extra state).
  - core.parameter_server: the literal event-driven parameter-server simulation
    (Figs. 3/4/7 of the paper) used for the faithful paper reproduction.

The guided/consistency names re-export lazily: they live in the jax stack,
while core.parameter_server is pure numpy — importing the package (e.g. via
repro.engine's sim backend) must not pay the jax import cost.
"""

_LAZY = {
    "consistency_increment": "consistency",
    "GuidedConfig": "guided",
    "GuidedState": "guided",
    "compensate_dc_asgd": "guided",
    "correction_weights": "guided",
    "guided_init": "guided",
    "refresh_stale": "guided",
    "update_scores": "guided",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(f"repro.core.{_LAZY[name]}"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
