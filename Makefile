# Convenience targets around the tier-1 verify command (see ROADMAP.md).

PY := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast bench quickstart lint

test:            ## tier-1: full suite, fail fast
	$(PY) -m pytest -x -q

lint:            ## JAX-aware static analysis + dist protocol audits (DESIGN.md §12)
	$(PY) -m repro.analysis src/

test-fast:       ## skip the multi-minute @slow tests
	$(PY) -m pytest -x -q -m "not slow"

bench:           ## paper tables/figures + framework benchmarks (quick mode)
	$(PY) benchmarks/run.py

quickstart:
	$(PY) examples/quickstart.py
