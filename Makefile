# Convenience targets around the tier-1 verify command (see ROADMAP.md).

PY := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast bench quickstart lint locks modelcheck check chaos

test:            ## tier-1: full suite, fail fast
	$(PY) -m pytest -x -q

lint:            ## JAX-aware static analysis + lockset pass + dist protocol audits (DESIGN.md §12/§13)
	$(PY) -m repro.analysis src/

locks:           ## the repo-wide lockset/lock-order discovery table (DESIGN.md §13)
	$(PY) -m repro.analysis.locks src/ --report

modelcheck:      ## explore dist-protocol interleavings + seeded-bug selfcheck (DESIGN.md §13)
	$(PY) -m repro.analysis.modelcheck

check: lint modelcheck  ## every static/model gate CI runs, in one target

chaos:           ## seeded fault injection: every ChaosPlan must self-heal (DESIGN.md §14)
	$(PY) -m pytest -q tests/test_chaos.py tests/test_resilience.py

test-fast:       ## skip the multi-minute @slow tests
	$(PY) -m pytest -x -q -m "not slow"

bench:           ## paper tables/figures + framework benchmarks (quick mode)
	$(PY) benchmarks/run.py

quickstart:
	$(PY) examples/quickstart.py
