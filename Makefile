# Convenience targets around the tier-1 verify command (see ROADMAP.md).

PY := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python

.PHONY: test test-fast bench quickstart

test:            ## tier-1: full suite, fail fast
	$(PY) -m pytest -x -q

test-fast:       ## skip the multi-minute @slow tests
	$(PY) -m pytest -x -q -m "not slow"

bench:           ## paper tables/figures + framework benchmarks (quick mode)
	$(PY) benchmarks/run.py

quickstart:
	$(PY) examples/quickstart.py
