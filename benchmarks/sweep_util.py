"""Shared benchmark helpers."""


def end_of_sweep(backend: str = "scan") -> None:
    """Release the delay-sim jit-runner LRU at a sweep boundary: the next
    sweep's shapes differ, so its compiles can't be reused — drop them instead
    of carrying them. No-op (and jax-import-free) on the numpy sim backend."""
    if backend != "scan":
        return
    from repro.engine.delaysim import clear_runners

    clear_runners()
