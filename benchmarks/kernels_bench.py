"""Kernel micro-benchmarks + the fused whole-update regression suite.

Two parts:

  * `bench_micro()` — the legacy one-shot rows (flash attention / decode /
    selective scan / guided sgd apply), interpret vs XLA-ref. On this CPU host
    the Pallas kernels run in interpret mode, so wall-clock is NOT the TPU
    number; the derived column carries the analytic FLOPs/bytes the roofline
    uses.
  * `bench_fused()` — the CI-gated suite (BENCH_kernels.json): per optimizer
    (sgd/momentum/adam/rmsprop) and size, the PRODUCTION whole-update path
    (`fused_update_for(impl="auto")`: one dispatch — Pallas kernel on gpu/tpu,
    the XLA-fused jnp reference on cpu) against the unfused two-dispatch
    chain it replaced (dispatch 1: guided/DC compensation materializing g~;
    dispatch 2: `repro.optim` accumulator update + apply). Records wall time,
    speedup, analytic HBM bytes, achieved bytes/s, dispatch counts, and
    parity of the fused result vs the optimizers-composed reference.
    `benchmarks/kernel_gate.py` fails CI when the fused/unfused speedup
    regresses >20% against the committed baseline.

Timing: best-of-3 repeats of an averaged loop (min absorbs scheduler noise on
shared CI boxes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

#: fused-suite sizes; --small trims to the first two (the gate compares the
#: common keys only)
SIZES = (16384, 65536, 262144, 1048576)
SMALL_SIZES = (16384, 65536)

#: analytic HBM traffic of the fused kernel, in 4-byte words per element:
#: reads(w,g,ws[,acc...]) + writes(w[,acc...])
_WORDS = {"sgd": 4, "momentum": 6, "rmsprop": 6, "adam": 8}


def _time(fn, *args, iters=3, repeats=3) -> float:
    """us per call: best-of-`repeats` averaged timing loops (compile excluded)."""
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def _fused_case(optimizer: str, n: int, dtype=jnp.float32):
    """One (optimizer, size) comparison: production fused path vs the
    two-dispatch unfused chain, plus parity vs the optimizers composition."""
    from repro.kernels.guided_update.ops import fused_update_for
    from repro.optim import get_optimizer

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(n), dtype)
    g = w * 0.01
    ws = w + 0.05
    lr, lam = 0.2, 0.04
    opt = get_optimizer(optimizer)
    hy = {k: v for k, v in opt.hypers.items() if k != "weight_decay"}
    acc0 = {
        "sgd": (),
        "momentum": (jnp.abs(w) * 0.1,),
        "rmsprop": (jnp.abs(w) * 0.1,),
        "adam": (jnp.abs(w) * 0.1, jnp.abs(w) * 0.05),
    }[optimizer]
    t_step = 3

    # --- production fused path: ONE dispatch ------------------------------
    fused = fused_update_for(optimizer, impl="auto", **hy)

    @jax.jit
    def run_fused(w, g, ws, acc):
        return fused(w, g, ws, acc, t_step, lr, lam)

    # --- unfused: compensation dispatch, then optimizer-ops dispatch ------
    @jax.jit
    def compensate(w, g, ws):
        return g + lam * g * g * (w - ws)

    opt_state = {
        "sgd": (),
        "momentum": lambda: {"m": acc0[0]},
        "rmsprop": lambda: {"r": acc0[0]},
        "adam": lambda: {"m": acc0[0], "v": acc0[1],
                         "t": jnp.asarray(t_step - 1, jnp.int32)},
    }[optimizer]
    opt_state = opt_state() if callable(opt_state) else opt_state

    @jax.jit
    def apply_opt(w, gt, state):
        upd, state = opt.update(gt, state, w, lr)
        return w + upd, state

    def run_unfused(w, g, ws, state):
        gt = compensate(w, g, ws)
        return apply_opt(w, gt, state)

    iters = max(8, (1 << 22) // n)
    fused_us = _time(run_fused, w, g, ws, acc0, iters=iters, repeats=4)
    unfused_us = _time(run_unfused, w, g, ws, opt_state, iters=iters, repeats=4)

    # parity: fused result vs compensation composed with the optimizers update
    w_f, _ = run_fused(w, g, ws, acc0)
    w_u, _ = run_unfused(w, g, ws, opt_state)
    parity = float(np.max(np.abs(np.asarray(w_f, np.float64)
                                 - np.asarray(w_u, np.float64))))

    word = jnp.dtype(dtype).itemsize
    hbm = _WORDS[optimizer] * word * n
    return {
        "kernel": f"guided_{optimizer}_update",
        "optimizer": optimizer,
        "n": n,
        "dtype": jnp.dtype(dtype).name,
        "impl": fused.impl,
        "fused_us": fused_us,
        "unfused_us": unfused_us,
        "speedup": unfused_us / fused_us,
        "dispatches_fused": 1,
        "dispatches_unfused": 2,
        "hbm_bytes": hbm,
        "fused_bytes_per_s": hbm / (fused_us * 1e-6),
        "parity_max_abs_diff": parity,
    }


def _interpret_diag(n: int = 65536):
    """Interpret-mode kernel wall times (diagnostic only: pure emulation on
    cpu, the compiled-path number on gpu/tpu)."""
    from repro.kernels.guided_update import kernel as K

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = w * 0.01
    ws = w + 0.05
    acc = jnp.abs(w) * 0.1
    runs = {
        "guided_sgd_update": lambda: K.guided_sgd_update_raw(
            w, g, ws, 0.2, 0.04),
        "guided_momentum_update": lambda: K.guided_momentum_update_raw(
            w, g, ws, acc, 0.2, 0.04, 0.9),
        "guided_rmsprop_update": lambda: K.guided_rmsprop_update_raw(
            w, g, ws, acc, 0.2, 0.04, 0.9, 1e-8),
        "guided_adam_update": lambda: K.guided_adam_update_raw(
            w, g, ws, acc, acc, 3, 0.2, 0.04, 0.9, 0.999, 1e-8),
    }
    return [{"kernel": k, "n": n, "us": _time(fn, iters=1, repeats=2)}
            for k, fn in runs.items()]


def bench_fused(small: bool = False) -> dict:
    """The structured BENCH_kernels.json payload."""
    from repro.kernels import autotune, default_interpret

    sizes = SMALL_SIZES if small else SIZES
    entries = [_fused_case(opt, n)
               for opt in ("sgd", "momentum", "rmsprop", "adam")
               for n in sizes]
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "interpret": default_interpret(),
        "sizes": list(sizes),
        "autotune_cache": autotune.cache_path(),
        "entries": entries,
        "interpret_diag": _interpret_diag(),
    }


def bench_micro():
    rows = []
    rng = np.random.default_rng(0)

    # flash attention (XLA reference path at bench shape; kernel in interpret)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    B, S, H, K, dh = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    flops = 4 * B * H * S * S * dh
    rows.append(("flash_attention_interpret",
                 _time(lambda *a: flash_attention(*a, causal=True), q, k, v, repeats=1),
                 f"flops={flops:.3g}"))
    ref = jax.jit(lambda *a: attention_ref(*a, causal=True))
    rows.append(("attention_xla_ref", _time(ref, q, k, v, repeats=1), f"flops={flops:.3g}"))

    # flash decode
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import decode_ref

    S2 = 2048
    q1 = jnp.asarray(rng.standard_normal((2, 1, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((2, S2, K, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((2, S2, K, dh)), jnp.float32)
    lens = jnp.asarray([S2, S2 // 2], jnp.int32)
    dflops = 4 * 2 * H * S2 * dh
    rows.append(("flash_decode_interpret", _time(flash_decode, q1, kc, vc, lens, repeats=1),
                 f"flops={dflops:.3g}"))
    rows.append(("decode_xla_ref", _time(jax.jit(decode_ref), q1, kc, vc, lens, repeats=1),
                 f"flops={dflops:.3g}"))

    # selective scan
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref

    Bs, Ss, ed, n = 1, 64, 128, 16
    x = jnp.asarray(rng.standard_normal((Bs, Ss, ed)), jnp.float32)
    dt = jnp.abs(x) * 0.1
    A = -jnp.abs(jnp.asarray(rng.standard_normal((ed, n)), jnp.float32))
    Bc = jnp.asarray(rng.standard_normal((Bs, Ss, n)), jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((Bs, Ss, n)), jnp.float32)
    sflops = 6 * Bs * Ss * ed * n
    rows.append(("selective_scan_interpret",
                 _time(selective_scan, x, dt, A, Bc, Cc, repeats=1), f"flops={sflops:.3g}"))
    rows.append(("selective_scan_xla_ref",
                 _time(jax.jit(selective_scan_ref), x, dt, A, Bc, Cc, repeats=1),
                 f"flops={sflops:.3g}"))

    # guided update (the paper's hot spot): fused kernel vs unfused XLA chain
    from repro.kernels.guided_update.ops import guided_sgd_update
    from repro.kernels.guided_update.ref import guided_sgd_update_ref

    npar = 1 << 20
    w = jnp.asarray(rng.standard_normal(npar), jnp.float32)
    g = w * 0.01
    ws = w + 0.05
    gbytes = 4 * npar * 4  # r(w,g,ws) + w(out)
    rows.append(("guided_update_interpret",
                 _time(lambda *a: guided_sgd_update(*a, 0.2, 0.04), w, g, ws, iters=1, repeats=2),
                 f"hbm_bytes={gbytes:.3g}"))
    rows.append(("guided_update_xla_ref",
                 _time(jax.jit(lambda *a: guided_sgd_update_ref(*a, 0.2, 0.04)), w, g, ws),
                 f"hbm_bytes={gbytes:.3g}"))
    return rows


def bench_all(small: bool = False) -> dict:
    out = bench_fused(small=small)
    out["micro"] = [list(r) for r in bench_micro()]
    return out


def main():
    out = bench_all()
    for name, us, derived in out["micro"]:
        print(f"{name},{us:.1f},{derived}")
    for e in out["entries"]:
        print(f"{e['kernel']}_n{e['n']},{e['fused_us']:.1f},"
              f"speedup={e['speedup']:.2f}x;parity={e['parity_max_abs_diff']:.2g}")


if __name__ == "__main__":
    main()
