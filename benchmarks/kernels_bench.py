"""Kernel micro-benchmarks.

On this CPU host the Pallas kernels run in interpret mode, so wall-clock is
NOT the TPU number — the derived column reports the analytic FLOPs (or bytes)
per call, which is the backend-independent quantity the roofline uses. The
XLA-path equivalents (what the dry-run lowers) are timed for comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_all():
    rows = []
    rng = np.random.default_rng(0)

    # flash attention (XLA reference path at bench shape; kernel in interpret)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    B, S, H, K, dh = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    flops = 4 * B * H * S * S * dh
    rows.append(("flash_attention_interpret", _time(lambda *a: flash_attention(*a, causal=True), q, k, v),
                 f"flops={flops:.3g}"))
    ref = jax.jit(lambda *a: attention_ref(*a, causal=True))
    rows.append(("attention_xla_ref", _time(ref, q, k, v), f"flops={flops:.3g}"))

    # flash decode
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import decode_ref

    S2 = 2048
    q1 = jnp.asarray(rng.standard_normal((2, 1, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((2, S2, K, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((2, S2, K, dh)), jnp.float32)
    lens = jnp.asarray([S2, S2 // 2], jnp.int32)
    dflops = 4 * 2 * H * S2 * dh
    rows.append(("flash_decode_interpret", _time(flash_decode, q1, kc, vc, lens), f"flops={dflops:.3g}"))
    rows.append(("decode_xla_ref", _time(jax.jit(decode_ref), q1, kc, vc, lens), f"flops={dflops:.3g}"))

    # selective scan
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref

    Bs, Ss, ed, n = 1, 64, 128, 16
    x = jnp.asarray(rng.standard_normal((Bs, Ss, ed)), jnp.float32)
    dt = jnp.abs(x) * 0.1
    A = -jnp.abs(jnp.asarray(rng.standard_normal((ed, n)), jnp.float32))
    Bc = jnp.asarray(rng.standard_normal((Bs, Ss, n)), jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((Bs, Ss, n)), jnp.float32)
    sflops = 6 * Bs * Ss * ed * n
    rows.append(("selective_scan_interpret", _time(selective_scan, x, dt, A, Bc, Cc), f"flops={sflops:.3g}"))
    rows.append(("selective_scan_xla_ref", _time(jax.jit(selective_scan_ref), x, dt, A, Bc, Cc),
                 f"flops={sflops:.3g}"))

    # guided update (the paper's hot spot): fused kernel vs unfused XLA chain
    from repro.kernels.guided_update.ops import guided_sgd_update
    from repro.kernels.guided_update.ref import guided_sgd_update_ref

    npar = 1 << 20
    w = jnp.asarray(rng.standard_normal(npar), jnp.float32)
    g = w * 0.01
    ws = w + 0.05
    gbytes = 4 * npar * 4  # r(w,g,ws) + w(out)
    rows.append(("guided_update_interpret", _time(lambda *a: guided_sgd_update(*a, 0.2, 0.04), w, g, ws),
                 f"hbm_bytes={gbytes:.3g}"))
    rows.append(("guided_update_xla_ref",
                 _time(jax.jit(lambda *a: guided_sgd_update_ref(*a, 0.2, 0.04)), w, g, ws),
                 f"hbm_bytes={gbytes:.3g}"))
    return rows


def main():
    for name, us, derived in bench_all():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
