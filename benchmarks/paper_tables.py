"""Tables 2-5 of the paper: best / average classification accuracy for each
algorithm on the 9 UCI-analog datasets, with the paper's protocol (Table 1):
50 epochs, eta=0.2, rho=10, 80:20 train/test, 80:20 train/validation, N runs,
quartile-trimmed tolerance, Wilcoxon signed-rank significance (scipy is not on
the image: we implement the exact-distribution signed-rank test for small N).
"""
from __future__ import annotations

import itertools
import json
import math

import numpy as np

from repro.data import DATASETS, load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer

CANONICAL = ["SGD", "gSGD", "SSGD", "gSSGD", "ASGD", "gASGD"]
VARIANTS = ["SSGD", "gSSGD", "SRMSprop", "gSRMSprop", "SAdagrad", "gSAdagrad"]


def wilcoxon_signed_rank(a, b) -> float:
    """Two-tailed Wilcoxon signed-rank p-value (exact for n<=12, else normal)."""
    d = np.asarray(a, float) - np.asarray(b, float)
    d = d[d != 0]
    n = len(d)
    if n == 0:
        return 1.0
    ranks = np.argsort(np.argsort(np.abs(d))) + 1.0
    # average ties
    absd = np.abs(d)
    for v in np.unique(absd):
        m = absd == v
        if m.sum() > 1:
            ranks[m] = ranks[m].mean()
    w_pos = ranks[d > 0].sum()
    w_neg = ranks[d < 0].sum()
    w = min(w_pos, w_neg)
    if n <= 12:  # exact enumeration
        total = 0
        count = 0
        for signs in itertools.product([0, 1], repeat=n):
            s = sum(r for r, sg in zip(ranks, signs) if sg)
            total += 1
            if s <= w:
                count += 1
        return min(1.0, 2.0 * count / total)
    mu = n * (n + 1) / 4
    sigma = math.sqrt(n * (n + 1) * (2 * n + 1) / 24)
    z = (w - mu) / sigma
    return min(1.0, 2.0 * 0.5 * math.erfc(abs(z) / math.sqrt(2)))


def tolerance(vals) -> float:
    """Paper's tolerance: half the IQR of the sorted run accuracies."""
    q1, q3 = np.percentile(vals, [25, 75])
    return (q3 - q1) / 2


def run_dataset(name: str, algos, runs: int = 30, epochs: int = 50, rho: int = 10,
                backend: str = "scan"):
    X, y, k = load_dataset(name, seed=0)
    out = {}
    for algo in algos:
        accs = []
        for run in range(runs):
            Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
            spec = ExperimentSpec.for_algo(algo, epochs=epochs, seed=run, rho=rho,
                                           backend=backend)
            report = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
            accs.append(report.test_accuracy * 100)
        out[algo] = accs
    return out


def summarize(per_algo: dict, pairs) -> dict:
    rows = {}
    for algo, accs in per_algo.items():
        rows[algo] = {
            "best": float(np.max(accs)),
            "avg": float(np.mean(accs)),
            "tol": float(tolerance(accs)),
        }
    for a, b in pairs:
        p = wilcoxon_signed_rank(per_algo[a], per_algo[b])
        rows[b]["p_vs_" + a] = float(p)
        rows[b]["significant_vs_" + a] = bool(p <= 0.05)
    return rows


def tables(which: str = "canonical", runs: int = 30, epochs: int = 50,
           datasets=None, verbose=True, backend: str = "scan") -> dict:
    algos = CANONICAL if which == "canonical" else VARIANTS
    pairs = ([("SGD", "gSGD"), ("SSGD", "gSSGD"), ("ASGD", "gASGD")] if which == "canonical"
             else [("SSGD", "gSSGD"), ("SRMSprop", "gSRMSprop"), ("SAdagrad", "gSAdagrad")])
    results = {}
    for ds in datasets or DATASETS:
        per_algo = run_dataset(ds, algos, runs=runs, epochs=epochs, backend=backend)
        results[ds] = summarize(per_algo, pairs)
        if verbose:
            row = " ".join(f"{a}={results[ds][a]['avg']:5.1f}±{results[ds][a]['tol']:3.1f}"
                           for a in algos)
            print(f"  {ds:28s} {row}", flush=True)
        from benchmarks.sweep_util import end_of_sweep

        end_of_sweep(backend)  # next dataset's shapes can't reuse these compiles
    return results


def main(runs=30, epochs=50, out_path="results/paper_tables.json", datasets=None,
         backend="scan"):
    print(f"[paper_tables] canonical algorithms (Tables 2-3 analog, backend={backend})")
    canonical = tables("canonical", runs, epochs, datasets, backend=backend)
    print("[paper_tables] RMSprop/Adagrad variants (Tables 4-5 analog)")
    variants = tables("variants", runs, epochs, datasets, backend=backend)
    out = {"canonical": canonical, "variants": variants,
           "protocol": {"runs": runs, "epochs": epochs, "lr": 0.2, "rho": 10,
                        "backend": backend}}
    import os

    os.makedirs("results", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--datasets", default="")
    ap.add_argument("--backend", default="scan", choices=["scan", "sim"],
                    help="scan = jitted lax.scan simulator; sim = numpy reference")
    args = ap.parse_args()
    main(args.runs, args.epochs,
         datasets=args.datasets.split(",") if args.datasets else None,
         backend=args.backend)
