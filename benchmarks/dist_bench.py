"""Async parameter server (backend="dist") vs the chunked-lockstep scan sim.

Four configurations on the same pima workload, same rho/lr/seed:
  scan        — the jitted single-process delay SIMULATOR (the reference the
                dist replay mode reproduces bit-for-bit; here run as the
                throughput baseline),
  dist_async  — free-running live mode: real worker processes pushing as fast
                as they compute, staleness OBSERVED not sampled,
  dist_davg   — DaSGD-style delayed averaging: push/pull overlapped with the
                next local gradient, so observed staleness shifts right,
  dist_heal   — dist_async with worker 0 SIGKILLed mid-run: the supervisor
                (repro.resilience, DESIGN.md §14) respawns it and the run
                completes its full budget; reports RECOVERY TIME TO HEALTHY
                (death detected -> respawned worker observed alive again).

Reported per config: wall seconds, server steps/s, final val loss, and the
observed staleness histogram + mean (scan reports the SCHEDULED histogram —
that is the point of the comparison). Throughput note: the dist numbers pay
real process spawn + socket round-trips on a tiny logreg problem, so steps/s
is a floor, not a ceiling — the bench is about completing async runs with
live staleness accounting, not beating a jitted scan at microbenchmark scale.
"""
from __future__ import annotations

import numpy as np

from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer


def _hist_stats(hist: dict) -> dict:
    n = sum(hist.values())
    mean = sum(s * c for s, c in hist.items()) / max(n, 1)
    return {"hist": {int(k): int(v) for k, v in sorted(hist.items())},
            "mean": float(mean), "max": int(max(hist, default=0))}


def run(epochs: int = 6, workers: int = 2, dataset: str = "pima",
        strategy: str = "dc_asgd", lr: float = 0.01, verbose: bool = True) -> dict:
    # lr=0.01 is the stable operating point for ALL three configs: delayed
    # averaging roughly triples the observed staleness (each gradient is a
    # full merge round behind), which at lr>=0.05 diverges on pima — with or
    # without compensation. The bench compares configs, not divergence.
    X, y, k = load_dataset(dataset, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    data = (Xtr, ytr, k, Xte, yte)
    common = dict(mode="asgd", strategy=strategy, epochs=epochs,
                  batch_size=16, rho=workers, lr=lr, seed=0)

    out = {"protocol": {"dataset": dataset, "epochs": epochs,
                        "workers": workers, "strategy": strategy}}

    scan_spec = ExperimentSpec(backend="scan", **common)
    rep = Trainer.from_spec(scan_spec).fit(data)
    from repro.core.parameter_server import prepare_run

    _, _, _, sched = prepare_run(Xtr, ytr, k, scan_spec.to_schedule_config())
    s_hist = {int(s): int(c) for s, c in
              zip(*np.unique(sched.staleness, return_counts=True))}
    out["scan"] = {"wall_s": rep.wall_time_s, "steps_per_s": rep.steps_per_s,
                   "n_steps": rep.n_steps, "val_loss": rep.val_loss,
                   "staleness": _hist_stats(s_hist), "observed": False}

    for name, extra in (("dist_async", {}), ("dist_davg", {"delayed_avg": True})):
        spec = ExperimentSpec(backend="dist", dist_mode="live", workers=workers,
                              dist_timeout=120.0, **extra, **common)
        rep = Trainer.from_spec(spec).fit(data)
        out[name] = {"wall_s": rep.wall_time_s, "steps_per_s": rep.steps_per_s,
                     "n_steps": rep.n_steps, "val_loss": rep.val_loss,
                     "staleness": _hist_stats(rep.staleness_hist),
                     "observed": True, "dist": rep.dist}

    # dist_heal: the recovery-time bench — same async config with worker 0
    # SIGKILLed mid-run (half the step budget, so the respawned worker has
    # budget left to prove itself on); dist_time_scale paces compute so the
    # kill version cannot race past the monitor's poll window
    kill_at = max(1, out["scan"]["n_steps"] // 2)
    spec = ExperimentSpec(backend="dist", dist_mode="live", workers=workers,
                          dist_timeout=120.0, dist_time_scale=0.002,
                          dist_events=(("kill", 0, kill_at),), **common)
    rep = Trainer.from_spec(spec).fit(data)
    sup = rep.dist.get("supervisor", {})
    recoveries = sup.get("recoveries", [])
    out["dist_heal"] = {"wall_s": rep.wall_time_s, "n_steps": rep.n_steps,
                        "val_loss": rep.val_loss, "kill_at_version": kill_at,
                        "worker_exits": rep.dist.get("worker_exits", 0),
                        "supervisor": sup,
                        "recovery_s": recoveries[0][1] if recoveries else None}

    out["headline"] = {
        "async_vs_scan_val_loss_delta": out["dist_async"]["val_loss"] - out["scan"]["val_loss"],
        "davg_vs_scan_val_loss_delta": out["dist_davg"]["val_loss"] - out["scan"]["val_loss"],
        "async_steps_per_s": out["dist_async"]["steps_per_s"],
        "scan_steps_per_s": out["scan"]["steps_per_s"],
        "async_mean_staleness": out["dist_async"]["staleness"]["mean"],
        "davg_mean_staleness": out["dist_davg"]["staleness"]["mean"],
        "kill_recovery_s": out["dist_heal"]["recovery_s"],
    }
    if verbose:
        for name in ("scan", "dist_async", "dist_davg"):
            r = out[name]
            kind = "observed" if r["observed"] else "scheduled"
            print(f"{name:11s} steps={r['n_steps']:4d} wall={r['wall_s']:6.2f}s "
                  f"steps/s={r['steps_per_s']:8.1f} val={r['val_loss']:.4f} "
                  f"{kind} staleness mean={r['staleness']['mean']:.2f}")
        h = out["dist_heal"]
        rec = f"{h['recovery_s']:.3f}s" if h["recovery_s"] is not None else "n/a"
        print(f"{'dist_heal':11s} steps={h['n_steps']:4d} wall={h['wall_s']:6.2f}s "
              f"kill@v{h['kill_at_version']} exits={h['worker_exits']} "
              f"respawns={h['supervisor'].get('respawns', 0)} recovery={rec}")
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1, default=float))
