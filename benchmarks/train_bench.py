"""Training-throughput benchmark: chunked dispatch + prefetch vs today's loop.

The pipeline headline (ISSUE 5 / DESIGN.md §9): at small per-step compute the
mesh trainer is dispatch- and staging-bound — one Python-dispatched jit call
per step, a synchronous host->device copy and batch *generation* in front of
it. Chunked multi-step dispatch (`spec.chunk_steps=K`: K steps fused into one
jitted lax.scan) amortizes the dispatch; prefetch (`spec.prefetch=True`)
moves generation + stacking + the device_put onto a background thread. Both
are bit-exact with the per-step loop (tests/test_trainloop.py), so the sweep
below is pure throughput.

Sweep: chunk_steps in {1, 8, 32, 64} x prefetch {off, on} x two archs (a
GQA llama-style block and a dense MHA sliding-window block, both shrunk to
the dispatch-bound operating point). Reported per cell: WARM steps/s
(Report.steps_per_s — the compile/warm split keeps jit compilation out of the
steady state) and compile_time_s. Headline: warm steps/s at chunk_steps=32,
prefetch on, vs chunk_steps=1 prefetch off (today's loop) on the small arch.

Machine-readable: BENCH_train.json via `benchmarks/run.py --only train`.
"""
from __future__ import annotations

import numpy as np

# two archs at the dispatch-bound operating point: tiny widths, short
# sequences — per-step compute in the hundreds of microseconds, which is
# exactly the regime where per-step dispatch dominates (the paper's "hide the
# compensation work behind parallel execution" applied to the host side).
# yi_9b (GQA llama block) is the SMALL point the headline is measured at;
# minicpm_2b (dense MHA + sliding window) is a bit wider, showing the win
# shrink as per-step compute grows toward being the bottleneck.
TINY = (("n_layers", 1), ("d_model", 16), ("d_ff", 32), ("vocab_size", 128),
        ("n_heads", 2), ("n_kv_heads", 2))
SMALL = (("n_layers", 1), ("d_model", 16), ("d_ff", 32), ("vocab_size", 256),
         ("n_heads", 2), ("n_kv_heads", 2))
POINTS = {
    "yi_9b": dict(arch="yi_9b", overrides=TINY, seq_len=4, global_batch=2),
    "minicpm_2b": dict(arch="minicpm_2b", overrides=SMALL, seq_len=8,
                       global_batch=2),
}

CHUNKS = (1, 8, 32, 64)


def _one(arch_key: str, point: dict, chunk_steps: int, prefetch: bool,
         steps: int) -> dict:
    from repro.engine import ExperimentSpec, Trainer

    spec = ExperimentSpec(
        backend="mesh", arch=point["arch"], reduced=True,
        model_overrides=point["overrides"], mode="ssgd",
        strategy="guided_fused", rho=8, lr=5e-2, seed=0, steps=steps,
        seq_len=point["seq_len"], global_batch=point["global_batch"],
        workers=2, chunk_steps=chunk_steps, prefetch=prefetch)
    # two identical fits; report the second. Report's compile/warm split
    # already keeps the jit compile out of steps_per_s, but the FIRST fit of
    # a cell also pays process-level ramp (XLA client thread pools, allocator
    # arenas, dispatch fast-path caches) that the split cannot see — the
    # repeated fit is the steady state the sweep compares.
    Trainer.from_spec(spec).fit(keep_history=False)
    rep = Trainer.from_spec(spec).fit(keep_history=False)
    return {
        "warm_steps_per_s": rep.steps_per_s,
        "compile_time_s": rep.compile_time_s,
        "wall_time_s": rep.wall_time_s,
        "warm_steps": rep.warm_steps,
        "final_loss": rep.final_loss,
    }


def run(steps: int = 512, chunks=CHUNKS, verbose: bool = True) -> dict:
    if 1 not in chunks:
        raise ValueError(f"chunks={chunks!r} must include 1 — chunk1_sync is "
                         f"the stepwise baseline every speedup divides by")
    out = {"protocol": {"steps": steps, "chunk_steps": list(chunks),
                        "prefetch": [False, True],
                        "archs": {k: {"overrides": [list(kv) for kv in v["overrides"]],
                                      "seq_len": v["seq_len"],
                                      "global_batch": v["global_batch"]}
                                  for k, v in POINTS.items()},
                        "strategy": "guided_fused", "workers": 2},
           "per_arch": {}}
    for arch_key, point in POINTS.items():
        grid = {}
        losses = []
        for k in chunks:
            for pf in (False, True):
                cell = _one(arch_key, point, k, pf, steps)
                grid[f"chunk{k}_{'prefetch' if pf else 'sync'}"] = cell
                losses.append(cell["final_loss"])
                if verbose:
                    print(f"{arch_key:12s} chunk={k:3d} prefetch={pf!s:5s} "
                          f"{cell['warm_steps_per_s']:8.1f} steps/s warm "
                          f"(compile {cell['compile_time_s']:.2f}s)")
        base = grid["chunk1_sync"]["warm_steps_per_s"]
        for k in chunks:
            if k != 1:
                grid[f"speedup_chunk{k}_prefetch"] = (
                    grid[f"chunk{k}_prefetch"]["warm_steps_per_s"] / base)
        # identical trajectories across the whole grid (bit-exactness is
        # locked by tests; the equal final loss is the cheap cross-check)
        grid["final_loss_max_abs_diff"] = float(
            np.max(np.abs(np.asarray(losses) - losses[0])))
        out["per_arch"][arch_key] = grid
    small = out["per_arch"]["yi_9b"]
    speedups = {k: small[f"speedup_chunk{k}_prefetch"] for k in chunks if k != 1}
    out["headline"] = {
        "small_arch": "yi_9b",
        # the acceptance metric: chunk_steps >= 32 + prefetch vs today's loop
        # (None when the sweep was called without those chunk sizes)
        "speedup_chunk32_prefetch": speedups.get(32),
        "speedup_chunk64_prefetch": speedups.get(64),
        "speedup_best_chunk_prefetch": max(speedups.values()) if speedups else None,
        "baseline_steps_per_s": small["chunk1_sync"]["warm_steps_per_s"],
        "best_steps_per_s": max(
            small[f"chunk{k}_{m}"]["warm_steps_per_s"]
            for k in chunks for m in ("sync", "prefetch")),
    }
    return out


if __name__ == "__main__":
    import json

    out = run()
    with open("BENCH_train.json", "w") as f:
        json.dump(out, f, indent=1)
    h = out["headline"]
    print(f"headline: {h['speedup_chunk32_prefetch']:.2f}x (chunk32+prefetch) "
          f"/ {h['speedup_chunk64_prefetch']:.2f}x (chunk64+prefetch)")
