"""Serving benchmark: continuous batching vs the lockstep baseline on a
staggered-arrival workload (BENCH_serve.json via benchmarks/run.py --only serve).

The workload is the serving analog of the paper's delay topologies: requests
arrive staggered (exponential inter-arrival times) with heterogeneous prompt
and generation lengths. The lockstep baseline barriers every batch on its
slowest member three ways — it waits for the whole batch to *arrive*, decodes
everyone from the padded max prompt length, and keeps burning decode steps on
finished slots until the longest generation ends. The continuous engine admits
and retires requests per step, so the same workload finishes in fewer decode
steps at higher slot occupancy.

Arrival times are specified in units of the engine's *measured* warm decode
step and realized on the wall clock, so the stagger is machine-independent in
shape but both engines pay it in real seconds. Both engines are warmed on the
full workload first (jit compiles excluded from the timed run; token streams
are identical between passes, greedy sampling).
"""
from __future__ import annotations

import time

import numpy as np


def make_workload(cfg, n_requests: int, seed: int, prompt_max: int, gen_max: int,
                  mean_interarrival_steps: float):
    """Returns (requests, arrival_steps): heterogeneous prompts/gens, Poisson
    arrivals (exponential inter-arrival, in decode-step units). Generation
    lengths span 2..gen_max — the wide spread is the point: it is exactly the
    heterogeneity a barriered batch serializes on its slowest member."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs, arrivals = [], []
    t = 0.0
    for i in range(n_requests):
        L = int(rng.integers(max(2, prompt_max // 8), prompt_max + 1))
        gen = int(rng.integers(max(4, gen_max // 8), gen_max + 1))
        reqs.append(Request(rng.integers(0, cfg.vocab_size, (L,)).tolist(),
                            max_new_tokens=gen, request_id=i))
        arrivals.append(t)
        t += float(rng.exponential(mean_interarrival_steps))
    return reqs, arrivals


def _fresh(reqs):
    from repro.serve import Request

    return [Request(list(r.prompt), max_new_tokens=r.max_new_tokens,
                    request_id=r.request_id) for r in reqs]


def _run_continuous(engine, reqs, arrival_s):
    """Drive the engine under real-time staggered arrivals; returns stats with
    wall including arrival stalls (same accounting as the lockstep barrier)."""
    engine.reset_stats()
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or engine.has_work:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrival_s[i] <= now:
            engine.submit(reqs[i])
            i += 1
        if not engine.has_work:  # idle: nothing active, next arrival pending
            time.sleep(max(0.0, arrival_s[i] - (time.perf_counter() - t0)))
            continue
        engine.step()
    # charge arrival-stall idle time too (step() only accumulates busy time),
    # mirroring the lockstep baseline's batch-barrier accounting
    engine.run_wall_s = time.perf_counter() - t0
    return engine.stats()


def run(arch: str = "minicpm-2b", pool: int = 4, n_requests: int = 24,
        prompt_max: int = 16, gen_max: int = 64, mean_interarrival_steps: float = 1.0,
        seed: int = 0, verbose: bool = True) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.module import split_params
    from repro.serve import ServeEngine, lockstep_generate

    cfg = get_config(arch).reduced()
    params = split_params(T.model_init(jax.random.PRNGKey(seed), cfg))[0]
    max_len = prompt_max + gen_max
    engine = ServeEngine(params, cfg, max_batch=pool, max_len=max_len)
    reqs, arrival_steps = make_workload(cfg, n_requests, seed, prompt_max,
                                        gen_max, mean_interarrival_steps)

    # ---- warmup: run the whole workload once on both paths (compiles all
    # prefill buckets + the pooled decode), then calibrate the warm step time.
    # The cold pass is timed so the output reports the compile/warm split
    # (same contract as Report.compile_time_s): throughput numbers below are
    # all WARM, and the one-time jit cost is visible instead of averaged in.
    t_cold = time.perf_counter()
    engine.run(_fresh(reqs))
    cold_wall_s = time.perf_counter() - t_cold
    lockstep_generate(engine, _fresh(reqs))
    engine.reset_stats()
    t0 = time.perf_counter()
    warm = engine.run(_fresh(reqs))
    warm_wall_s = time.perf_counter() - t0
    step_s = warm_wall_s / max(engine.decode_steps + engine.prefill_calls, 1)
    assert len(warm) == n_requests
    warm_stats = engine.stats()
    engine.reset_stats()

    arrival_s = [a * step_s for a in arrival_steps]

    cont_comps_start = len(engine.completions)
    cont = _run_continuous(engine, _fresh(reqs), arrival_s)
    cont_tokens = [c.tokens for c in sorted(
        engine.completions[cont_comps_start:], key=lambda c: c.request_id)]

    lock_comps, lock = lockstep_generate(engine, _fresh(reqs), arrival_s=arrival_s)
    lock_tokens = [c.tokens for c in sorted(lock_comps, key=lambda c: c.request_id)]

    out = {
        "protocol": {
            "arch": arch, "pool": pool, "n_requests": n_requests,
            "prompt_max": prompt_max, "gen_max": gen_max,
            "mean_interarrival_steps": mean_interarrival_steps,
            "calibrated_step_s": step_s, "seed": seed,
            "new_tokens": cont["new_tokens"],
        },
        "continuous": cont,
        "lockstep": lock,
        "compile_warm_split": {
            "cold_wall_s": cold_wall_s,          # first pass: jit compiles
            "warm_wall_s": warm_wall_s,          # identical pass, warm jits
            "compile_time_s": max(cold_wall_s - warm_wall_s, 0.0),
            "warm_tokens_per_s": warm_stats["new_tokens"] / max(warm_wall_s, 1e-9),
        },
        "speedup_tokens_per_s": cont["tokens_per_s"] / lock["tokens_per_s"],
        "decode_step_ratio_lock_over_cont":
            lock["decode_steps"] / max(cont["decode_steps"], 1),
        # equal-length greedy rows agree by construction; heterogeneous rows
        # won't (padded shared-position decode is the baseline's flaw) — record
        # how many request streams the barriered loop corrupts
        "lockstep_divergent_streams": int(sum(
            a != b for a, b in zip(cont_tokens, lock_tokens))),
    }
    if verbose:
        print(f"continuous: {cont['new_tokens']} tok in {cont['wall_s']:.2f}s "
              f"({cont['tokens_per_s']:.1f} tok/s, {cont['decode_steps']} steps, "
              f"occupancy {cont['occupancy']:.2f})")
        print(f"lockstep:   {lock['new_tokens']} tok in {lock['wall_s']:.2f}s "
              f"({lock['tokens_per_s']:.1f} tok/s, {lock['decode_steps']} steps, "
              f"occupancy {lock['occupancy']:.2f})")
        print(f"speedup: {out['speedup_tokens_per_s']:.2f}x")
    return out


if __name__ == "__main__":
    run()
