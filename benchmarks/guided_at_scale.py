"""Beyond-paper experiment: the paper's stated future work — "apply gSSGD to
deep networks" — realized on a transformer LM with the scalable guided
optimizer (repro.core.guided), CPU-sized.

Setup: a reduced decoder LM on the synthetic Markov stream, c=8 workers whose
shards draw from DIFFERENT corpora mixtures (real per-worker loss variance),
trained with (a) plain SSGD, (b) ASGD with simulated staleness tau=rho, (c)
guided ASGD (the paper's compensation), (d) DC-ASGD (Zheng et al. 2017
baseline). Reports final train loss: delay should hurt (b vs a), the guided
correction and DC-ASGD should recover part (c, d vs b).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.guided import GuidedConfig
from repro.data import synthetic_lm_batches
from repro.optim import constant, get_optimizer
from repro.sharding.rules import LOCAL_CTX
from repro.train import steps as S

VARIANTS = {
    "SSGD": dict(mode="ssgd", guided=False),
    "gSSGD": dict(mode="ssgd", guided=True),
    "ASGD(sim)": dict(mode="asgd", guided=False),
    "gASGD(sim)": dict(mode="asgd", guided=True),
    "DC-ASGD": dict(mode="dc_asgd", guided=False),
}


def run(steps=150, c=8, batch=16, seq=64, lr=2e-2, rho=10, seed=0, arch="yi_9b", verbose=True):
    cfg = get_config(arch).reduced()
    out = {}
    for name, kw in VARIANTS.items():
        gcfg = GuidedConfig(rho=rho, **kw)
        opt = get_optimizer("sgd")
        params, _, gstate = S.make_train_state(jax.random.PRNGKey(seed), cfg, gcfg, opt, n_workers=c)
        step = jax.jit(S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(lr), n_workers=c))
        data = synthetic_lm_batches(cfg.vocab_size, seq, batch, seed=seed, n_corpora=c)
        losses = []
        for _ in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, gstate, m = step(params, gstate, b)
            losses.append(float(m["loss"]))
        tail = float(np.mean(losses[-10:]))
        out[name] = {"final_loss": tail, "curve": losses[:: max(1, steps // 40)]}
        if verbose:
            print(f"  {name:12s} final(mean@10) loss = {tail:.4f}", flush=True)
    return out


def main(steps=150):
    res = run(steps=steps)
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/guided_at_scale.json", "w") as f:
        json.dump(res, f, indent=1)
    gap = res["ASGD(sim)"]["final_loss"] - res["SSGD"]["final_loss"]
    rec = res["ASGD(sim)"]["final_loss"] - res["gASGD(sim)"]["final_loss"]
    print(f"staleness damage (ASGD-SSGD): {gap:+.4f}; guided recovery: {rec:+.4f}")
    return res


if __name__ == "__main__":
    main()
