"""Beyond-paper experiment: the paper's stated future work — "apply gSSGD to
deep networks" — realized on a transformer LM with the scalable guided
optimizer, CPU-sized, through the unified engine API.

Setup: a reduced decoder LM on the synthetic Markov stream, c=8 workers whose
shards draw from DIFFERENT corpora mixtures (real per-worker loss variance),
trained with (a) plain SSGD, (b) ASGD with simulated staleness tau=rho, (c)
guided ASGD (the paper's compensation), (d) DC-ASGD (Zheng et al. 2017
baseline), (e) Gap-Aware dampening (registry plugin). Reports final train
loss: delay should hurt (b vs a), the compensation strategies should recover
part (c-e vs b).
"""
from __future__ import annotations

import json

import numpy as np

from repro.engine import ExperimentSpec, Trainer

VARIANTS = {
    "SSGD": dict(mode="ssgd", strategy="none"),
    "gSSGD": dict(mode="ssgd", strategy="guided_fused"),
    "ASGD(sim)": dict(mode="asgd", strategy="none"),
    "gASGD(sim)": dict(mode="asgd", strategy="guided_fused"),
    "DC-ASGD": dict(mode="asgd", strategy="dc_asgd"),
    "GapAware": dict(mode="asgd", strategy="gap_aware"),
}


def run(steps=150, c=8, batch=16, seq=64, lr=2e-2, rho=10, seed=0, arch="yi_9b", verbose=True):
    out = {}
    for name, kw in VARIANTS.items():
        spec = ExperimentSpec(
            backend="mesh", arch=arch, reduced=True, rho=rho, lr=lr, seed=seed,
            steps=steps, seq_len=seq, global_batch=batch, workers=c,
            optimizer="sgd", schedule="constant", **kw)
        report = Trainer.from_spec(spec).fit()
        losses = [h["loss"] for h in report.history]
        tail = float(np.mean(losses[-10:]))
        out[name] = {"final_loss": tail, "curve": losses[:: max(1, steps // 40)]}
        if verbose:
            print(f"  {name:12s} final(mean@10) loss = {tail:.4f}", flush=True)
    return out


def main(steps=150):
    res = run(steps=steps)
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/guided_at_scale.json", "w") as f:
        json.dump(res, f, indent=1)
    gap = res["ASGD(sim)"]["final_loss"] - res["SSGD"]["final_loss"]
    rec = res["ASGD(sim)"]["final_loss"] - res["gASGD(sim)"]["final_loss"]
    print(f"staleness damage (ASGD-SSGD): {gap:+.4f}; guided recovery: {rec:+.4f}")
    return res


if __name__ == "__main__":
    main()
