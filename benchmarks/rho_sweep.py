"""Figs. 12-13 of the paper: impact of the delay tolerance rho on accuracy.

rho doubles as the worker count c (the paper sets c = rho), so this sweep is
the accuracy-vs-parallelism trade the whole paper is about: higher rho = more
parallel speedup (~rho-fold) but lower accuracy; the guided variant should
degrade more slowly.
"""
from __future__ import annotations

import json

import numpy as np

from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer

RHOS = [1, 2, 4, 10, 17, 25, 36]


def sweep(dataset: str, runs: int = 10, epochs: int = 50, guided_both=True,
          backend: str = "scan"):
    X, y, k = load_dataset(dataset, seed=0)
    out = {}
    for rho in RHOS:
        for guided in ([False, True] if guided_both else [False]):
            accs = []
            for run in range(runs):
                Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
                mode = "seq" if rho == 1 else "ssgd"
                # batch_size 4 so even the largest rho has enough mini-batches
                # per round on the small datasets (c = rho workers)
                spec = ExperimentSpec(
                    backend=backend, mode=mode,
                    strategy="guided_fused" if guided else "none",
                    rho=rho, epochs=epochs, seed=run, batch_size=4)
                report = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
                accs.append(report.test_accuracy * 100)
            key = f"rho={rho}" + ("/guided" if guided else "")
            out[key] = {"mean": float(np.mean(accs)), "std": float(np.std(accs))}
            print(f"  {dataset:26s} {key:16s} acc={out[key]['mean']:5.1f}±{out[key]['std']:3.1f}",
                  flush=True)
    return out


def main(runs=10, epochs=50, datasets=("liver_filtered", "pima"), backend="scan"):
    from benchmarks.sweep_util import end_of_sweep

    results = {}
    for ds in datasets:
        results[ds] = sweep(ds, runs, epochs, backend=backend)
        end_of_sweep(backend)
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/rho_sweep.json", "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="scan", choices=["scan", "sim"])
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=50)
    args = ap.parse_args()
    main(args.runs, args.epochs, backend=args.backend)
