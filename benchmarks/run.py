"""Benchmark harness: one entry per paper table/figure + framework benches.

Prints `name,us_per_call,derived` CSV lines per the harness contract. The
paper-accuracy benchmarks report their headline metric in `derived` (accuracy
deltas) and the wall time of the benchmark itself in us_per_call.

Quick mode (default) uses trimmed protocols so the whole suite finishes on one
CPU core; `--full` runs the paper's exact protocol (30 runs x 50 epochs).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on sys.path;
# the `from benchmarks.X import ...` imports below need the root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def bench_tables(full: bool):
    from benchmarks.paper_tables import tables

    runs, epochs = (30, 50) if full else (6, 25)
    datasets = None if full else ["new_thyroid", "pima", "cancer"]
    out, us = _timed(lambda: tables("canonical", runs, epochs, datasets, verbose=False))
    # headline: mean (gSSGD - SSGD) accuracy delta across datasets (paper: +)
    deltas = [v["gSSGD"]["avg"] - v["SSGD"]["avg"] for v in out.values()]
    seq_gap = [v["SGD"]["avg"] - v["SSGD"]["avg"] for v in out.values()]
    print(f"table2_3_canonical,{us:.0f},gSSGD-SSGD={np.mean(deltas):+.2f}pp;SGD-SSGD={np.mean(seq_gap):+.2f}pp")
    return out


def bench_variant_tables(full: bool):
    from benchmarks.paper_tables import tables

    runs, epochs = (30, 50) if full else (6, 25)
    datasets = None if full else ["new_thyroid", "pima", "cancer"]
    out, us = _timed(lambda: tables("variants", runs, epochs, datasets, verbose=False))
    d_rms = [v["gSRMSprop"]["avg"] - v["SRMSprop"]["avg"] for v in out.values()]
    d_ada = [v["gSAdagrad"]["avg"] - v["SAdagrad"]["avg"] for v in out.values()]
    print(f"table4_5_variants,{us:.0f},gSRMSprop-SRMSprop={np.mean(d_rms):+.2f}pp;gSAdagrad-SAdagrad={np.mean(d_ada):+.2f}pp")
    return out


def bench_rho_sweep(full: bool):
    from benchmarks.rho_sweep import sweep

    runs, epochs = (10, 50) if full else (4, 25)
    out, us = _timed(lambda: sweep("new_thyroid", runs, epochs))
    lo = out["rho=1"]["mean"]
    hi = out["rho=36"]["mean"]
    print(f"fig12_13_rho_sweep,{us:.0f},acc(rho=1)={lo:.1f};acc(rho=36)={hi:.1f};drop={lo-hi:+.1f}pp")
    return out


def bench_progression(full: bool):
    from benchmarks.progression import progression

    runs, epochs = (5, 50) if full else (3, 25)
    out, us = _timed(lambda: progression(runs=runs, epochs=epochs))
    end_gap = out["SSGD"]["val_error"][-1] - out["SGD"]["val_error"][-1]
    g_gain = out["SSGD"]["val_error"][-1] - out["gSSGD"]["val_error"][-1]
    print(f"fig14_progression,{us:.0f},SSGD-SGD_end_err={end_gap:+.4f};guided_recovers={g_gain:+.4f}")
    return out


def _selfgen_dryrun_records(out_dir="results/dryrun", timeout_s=900):
    """Generate --small dry-run records (reduced config, 4x2 mesh, 8 forced
    host devices) so the roofline bench has something to aggregate on a bare
    checkout. Returns an error string on failure, None on success."""
    import subprocess

    env = dict(os.environ)
    src = os.path.join(_ROOT, "src")
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--small",
           "--arch", "yi-9b", "--shape", "train_4k", "--out", out_dir,
           "--skip-existing"]
    try:
        p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=timeout_s)
    except (subprocess.TimeoutExpired, OSError) as e:
        return f"{type(e).__name__}"
    if p.returncode != 0:
        tail = (p.stdout + p.stderr).strip().splitlines()[-3:]
        return " / ".join(tail) if tail else f"exit={p.returncode}"
    return None


def bench_roofline(out_path: str = "BENCH_roofline.json"):
    """Aggregate dry-run records into the roofline table. On a bare checkout
    (no results/dryrun records) it SELF-GENERATES a --small record first —
    reduced config compiled on a 4x2 placeholder mesh — so the bench always
    reports real compiled-HLO numbers, or a nonzero-signal failure reason."""
    import json

    from benchmarks.roofline import MESHES, load_records, table

    gen_err = None
    if not any("compute_ms" in r for m in MESHES for r in table(load_records(), mesh=m)):
        t0 = time.perf_counter()
        gen_err = _selfgen_dryrun_records()
        gen_us = (time.perf_counter() - t0) * 1e6
        if gen_err is None:
            print(f"roofline_selfgen,{gen_us:.0f},generated --small dry-run records (mesh4x2)")
    recs, us = _timed(load_records)
    out = {"meshes": {}, "selfgen_error": gen_err}
    any_rows = False
    for mesh in MESHES:
        rows = [r for r in table(recs, mesh=mesh) if "compute_ms" in r]
        out["meshes"][mesh] = rows
        if not rows:
            continue
        any_rows = True
        dom = {}
        for r in rows:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        useful = np.mean([r["useful_ratio"] for r in rows])
        dom_s = ";".join(f"{k}:{v}" for k, v in sorted(dom.items()))
        print(f"roofline_{mesh},{us:.0f},combos={len(rows)};dominant={dom_s};mean_useful={useful:.2f}")
    if not any_rows:
        # nonzero-signal skip: say WHY there is nothing to aggregate
        why = f"self-generation failed: {gen_err}" if gen_err else \
            "no dry-run records and nothing self-generated"
        print(f"roofline_selfgen,0,SKIP {why}")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def bench_guided_at_scale(full: bool):
    from benchmarks.guided_at_scale import run

    out, us = _timed(lambda: run(steps=150 if full else 40, verbose=False))
    gap = out["ASGD(sim)"]["final_loss"] - out["SSGD"]["final_loss"]
    rec = out["ASGD(sim)"]["final_loss"] - out["gASGD(sim)"]["final_loss"]
    dc = out["ASGD(sim)"]["final_loss"] - out["DC-ASGD"]["final_loss"]
    ga = out["ASGD(sim)"]["final_loss"] - out["GapAware"]["final_loss"]
    print(f"beyond_guided_at_scale,{us:.0f},staleness_damage={gap:+.4f};guided_recovers={rec:+.4f};"
          f"dcasgd_recovers={dc:+.4f};gap_aware_recovers={ga:+.4f}")
    return out


def bench_kernels(small: bool = False, out_path: str = "BENCH_kernels.json"):
    """Kernel micro rows + the fused whole-update suite; the JSON artifact is
    the baseline `benchmarks/kernel_gate.py` gates CI against (20% tolerance
    on the fused/unfused speedup ratio, which travels across machines where
    absolute wall times don't)."""
    import json

    from benchmarks.kernels_bench import bench_all

    out, us = _timed(lambda: bench_all(small=small))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    for name, row_us, derived in out["micro"]:
        print(f"{name},{row_us:.1f},{derived}")
    big = [e["speedup"] for e in out["entries"] if e["n"] >= 65536]
    worst = min(big) if big else float("nan")
    par = max(e["parity_max_abs_diff"] for e in out["entries"])
    print(f"kernels_fused_vs_unfused,{us:.0f},"
          f"worst_speedup_64k+={worst:.2f}x;entries={len(out['entries'])};"
          f"max_parity={par:.2g};impl={out['entries'][0]['impl']}")
    return out


def bench_delaysim(full: bool, out_path: str = "BENCH_delaysim.json"):
    """paper_tables workload, scan backend vs the numpy reference loop.

    The canonical algorithm set at the paper's protocol on one dataset: the
    numpy event loop runs the N seeds sequentially (the only way it can); the
    scan backend runs them as ONE vmapped jit call (n_seeds=N), which is the
    execution model the backend exists for. Reports cold (includes jit
    compile) and warm (steady-state, e.g. the next dataset at equal shapes)
    wall times, steps/s and final losses per algorithm; the headline speedup
    is warm. Everything lands machine-readable in BENCH_delaysim.json.
    """
    import json

    from repro.core.parameter_server import algo_config, train_ps
    from repro.data import load_dataset, train_test_split
    from repro.engine import ExperimentSpec, Trainer

    runs, epochs, dataset = (30, 50, "pima") if full else (8, 25, "pima")
    algos = ["SGD", "gSGD", "SSGD", "gSSGD", "ASGD", "gASGD"]
    X, y, k = load_dataset(dataset, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)

    out = {"protocol": {"dataset": dataset, "runs": runs, "epochs": epochs,
                        "algos": algos}, "per_algo": {}}
    tot_np = tot_cold = tot_warm = 0.0
    for algo in algos:
        t0 = time.perf_counter()
        finals_np = []
        for run in range(runs):
            res = train_ps(Xtr, ytr, k, algo_config(algo, epochs=epochs, seed=run),
                           Xte, yte)
            finals_np.append(res["val_loss"])
        t_np = time.perf_counter() - t0

        spec = ExperimentSpec.for_algo(algo, epochs=epochs, seed=0, backend="scan",
                                       n_seeds=runs)
        rep = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
        t_cold = rep.wall_time_s
        rep = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
        t_warm = rep.wall_time_s
        finals_scan = np.asarray(rep.final["val_loss"])
        tot_np += t_np
        tot_cold += t_cold
        tot_warm += t_warm
        out["per_algo"][algo] = {
            "numpy_wall_s": t_np,
            "scan_wall_cold_s": t_cold,
            "scan_wall_warm_s": t_warm,
            "scan_steps_per_s": rep.steps_per_s,
            "numpy_steps_per_s": rep.n_steps * runs / t_np,
            "speedup_warm": t_np / t_warm,
            "final_val_loss_numpy_mean": float(np.mean(finals_np)),
            "final_val_loss_scan_mean": float(finals_scan.mean()),
            "final_val_loss_max_abs_diff": float(
                np.abs(finals_scan - np.asarray(finals_np)).max()),
        }
    out["total"] = {
        "numpy_wall_s": tot_np,
        "scan_wall_cold_s": tot_cold,
        "scan_wall_warm_s": tot_warm,
        "speedup_cold": tot_np / tot_cold,
        "speedup_warm": tot_np / tot_warm,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"delaysim_scan_vs_numpy,{tot_np * 1e6:.0f},"
          f"speedup_warm={tot_np / tot_warm:.1f}x;speedup_cold={tot_np / tot_cold:.1f}x;"
          f"algos={len(algos)};runs={runs};epochs={epochs}")
    return out


def bench_serve(full: bool, out_path: str = "BENCH_serve.json"):
    """Continuous batching vs the lockstep serve loop on a staggered-arrival
    workload (benchmarks/serve_bench.py). Headline: aggregate tok/s ratio."""
    import json

    from benchmarks.serve_bench import run

    n_req, gen_max = (48, 96) if full else (24, 64)
    out, us = _timed(lambda: run(n_requests=n_req, gen_max=gen_max, verbose=False))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    c, l = out["continuous"], out["lockstep"]
    print(f"serve_continuous_vs_lockstep,{us:.0f},"
          f"speedup={out['speedup_tokens_per_s']:.2f}x;"
          f"cont_tok_s={c['tokens_per_s']:.1f};lock_tok_s={l['tokens_per_s']:.1f};"
          f"cont_occ={c['occupancy']:.2f};lock_occ={l['occupancy']:.2f};"
          f"steps={c['decode_steps']}v{l['decode_steps']}")
    return out


def bench_train(full: bool, out_path: str = "BENCH_train.json"):
    """Chunked multi-step dispatch + double-buffered prefetch vs the per-step
    mesh loop (benchmarks/train_bench.py). Headline: warm steps/s speedup at
    chunk_steps>=32 + prefetch on the small (dispatch-bound) arch."""
    import json

    from benchmarks.train_bench import run

    steps = 2048 if full else 512
    out, us = _timed(lambda: run(steps=steps, verbose=False))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    h = out["headline"]
    print(f"train_chunked_vs_stepwise,{us:.0f},"
          f"speedup_chunk32_prefetch={h['speedup_chunk32_prefetch']:.2f}x;"
          f"speedup_chunk64_prefetch={h['speedup_chunk64_prefetch']:.2f}x;"
          f"baseline={h['baseline_steps_per_s']:.0f}steps/s;"
          f"best={h['best_steps_per_s']:.0f}steps/s;steps={steps}")
    return out


def bench_ckpt(full: bool, out_path: str = "BENCH_ckpt.json"):
    """Async checkpoint-writer overhead vs inline saves (benchmarks/ckpt_bench).
    Headline: step-time overhead per full-state snapshot, async vs sync."""
    import json

    from benchmarks.ckpt_bench import run

    steps, every = (40, 4) if full else (20, 2)
    out, us = _timed(lambda: run(steps=steps, every=every, verbose=False))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    oh = out["overhead_ms_per_ckpt"]
    m = out["mean_step_ms"]
    print(f"ckpt_async_vs_sync,{us:.0f},"
          f"overhead_per_ckpt_async={oh['async']:+.1f}ms;"
          f"overhead_per_ckpt_sync={oh['sync']:+.1f}ms;"
          f"step_none={m['none']:.1f}ms;step_async={m['async']:.1f}ms;"
          f"step_sync={m['sync']:.1f}ms")
    return out


def bench_dist(full: bool, out_path: str = "BENCH_dist.json"):
    """Real async parameter server vs the chunked-lockstep scan sim
    (benchmarks/dist_bench.py). Headline: async/delayed-avg final val loss
    deltas vs scan + observed-staleness means + the supervisor's recovery
    time-to-healthy after a mid-run worker SIGKILL. Dist steps/s pays real
    process spawn + socket RTTs at toy scale — a floor, not a ceiling."""
    import json

    from benchmarks.dist_bench import run

    epochs = 12 if full else 6
    out, us = _timed(lambda: run(epochs=epochs, verbose=False))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1, default=float)
    h = out["headline"]
    rec = h.get("kill_recovery_s")
    print(f"dist_async_vs_scan,{us:.0f},"
          f"async_dloss={h['async_vs_scan_val_loss_delta']:+.4f};"
          f"davg_dloss={h['davg_vs_scan_val_loss_delta']:+.4f};"
          f"async_steps_s={h['async_steps_per_s']:.1f};"
          f"scan_steps_s={h['scan_steps_per_s']:.1f};"
          f"async_stale={h['async_mean_staleness']:.2f};"
          f"davg_stale={h['davg_mean_staleness']:.2f};"
          f"kill_recovery_s={rec if rec is None else format(rec, '.3f')}")
    return out


def _clear_jit_runners():
    """Release the delay-sim jit-runner cache between benchmarks so one
    workload's compiles don't stay pinned through the next."""
    from benchmarks.sweep_util import end_of_sweep

    end_of_sweep("scan")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper protocol (30x50)")
    ap.add_argument("--small", action="store_true",
                    help="CI mode: trim the kernel fused suite to the sizes "
                         "the perf gate compares")
    ap.add_argument("--only", default="",
                    help="comma list: tables,variants,rho,progression,roofline,"
                         "kernels,scale,delaysim,serve,ckpt,train,dist")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("tables"):
        bench_tables(args.full)
        _clear_jit_runners()
    if want("variants"):
        bench_variant_tables(args.full)
        _clear_jit_runners()
    if want("rho"):
        bench_rho_sweep(args.full)
        _clear_jit_runners()
    if want("progression"):
        bench_progression(args.full)
        _clear_jit_runners()
    if want("roofline"):
        bench_roofline()
    if want("scale"):
        bench_guided_at_scale(args.full)
    if want("kernels"):
        bench_kernels(small=args.small)
    if want("delaysim"):
        bench_delaysim(args.full)
        _clear_jit_runners()
    if want("serve"):
        bench_serve(args.full)
    if want("ckpt"):
        bench_ckpt(args.full)
    if want("train"):
        bench_train(args.full)
    if want("dist"):
        bench_dist(args.full)


if __name__ == "__main__":
    main()
