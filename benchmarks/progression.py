"""Fig. 14 of the paper: validation-error progression over training for all
sequential and parallel algorithms (new-thyroid). The parallel algorithms
should converge visibly slower per arrival (the O(1/(cT)) undertraining term)
and the guided variants should close part of that gap."""
from __future__ import annotations

import json

import numpy as np

from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer

ALGOS = ["SGD", "gSGD", "SSGD", "gSSGD", "ASGD", "gASGD"]


def progression(dataset="new_thyroid", runs: int = 5, epochs: int = 50, points: int = 40,
                backend: str = "scan"):
    X, y, k = load_dataset(dataset, seed=0)
    out = {}
    for algo in ALGOS:
        curves = []
        for run in range(runs):
            Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
            spec = ExperimentSpec.for_algo(algo, epochs=epochs, seed=run, backend=backend)
            report = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
            t = np.array([h[0] for h in report.history], float)
            e = np.array([h[1] for h in report.history], float)
            # resample onto a common grid of `points` fractions of training
            grid = np.linspace(t[0], t[-1], points)
            curves.append(np.interp(grid, t, e))
        mean = np.mean(curves, axis=0)
        out[algo] = {"val_error": [float(v) for v in mean]}
        print(f"  {algo:10s} start={mean[0]:.3f} mid={mean[len(mean)//2]:.3f} "
              f"end={mean[-1]:.3f}", flush=True)
    return out


def main(runs=5, epochs=50, backend="scan"):
    results = progression(runs=runs, epochs=epochs, backend=backend)
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/progression.json", "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="scan", choices=["scan", "sim"])
    ap.add_argument("--runs", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=50)
    args = ap.parse_args()
    main(args.runs, args.epochs, backend=args.backend)
