"""Ablation of the paper's max_consistent = 4 design choice (Section 4: "the
size of the most consistent mini-batches is generally not more than 4 to keep
the algorithm efficient"). Sweeps the replay budget k for gSSGD."""
from __future__ import annotations

import json

import numpy as np

from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, Trainer

KS = [0, 1, 2, 4, 8, 10]


def sweep(dataset="pima", runs=10, epochs=50, backend="scan"):
    X, y, kcls = load_dataset(dataset, seed=0)
    out = {}
    for k in KS:
        accs = []
        for run in range(runs):
            Xtr, ytr, Xte, yte = train_test_split(X, y, seed=run)
            spec = ExperimentSpec(
                backend=backend, mode="ssgd",
                strategy="guided_fused" if k > 0 else "none",
                rho=10, epochs=epochs, seed=run, max_consistent=max(k, 1))
            report = Trainer.from_spec(spec).fit((Xtr, ytr, kcls, Xte, yte))
            accs.append(report.test_accuracy * 100)
        out[f"k={k}"] = {"mean": float(np.mean(accs)), "std": float(np.std(accs))}
        print(f"  {dataset:16s} k={k:2d} acc={out[f'k={k}']['mean']:5.1f}±{out[f'k={k}']['std']:3.1f}",
              flush=True)
    return out


def main(runs=10, epochs=50, backend="scan"):
    from benchmarks.sweep_util import end_of_sweep

    results = {}
    for ds in ("pima", "liver_filtered"):
        results[ds] = sweep(ds, runs, epochs, backend=backend)
        end_of_sweep(backend)
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/k_ablation.json", "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="scan", choices=["scan", "sim"])
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=50)
    args = ap.parse_args()
    main(args.runs, args.epochs, backend=args.backend)
