"""Consolidate dry-run records into the §Roofline table.

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and emits the
per-(arch x shape x mesh) roofline terms: compute/memory/collective seconds,
dominant term, MODEL_FLOPS ratio, and per-device memory. Run the dry-runs
first; this tool only aggregates."""
from __future__ import annotations

import glob
import json
import os


def load_records(dirname="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs=None, mesh="pod16x16", rules="default", baseline_only=True):
    recs = recs or load_records()
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or r.get("rules", "default") != rules:
            continue
        if baseline_only and (
            r.get("moe_impl", "gather") != "gather"
            or r.get("micro_override", 0)
            or r.get("attn_impl", "xla") not in ("", "xla")
        ):
            continue
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "skip": r["note"]})
            continue
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "error": r.get("error", "?")[:80]})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mem_gib": r["full_step"]["memory"]["peak_estimate_bytes"] / 2**30,
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"].replace("_s", ""),
            "useful_ratio": t["useful_ratio"],
        })
    return rows


def print_table(rows):
    hdr = f"{'arch':26s} {'shape':12s} {'mem GiB':>8s} {'comp ms':>9s} {'mem ms':>9s} {'coll ms':>9s} {'dom':>10s} {'useful':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "skip" in r:
            print(f"{r['arch']:26s} {r['shape']:12s} SKIP: {r['skip'][:60]}")
        elif "error" in r:
            print(f"{r['arch']:26s} {r['shape']:12s} FAIL: {r['error']}")
        else:
            print(f"{r['arch']:26s} {r['shape']:12s} {r['mem_gib']:8.2f} {r['compute_ms']:9.2f} "
                  f"{r['memory_ms']:9.2f} {r['collective_ms']:9.2f} {r['dominant']:>10s} "
                  f"{r['useful_ratio']:7.2f}")


MESHES = ("pod16x16", "pod2x16x16", "mesh4x2")  # mesh4x2: --small self-gen runs


def main():
    recs = load_records()
    for mesh in MESHES:
        rows = table(recs, mesh=mesh)
        if rows:
            print(f"\n=== roofline: {mesh} (default rules) ===")
            print_table(rows)
    return table(recs)


if __name__ == "__main__":
    main()
