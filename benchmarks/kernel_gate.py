"""CI kernel perf gate: fail loudly when a fused kernel regresses vs baseline.

Compares a freshly measured BENCH_kernels.json against the committed baseline
on the fused/unfused SPEEDUP ratio per (kernel, dtype, n) — a ratio of two
wall times on the same box, so it travels across machines where absolute
microseconds don't. A kernel regresses when its fresh speedup falls more than
`--tol` (default 20%) below the baseline's. Parity is gated absolutely:
1e-6 for float32 entries, 1e-12 for float64 (the repo's acceptance bars).

Only keys present in BOTH files are compared (CI runs the --small size set;
the committed baseline carries the full sweep), so trimming sizes in CI never
trips the gate. Speedup is gated only at n >= --min-n (default 64k): below
that the update is dispatch-overhead-bound and the ratio too noisy for a 20%
gate on shared runners — parity is still checked at every size. Exit 0 =
pass, 1 = regression/parity failure, 2 = unusable inputs (missing file, no
common keys) — also a failure, loudly.

Usage:
    python benchmarks/kernel_gate.py --baseline BENCH_kernels.json \
        --fresh /tmp/fresh.json [--tol 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys

PARITY_BAR = {"float32": 1e-6, "float64": 1e-12}


def _index(doc: dict) -> dict:
    return {(e["kernel"], e["dtype"], e["n"]): e for e in doc.get("entries", [])}


def gate(baseline: dict, fresh: dict, tol: float = 0.2,
         min_n: int = 65536) -> list[str]:
    """Returns a list of human-readable failures (empty = pass)."""
    base = _index(baseline)
    new = _index(fresh)
    common = sorted(set(base) & set(new))
    if not common:
        return ["no common (kernel, dtype, n) keys between baseline and fresh "
                f"(baseline has {len(base)}, fresh has {len(new)})"]
    failures = []
    for key in common:
        b, f = base[key], new[key]
        kernel, dtype, n = key
        floor = b["speedup"] * (1.0 - tol)
        if n >= min_n and f["speedup"] < floor:
            failures.append(
                f"{kernel} n={n} {dtype}: speedup {f['speedup']:.2f}x < "
                f"{floor:.2f}x (baseline {b['speedup']:.2f}x - {tol:.0%})")
        bar = PARITY_BAR.get(dtype)
        if bar is not None and f["parity_max_abs_diff"] > bar:
            failures.append(
                f"{kernel} n={n} {dtype}: parity {f['parity_max_abs_diff']:.3g}"
                f" > {bar:g} vs optimizers reference")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_kernels.json",
                    help="committed baseline JSON")
    ap.add_argument("--fresh", required=True, help="freshly measured JSON")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional speedup regression (default 0.2)")
    ap.add_argument("--min-n", type=int, default=65536,
                    help="gate speedup only at sizes >= this (default 64k; "
                         "smaller sizes are dispatch-bound and noisy)")
    args = ap.parse_args(argv)

    docs = {}
    for label, path in (("baseline", args.baseline), ("fresh", args.fresh)):
        try:
            with open(path) as fh:
                docs[label] = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"KERNEL GATE ERROR: cannot read {label} {path!r}: {e}",
                  file=sys.stderr)
            return 2

    failures = gate(docs["baseline"], docs["fresh"], tol=args.tol,
                    min_n=args.min_n)
    n_keys = len(set(_index(docs["baseline"])) & set(_index(docs["fresh"])))
    if failures:
        print(f"KERNEL PERF GATE: FAIL ({len(failures)} regression(s) across "
              f"{n_keys} compared entries, tol={args.tol:.0%})", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1 if n_keys else 2
    print(f"KERNEL PERF GATE: PASS ({n_keys} entries within {args.tol:.0%} "
          f"of baseline speedup; parity within bars)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
