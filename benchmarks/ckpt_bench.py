"""Checkpoint-writer overhead: is the async snapshot path actually off the
hot path?

One jitted mesh train step (engine.mesh, guided_fused), three loops over the
same batches with identical full-state snapshots every `every` steps:

  * none  — no checkpointing (the floor);
  * async — AsyncCheckpointer (device->host copy on the step boundary,
            npz serialization + manifest + retention on the writer thread);
  * sync  — save_train_state inline (the blocking baseline async replaces).

Headline: mean step-time overhead vs the floor per checkpointed step; the
acceptance bar is async << sync. First `warmup` steps (jit compile) dropped.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np


def _build(steps: int):
    import jax
    import jax.numpy as jnp

    from repro.engine import ExperimentSpec, Trainer
    from repro.engine import mesh as M
    from repro.optim import for_run, get_optimizer

    spec = ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode="ssgd",
        strategy="guided_fused", rho=4, lr=5e-2, seed=0, steps=steps,
        seq_len=32, global_batch=8, workers=2)
    cfg = spec.model_config()
    gcfg = spec.to_guided_config()
    opt = get_optimizer(spec.optimizer)
    ctx = M.build_ctx("local")
    strategy = Trainer.from_spec(spec).strategy
    lr = for_run(spec.schedule, spec.lr, spec.warmup, steps)
    step_fn = jax.jit(
        M.build_train_step(cfg, gcfg, opt, ctx, lr, n_workers=2, strategy=strategy),
        donate_argnums=(0, 1))

    def init():
        params, _, gstate = M.init_train_state(jax.random.PRNGKey(0), cfg, gcfg,
                                               opt, n_workers=2, strategy=strategy)
        return params, gstate

    from repro.data import synthetic_lm_batches

    gen = synthetic_lm_batches(cfg.vocab_size, spec.seq_len, spec.global_batch,
                               seed=0, n_corpora=2)
    batches = [{k: jnp.asarray(v) for k, v in next(gen).items()}
               for _ in range(steps)]
    return spec, step_fn, init, batches


def _loop(step_fn, init, batches, save_hook=None, warmup: int = 2):
    """Times each step; save_hook(done, params, gstate) runs ON the hot path
    (exactly where the trainer snapshots), so its cost lands in the step time.
    Returns (warm_times, compile_time_s): the first step — dominated by the
    jit compile — is reported separately instead of averaged in (the same
    compile/warm split Report.compile_time_s makes)."""
    params, gstate = init()
    times = []
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        params, gstate, m = step_fn(params, gstate, batch)
        float(m["loss"])  # host sync: the step really finished
        if save_hook is not None:
            save_hook(i + 1, params, gstate)
        times.append(time.perf_counter() - t0)
    return np.asarray(times[warmup:]), float(times[0])


def run(steps: int = 20, every: int = 2, verbose: bool = True) -> dict:
    from repro import checkpoint as C

    spec, step_fn, init, batches = _build(steps)
    root = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        t_none, compile_s = _loop(step_fn, init, batches)

        d = os.path.join(root, "async")
        ck = C.AsyncCheckpointer(d, keep_last=2, meta=C.spec_meta(spec))

        def async_save(done, params, gstate):
            if done % every == 0:
                ck.save(done, C.snapshot(params, gstate, done))

        t_async, _ = _loop(step_fn, init, batches, async_save)
        ck.close()

        d2 = os.path.join(root, "sync")

        def sync_save(done, params, gstate):
            if done % every == 0:
                C.save_train_state(d2, done, C.snapshot(params, gstate, done),
                                   meta=C.spec_meta(spec), keep_last=2)

        t_sync, _ = _loop(step_fn, init, batches, sync_save)

        n_ckpts = max(1, sum(1 for s in range(3, steps + 1) if s % every == 0))
        out = {
            "protocol": {"steps": steps, "ckpt_every": every,
                         "arch": "yi_9b(reduced)", "measured_steps": len(t_none),
                         "snapshot": "full TrainState (params+gstate+cursor)"},
            "mean_step_ms": {k: float(t.mean() * 1e3)
                             for k, t in (("none", t_none), ("async", t_async),
                                          ("sync", t_sync))},
            "p90_step_ms": {k: float(np.percentile(t, 90) * 1e3)
                            for k, t in (("none", t_none), ("async", t_async),
                                         ("sync", t_sync))},
            "overhead_ms_per_ckpt": {
                "async": float((t_async.sum() - t_none.sum()) * 1e3 / n_ckpts),
                "sync": float((t_sync.sum() - t_none.sum()) * 1e3 / n_ckpts),
            },
            # the compile/warm split: step_ms above is already warm (the jit
            # compile of the shared step_fn happens once, in the first "none"
            # step); the one-time cost is reported, not averaged in
            "compile_time_s": compile_s,
            "warm_steps_per_s": {k: float(1.0 / t.mean())
                                 for k, t in (("none", t_none),
                                              ("async", t_async),
                                              ("sync", t_sync))},
        }
        a = out["overhead_ms_per_ckpt"]["async"]
        s = out["overhead_ms_per_ckpt"]["sync"]
        out["async_vs_sync_overhead_ratio"] = float(a / s) if s > 0 else 0.0
        if verbose:
            m = out["mean_step_ms"]
            print(f"step ms: none={m['none']:.1f} async={m['async']:.1f} "
                  f"sync={m['sync']:.1f}; overhead/ckpt: async={a:+.1f}ms "
                  f"sync={s:+.1f}ms")
        return out
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    run()
