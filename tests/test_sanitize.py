"""repro.analysis.sanitize tests (DESIGN.md §13): the runtime race sanitizer
catches a seeded unlocked shared write, a lock-order inversion, and a thread
exiting with a lock held; stays quiet on disciplined code (including RLock
reentrancy and the repo's real concurrent classes under load); and installs/
uninstalls without leaving the instrumented modules patched.
"""
import threading
import time

import pytest

from repro.analysis import sanitize
from repro.analysis.sanitize import (
    _Facade,
    _Registry,
    instrument_class,
    uninstrument_class,
)


@pytest.fixture()
def tsan():
    """A fresh global registry per test; uninstalls and restores after."""
    sanitize.reset()
    sanitize.install()
    yield sanitize
    sanitize.uninstall()
    sanitize.reset()


def _join(*threads):
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
        assert not t.is_alive()


# ----------------------------------------------------------- seeded defects


class _Racy:
    """Two threads bump `count` with no lock: a textbook Eraser hit."""

    def __init__(self):
        self.count = 0

    def bump(self, n):
        for _ in range(n):
            self.count += 1


def test_unlocked_shared_write_is_reported(tsan):
    instrument_class(_Racy)
    try:
        obj = _Racy()
        _join(threading.Thread(target=obj.bump, args=(200,)),
              threading.Thread(target=obj.bump, args=(200,)))
    finally:
        uninstrument_class(_Racy)
    hits = [r for r in tsan.report() if "unlocked-shared-write" in r]
    assert hits and "_Racy.count" in hits[0]
    sanitize.reset()  # consumed: don't fail the fixture teardown


def test_locked_shared_write_is_clean(tsan):
    class _Locked:
        def __init__(self, facade):
            self.mu = facade.Lock()
            self.count = 0

        def bump(self, n):
            for _ in range(n):
                with self.mu:
                    self.count += 1

    instrument_class(_Locked)
    try:
        obj = _Locked(_Facade(sanitize._registry))
        _join(threading.Thread(target=obj.bump, args=(200,)),
              threading.Thread(target=obj.bump, args=(200,)))
    finally:
        uninstrument_class(_Locked)
    assert not [r for r in tsan.report() if "unlocked-shared-write" in r]


def test_lock_order_inversion_is_reported():
    reg = _Registry()
    facade = _Facade(reg)
    a = facade.Lock()
    b = facade.RLock()  # distinct creation lines -> distinct node names

    def ab():
        with a:
            with b:
                pass

    def ba():
        with b:
            with a:
                pass

    # sequential is enough: the inversion is in the order *table*, not a
    # timing accident — exactly why the check beats stress testing
    ab()
    ba()
    hits = [r for r in reg.report() if "lock-order-inversion" in r]
    assert len(hits) == 1
    assert "Lock@" in hits[0] and "RLock@" in hits[0]


def test_consistent_order_and_reentrancy_are_clean():
    reg = _Registry()
    facade = _Facade(reg)
    a, b = facade.Lock(), facade.RLock()
    for _ in range(3):
        with a:
            with b:
                with b:  # RLock re-acquire: no self-edge, no report
                    pass
    assert reg.report() == []


def test_thread_exit_holding_lock_is_reported():
    reg = _Registry()
    facade = _Facade(reg)
    mu = facade.Lock()

    def leaky():
        mu.acquire()  # never released

    t = facade.Thread(target=leaky, name="leaky")
    t.start()
    t.join(timeout=10.0)
    hits = [r for r in reg.report() if "thread-exit-holding-lock" in r]
    assert hits and "leaky" in hits[0]
    mu._inner.release()  # free the real lock for GC hygiene


# ------------------------------------------------------- real classes, clean


def test_prefetcher_is_clean_under_tsan(tsan):
    from repro.data.prefetch import ChunkPrefetcher

    for _ in range(3):
        with ChunkPrefetcher(iter(range(50)), put=lambda x: x) as pf:
            assert list(pf) == list(range(50))
    assert tsan.report() == []


def test_checkpointer_is_clean_under_tsan(tsan, tmp_path):
    import numpy as np

    from repro.checkpoint.writer import AsyncCheckpointer

    ckpt = AsyncCheckpointer(str(tmp_path), keep_last=2)
    for step in range(6):
        ckpt.save(step, {"w": np.full((4,), step, np.float32)})
    ckpt.close()
    assert tsan.report() == []


def test_dist_store_is_clean_under_tsan(tsan):
    """A real live-mode ParameterStore driven by two pushing threads: every
    shared write goes through `cond`, so the sanitizer stays silent."""
    import numpy as np

    from repro.core.parameter_server import prepare_run
    from repro.dist.store import ParameterStore
    from repro.engine import ExperimentSpec
    from repro.engine.strategies import get_compensator

    rng = np.random.default_rng(0)
    X = rng.standard_normal((60, 4))
    y = (X @ rng.standard_normal((4,)) > 0).astype(np.int64)
    spec = ExperimentSpec(backend="dist", mode="asgd", strategy="guided_fused",
                          epochs=1, batch_size=16, rho=2, lr=0.2, seed=0)
    W0, train, val, _sched = prepare_run(X, y, 2, spec.to_schedule_config())
    strategy = get_compensator(spec.strategy, spec.to_guided_config())
    store = ParameterStore(spec, strategy, W0, train, val, total_steps=12)

    def worker(wid):
        out = store.live_step(wid, None, 0, None, None)
        while out is not None:
            W, v = out
            g = 0.01 * np.ones_like(np.asarray(W))
            out = store.live_step(wid, g, v, np.arange(8),
                                  np.asarray(W).copy())

    _join(threading.Thread(target=worker, args=(0,)),
          threading.Thread(target=worker, args=(1,)))
    assert store.version == 12
    assert len(store.staleness) == 12
    assert tsan.report() == []


# ------------------------------------------------------ install / uninstall


def test_install_is_idempotent_and_reversible():
    import repro.data.prefetch as P

    orig = P.threading
    sanitize.install()
    try:
        sanitize.install()  # second call: no double-patch
        assert isinstance(P.threading, _Facade)
    finally:
        sanitize.uninstall()
        sanitize.reset()
    assert P.threading is orig
    from repro.data.prefetch import ChunkPrefetcher
    assert not getattr(ChunkPrefetcher, "_tsan_instrumented_", False)


def test_enabled_reads_the_env(monkeypatch):
    monkeypatch.delenv("REPRO_TSAN", raising=False)
    assert not sanitize.enabled()
    monkeypatch.setenv("REPRO_TSAN", "1")
    assert sanitize.enabled()
