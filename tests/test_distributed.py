"""Distributed-semantics tests on 8 virtual CPU devices (subprocess, because
XLA device count is locked at first jax init in the main test process).

Verifies the numerics that the 512-device dry-run only type-checks:
  * MoE gather vs all-to-all dispatch vs single-device reference agree;
  * sequence-sharded flash-decode == single-device decode attention;
  * the distributed guided train step matches the single-device train step
    (same c workers, same data -> same losses).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"  # skip the 60s+ TPU-probe stall
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import moe as MOE
    from repro.models import transformer as T
    from repro.models.module import split_params
    from repro.sharding.rules import ShardCtx, DEFAULT_RULES, LOCAL_CTX

    from repro.common.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))

    # ---------------- MoE: local vs gather vs all-to-all ----------------
    cfg = get_config("qwen3_moe_235b_a22b").reduced()  # 4 experts top-2
    key = jax.random.PRNGKey(0)
    params, _ = split_params(MOE.moe_init(key, cfg))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)

    # capacity_factor = n_experts -> C clips at N: no token ever drops, so the
    # local reference and the per-shard dispatch see identical routing.
    CF = float(cfg.moe.n_experts)
    y_ref, aux_ref = MOE.moe_apply(params, x, cfg, LOCAL_CTX, capacity_factor=CF)

    for impl in ("gather", "alltoall"):
        ctx = ShardCtx(mesh=mesh, rules=DEFAULT_RULES, moe_impl=impl)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y, aux = jax.jit(lambda p, xv: MOE.moe_apply(p, xv, cfg, ctx, capacity_factor=CF))(params, xs)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
        # aux: per-shard load-balance estimator (mean of shard-local E*f_e*P_e)
        # differs from the global product by O(inter-shard routing variance)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=5e-2)
        print(f"moe {impl} OK")

    # ------------- sequence-sharded flash decode vs local ---------------
    cfg2 = get_config("yi_9b").reduced()
    B, S_c = 8, 64
    K, dh = cfg2.n_kv_heads, cfg2.d_head
    H = cfg2.n_heads
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, dh), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(3), (B, S_c, K, dh), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(4), (B, S_c, K, dh), jnp.float32)
    clen = jnp.asarray(np.random.default_rng(0).integers(1, S_c + 1, (B,)), jnp.int32)

    from repro.models import layers as L
    ref = L.decode_attention(q, kc, vc, clen, n_kv_heads=K)
    ctx2 = ShardCtx(mesh=mesh, rules=DEFAULT_RULES)
    kc_s = jax.device_put(kc, NamedSharding(mesh, P(None, "model", None, None)))
    vc_s = jax.device_put(vc, NamedSharding(mesh, P(None, "model", None, None)))
    out = jax.jit(lambda *a: T.sharded_decode_attention(*a, cfg2, ctx2))(q, kc_s, vc_s, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)
    print("sharded decode OK")

    # --------- distributed guided train step == local train step --------
    from repro.core.guided import GuidedConfig
    from repro.optim import constant, get_optimizer
    from repro.train import steps as STEPS
    from repro.data import make_batch_for

    cfg3 = get_config("yi_9b").reduced()
    cfg3 = cfg3.replace(param_dtype="float32", compute_dtype="float32")
    gcfg = GuidedConfig(mode="ssgd", guided=True, rho=2)
    opt = get_optimizer("sgd")
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg3, 16, 8, seed=0).items()}

    losses = {}
    for name, ctx3 in (("local", LOCAL_CTX), ("mesh", ShardCtx(mesh=mesh, rules=DEFAULT_RULES))):
        p3, _, g3 = STEPS.make_train_state(jax.random.PRNGKey(0), cfg3, gcfg, opt, n_workers=4)
        step = jax.jit(STEPS.build_train_step(cfg3, gcfg, opt, ctx3, constant(1e-2), n_workers=4))
        ls = []
        for _ in range(4):
            p3, g3, m = step(p3, g3, batch)
            ls.append(float(m["loss"]))
        losses[name] = ls
    np.testing.assert_allclose(losses["local"], losses["mesh"], rtol=2e-4, atol=2e-4)
    print("distributed train step OK")
    """
)


@pytest.mark.slow
def test_distributed_semantics():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "moe gather OK" in out.stdout
    assert "moe alltoall OK" in out.stdout
    assert "sharded decode OK" in out.stdout
    assert "distributed train step OK" in out.stdout
