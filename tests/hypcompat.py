"""`hypothesis` imports, or skip-stubs when it isn't installed.

The image doesn't ship hypothesis; importing it at module top used to break
collection of four whole test modules, hiding every plain test they contain.
Importing `given / settings / st` from here keeps those modules collectable:
with hypothesis present the real objects pass straight through; without it the
property-based tests collect as individually-skipped stubs and the plain tests
keep running.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub(*a, **k):  # pragma: no cover
                pass

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Attribute access yields inert strategy factories (never executed)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
