"""Fault tolerance: bit-exact checkpoint/resume of the full guided train state.

The headline criterion of the checkpoint subsystem (DESIGN.md §8): for every
registered delay-compensation strategy on the mesh backend,

    train(N)  ==  train(k) -> kill -> resume -> train(N-k)

leaf for leaf over params AND GuidedState (opt state, consistency scores,
w_stale, strategy extra, step). Also covers the SIGTERM path, the launcher
regression (it used to snapshot `{"params": params}` only, dropping the
entire guided state), resharding restore, serve warm-start, and the two
schedule/throughput satellite fixes.
"""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import ExperimentSpec, Trainer


def _spec(strategy, mode, **kw):
    kw.setdefault("rho", 4)  # cut at k=3 is MID-window: scores must survive
    kw.setdefault("staleness", 2)
    kw.setdefault("steps", 6)
    return ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode=mode, strategy=strategy,
        lr=5e-2, seed=0, seq_len=16, global_batch=4, workers=2, **kw)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- the headline matrix

# every registered strategy (the built-in registry; test-local plugins from
# other modules are excluded on purpose) under its natural execution mode
STRATEGIES = [
    ("none", "ssgd"),
    ("guided_fused", "ssgd"),
    ("guided_two_pass", "ssgd"),
    ("dc_asgd", "asgd"),
    ("dc_asgd_guided", "asgd"),
    ("gap_aware", "asgd"),
]


@pytest.mark.parametrize("strategy,mode", STRATEGIES)
def test_bit_exact_resume(strategy, mode, tmp_path):
    d = str(tmp_path / strategy)
    full = Trainer.from_spec(_spec(strategy, mode)).fit()

    # "kill" after k=3 of 6 steps: a separate process's worth of state is
    # exactly what the final full-state snapshot holds
    part = Trainer.from_spec(_spec(strategy, mode, steps=3, ckpt_dir=d)).fit()
    assert part.n_steps == 3

    resumed = Trainer.from_spec(_spec(strategy, mode, ckpt_dir=d)).fit(resume=True)
    assert resumed.start_step == 3 and resumed.n_steps == 3
    _assert_trees_equal(full.model, resumed.model)
    _assert_trees_equal(full.state, resumed.state)  # scores, w_stale, opt, extra
    assert int(resumed.state.step) == 6
    # the cut was mid-window: the restored consistency scores were live state
    if strategy in ("guided_fused", "guided_two_pass", "dc_asgd_guided"):
        assert float(jnp.sum(jnp.abs(part.state.score))) > 0.0


def test_resume_with_explicit_data_stream(tmp_path):
    """resume skips the already-consumed prefix of a caller-provided stream."""
    from repro.data import make_batch_for

    d = str(tmp_path)
    spec = _spec("guided_fused", "ssgd")
    cfg = spec.model_config()
    batches = [make_batch_for(cfg, 16, 4, seed=i) for i in range(6)]
    full = Trainer.from_spec(spec).fit(data=[dict(b) for b in batches])
    Trainer.from_spec(spec.replace(steps=3, ckpt_dir=d)).fit(
        data=[dict(b) for b in batches[:3]])
    resumed = Trainer.from_spec(spec.replace(ckpt_dir=d)).fit(
        data=[dict(b) for b in batches], resume=True)
    _assert_trees_equal(full.model, resumed.model)
    _assert_trees_equal(full.state, resumed.state)


def test_resume_past_end_raises_without_stranding_writer(tmp_path):
    """Failed resume validation must not leak the async writer thread (the
    checkpointer is constructed only after the restore succeeds)."""
    import threading

    d = str(tmp_path)
    Trainer.from_spec(_spec("none", "ssgd", steps=4, ckpt_dir=d)).fit()
    n0 = threading.active_count()
    with pytest.raises(ValueError, match="past this run's n_steps=2"):
        Trainer.from_spec(_spec("none", "ssgd", steps=2, ckpt_dir=d)).fit(resume=True)
    assert threading.active_count() == n0  # no stranded ckpt-writer threads


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    d = str(tmp_path / "empty")
    r = Trainer.from_spec(_spec("none", "ssgd", ckpt_dir=d)).fit(resume=True)
    assert r.start_step == 0 and r.n_steps == 6


def test_resume_rejects_params_only_checkpoint(tmp_path):
    """THE original bug as an error message: a v1 params-only archive cannot
    silently restart compensation from scratch — restore names what's gone."""
    from repro.checkpoint import restore_train_state, save, snapshot
    from repro.engine import mesh as M
    from repro.optim import get_optimizer

    spec = _spec("guided_fused", "ssgd")
    params, _, gstate = M.init_train_state(
        jax.random.PRNGKey(0), spec.model_config(), spec.to_guided_config(),
        get_optimizer("sgd"), n_workers=2)
    d = str(tmp_path)
    save(d, 3, {"params": params})  # what launch/train.py used to write
    with pytest.raises(ValueError, match="missing from archive.*gstate"):
        restore_train_state(d, 3, snapshot(params, gstate, 0))


def test_sigterm_saves_full_state_and_resume_matches(tmp_path):
    """SIGTERM mid-run: the in-flight step finishes, full state is snapshotted,
    fit returns interrupted=True — and resume completes bit-exactly."""
    d = str(tmp_path)
    full = Trainer.from_spec(_spec("guided_fused", "ssgd")).fit()

    def kill_at_2(step, m, params):
        if step == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    part = Trainer.from_spec(_spec("guided_fused", "ssgd", ckpt_dir=d)).fit(
        on_step=kill_at_2)
    assert part.interrupted
    assert part.n_steps == 3  # steps 0..2 ran; the in-flight step completed
    from repro.checkpoint import latest_step

    assert latest_step(d) == 3
    resumed = Trainer.from_spec(_spec("guided_fused", "ssgd", ckpt_dir=d)).fit(
        resume=True)
    assert resumed.start_step == 3 and not resumed.interrupted
    _assert_trees_equal(full.model, resumed.model)
    _assert_trees_equal(full.state, resumed.state)


def test_periodic_async_checkpoints_and_retention(tmp_path):
    from repro.checkpoint import read_manifest

    d = str(tmp_path)
    Trainer.from_spec(_spec("guided_fused", "ssgd", ckpt_dir=d, ckpt_every=2,
                            keep_last=2)).fit()
    man = read_manifest(d)
    assert man["latest"] == 6
    assert [c["step"] for c in man["ckpts"]] == [4, 6]  # 2 pruned by retention
    assert man["ckpts"][-1]["meta"]["strategy"] == "guided_fused"
    files = [f for f in os.listdir(d) if f.endswith(".npz")]
    assert len(files) == 2


# ------------------------------------------------------------- the launcher


def test_launcher_restored_run_matches_uninterrupted(tmp_path):
    """Regression for the launcher checkpoint hazard: snapshots now go through
    the Trainer's full-state path (params AND GuidedState, off the donated
    buffers), so kill+--resume reproduces the uninterrupted run's final
    archive bit for bit."""
    from repro.checkpoint import latest_step, restore_train_state
    from repro.launch.train import main as train_main

    common = ["--arch", "yi_9b", "--reduced", "--mode", "ssgd",
              "--strategy", "guided_fused", "--rho", "4", "--lr", "0.05",
              "--seq", "16", "--batch", "4", "--workers", "2",
              "--log-every", "2"]
    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    train_main(common + ["--steps", "6", "--ckpt-dir", da])
    train_main(common + ["--steps", "3", "--ckpt-dir", db])   # "preempted"
    train_main(common + ["--steps", "6", "--ckpt-dir", db, "--resume"])
    assert latest_step(da) == latest_step(db) == 6

    import numpy as _np
    A = _np.load(os.path.join(da, "step_00000006.npz"))
    B = _np.load(os.path.join(db, "step_00000006.npz"))
    assert sorted(A.files) == sorted(B.files)
    assert any("gstate" in k for k in A.files)  # full state, not params-only
    for k in A.files:
        _np.testing.assert_array_equal(A[k], B[k], err_msg=k)


def test_launcher_accepts_cosine_schedule(tmp_path, capsys):
    """argparse rejected --schedule cosine although ExperimentSpec/Trainer
    support it; choices now come from the spec's canonical tuple."""
    from repro.launch.train import main as train_main

    hist = train_main(["--arch", "yi_9b", "--reduced", "--steps", "3",
                       "--seq", "16", "--batch", "4", "--workers", "2",
                       "--schedule", "cosine", "--log-every", "1"])
    assert len(hist) == 3 and np.isfinite(hist[-1]["loss"])


# ------------------------------------------------- resharding + serve warm-start


def test_reshard_restore_onto_host_mesh(tmp_path):
    """A snapshot written on the local (meshless) backend restores onto a host
    mesh through the logical sharding rules: every leaf comes back as a
    committed jax.Array with the mesh's sharding."""
    from repro import checkpoint as C
    from repro.engine import mesh as M
    from repro.optim import get_optimizer

    d = str(tmp_path)
    spec = _spec("dc_asgd", "asgd", optimizer="rmsprop")
    Trainer.from_spec(spec.replace(steps=2, ckpt_dir=d)).fit()

    ctx = M.build_ctx("host")  # 1-device host mesh on CPU; still a real Mesh
    assert ctx.distributed
    params, logical, gstate = M.init_train_state(
        jax.random.PRNGKey(0), spec.model_config(), spec.to_guided_config(),
        get_optimizer("rmsprop"), n_workers=2,
        strategy=Trainer.from_spec(spec).strategy)
    shardings = C.train_state_shardings(ctx, logical, params, gstate)
    snap = C.restore_train_state(d, 2, C.snapshot(params, gstate, 0),
                                 shardings=shardings)
    assert int(np.asarray(snap["data"]["cursor"])) == 2
    for leaf in jax.tree.leaves(snap):
        assert isinstance(leaf, jax.Array) and leaf.sharding.mesh == ctx.mesh
    # w_stale resharded like the params (non-trivial tree: rmsprop "r" too)
    assert jax.tree.structure(snap["gstate"].w_stale) == jax.tree.structure(params)


def test_serve_engine_from_checkpoint(tmp_path):
    """A training snapshot warm-starts serving: params subtree only, config
    rebuilt from the manifest metadata, token streams identical to an engine
    built directly from the trained params."""
    from repro.serve import Request, ServeEngine

    d = str(tmp_path)
    spec = _spec("guided_fused", "ssgd", steps=2, ckpt_dir=d)
    report = Trainer.from_spec(spec).fit()

    eng_ckpt = ServeEngine.from_checkpoint(d, max_batch=2, max_len=32)  # cfg from manifest
    eng_live = ServeEngine(report.model, spec.model_config(), max_batch=2, max_len=32)
    prompts = [[5, 3, 8, 1], [2, 9]]
    outs = []
    for eng in (eng_ckpt, eng_live):
        comps = eng.run([Request(p, max_new_tokens=6) for p in prompts])
        outs.append({c.request_id: c.tokens for c in comps})
    assert outs[0] == outs[1]


def test_serve_from_checkpoint_missing_dir(tmp_path):
    from repro.serve import ServeEngine

    with pytest.raises(FileNotFoundError, match="no checkpoint manifest"):
        ServeEngine.from_checkpoint(str(tmp_path / "nope"))


# ------------------------------------------------- satellite: schedules


def test_wsd_phases_partition_run_and_reach_final_frac():
    """warmup + stable + decay == n_steps now (the old wiring passed
    stable = decay = n_steps // 2, overrunning by warmup steps, so the decay
    never reached final_frac before the run ended)."""
    from repro.optim import for_run

    lr, warmup, n = 0.1, 10, 100
    f = for_run("wsd", lr, warmup, n)
    assert float(f(0)) == 0.0
    assert float(f(warmup)) == pytest.approx(lr)
    rem = n - warmup
    stable, decay = rem // 2, rem - rem // 2
    assert float(f(warmup + stable)) == pytest.approx(lr)      # plateau end
    assert float(f(n)) == pytest.approx(0.01 * lr, rel=1e-5)   # full decay IN the run
    # the last step the run actually takes is already essentially decayed
    assert float(f(n - 1)) < 0.012 * lr
    # old behaviour check: overrun would leave f(n) ~ lr * final_frac^(something < 1)
    assert float(f(n)) < float(f(warmup + stable + 1))


def test_cosine_schedule_endpoint():
    from repro.optim import for_run

    f = for_run("cosine", 0.2, 5, 50)
    assert float(f(50)) == pytest.approx(0.1 * 0.2, rel=1e-5)


def test_unknown_schedule_rejected_at_spec_construction():
    with pytest.raises(ValueError, match="unknown schedule 'linear'"):
        ExperimentSpec(backend="mesh", schedule="linear")
    with pytest.raises(ValueError, match="ckpt_every=5 needs ckpt_dir"):
        ExperimentSpec(backend="mesh", ckpt_every=5)


# ------------------------------------------------- satellite: steps_per_s


def test_steps_per_s_counts_server_steps_not_history_records():
    """Throughput derives from the schedule/server step count (train_ps's own
    counter, the scan schedule's T, the mesh loop's steps-actually-run), not
    from len(history)."""
    from repro.data import load_dataset

    X, y, k = load_dataset("new_thyroid", seed=0)
    rep = Trainer.from_spec(ExperimentSpec.for_algo("gSSGD", epochs=2, seed=0)).fit(
        (X, y, k))
    assert rep.n_steps == len(rep.history) > 0  # sim: 1 record per arrival
    assert rep.steps_per_s == pytest.approx(rep.n_steps / rep.wall_time_s)

    rep2 = Trainer.from_spec(ExperimentSpec.for_algo(
        "gSSGD", backend="scan", epochs=2, seed=0, n_seeds=2)).fit((X, y, k))
    # scan: n_steps is per-seed (the schedule's T); throughput counts seeds
    assert rep2.n_steps == len(rep2.history)
    assert rep2.steps_per_s == pytest.approx(2 * rep2.n_steps / rep2.wall_time_s)


def test_steps_per_s_on_resumed_mesh_run_counts_steps_run(tmp_path):
    """A resumed fit runs N-k steps; throughput must not claim all N — and
    (since the compile/warm split) it is WARM: the compiling dispatch and the
    out-of-loop setup (incl. the checkpoint restore itself) are excluded
    (Report.compile_time_s / Report.warm_steps / Report.warm_time_s)."""
    d = str(tmp_path)
    Trainer.from_spec(_spec("none", "ssgd", steps=4, ckpt_dir=d)).fit()
    r = Trainer.from_spec(_spec("none", "ssgd", ckpt_dir=d)).fit(resume=True)
    assert r.n_steps == 2 and r.start_step == 4
    assert r.compile_time_s > 0 and r.warm_steps == 1
    assert 0 < r.warm_time_s < r.wall_time_s - r.compile_time_s
    assert r.steps_per_s == pytest.approx(r.warm_steps / r.warm_time_s)
