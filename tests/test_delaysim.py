"""Tests for the jitted scan delay-simulation backend (repro.engine.delaysim):

  * trajectory parity with the numpy reference loop (train_ps) for the
    paper's algorithms — the acceptance bar is 1e-5 on the final losses;
    float64 + an identical schedule give ~1e-15 in practice;
  * DelaySchedule extraction semantics (seq / barrier / event-queue);
  * multi-seed vmap: one n_seeds=k run equals k independent runs leaf-for-leaf;
  * the new delay topologies and scan-only strategies (dc_asgd, gap_aware);
  * ExperimentSpec construction-time validation of strategy/mode/topology.
"""
import numpy as np
import pytest

from repro.core.parameter_server import (
    PSConfig,
    algo_config,
    extract_schedule,
    prepare_run,
    train_ps,
)
from repro.data import load_dataset, train_test_split
from repro.engine import ExperimentSpec, TOPOLOGIES, Trainer


@pytest.fixture(scope="module")
def cancer():
    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=2)
    return Xtr[:260], ytr[:260], k, Xte, yte


@pytest.fixture(scope="module")
def thyroid():
    X, y, k = load_dataset("new_thyroid", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=1)
    return Xtr, ytr, k, Xte, yte


# ------------------------------------------------------------------ parity


@pytest.mark.parametrize("algo", ["SGD", "SSGD", "gSSGD", "ASGD"])
def test_scan_matches_train_ps_trajectory(cancer, algo):
    """The acceptance-criteria lock: backend='scan' reproduces the numpy
    train_ps trajectory (same seed -> same schedule) to <=1e-5 final loss."""
    Xtr, ytr, k, Xte, yte = cancer
    legacy = train_ps(Xtr, ytr, k, algo_config(algo, epochs=2, seed=2), Xte, yte)
    rep = Trainer.from_spec(
        ExperimentSpec.for_algo(algo, epochs=2, seed=2, backend="scan")
    ).fit((Xtr, ytr, k, Xte, yte))
    assert abs(rep.final_loss - legacy["train_loss"]) <= 1e-5
    assert abs(rep.val_loss - legacy["val_loss"]) <= 1e-5
    h_np = np.array([h[1] for h in legacy["history"]])
    h_sc = np.array([h[1] for h in rep.history])
    assert h_np.shape == h_sc.shape
    np.testing.assert_allclose(h_sc, h_np, atol=1e-5, rtol=0)
    assert rep.test_accuracy == legacy["test_accuracy"]


@pytest.mark.parametrize("algo", ["gSGD", "gASGD", "SRMSprop", "gSAdagrad"])
def test_scan_matches_train_ps_variants(cancer, algo):
    """Optimizer variants + remaining guided combos hold the same parity."""
    Xtr, ytr, k, Xte, yte = cancer
    legacy = train_ps(Xtr, ytr, k, algo_config(algo, epochs=2, seed=3), Xte, yte)
    rep = Trainer.from_spec(
        ExperimentSpec.for_algo(algo, epochs=2, seed=3, backend="scan")
    ).fit((Xtr, ytr, k, Xte, yte))
    assert abs(rep.final_loss - legacy["train_loss"]) <= 1e-5
    assert abs(rep.val_loss - legacy["val_loss"]) <= 1e-5


# -------------------------------------------------------- schedule extraction


def test_schedule_seq_and_barrier_shapes():
    cfg = PSConfig(mode="seq", epochs=2, batch_size=8, rho=4, seed=0)
    rng = np.random.default_rng(0)
    s = extract_schedule(cfg, 50, rng)
    nb = (50 - 8) // 8 + 1
    assert s.n_steps == 2 * nb
    assert s.topology == "seq" and s.n_workers == 1
    assert s.max_staleness == 0

    cfg = PSConfig(mode="ssgd", epochs=1, batch_size=8, rho=4, seed=0)
    s = extract_schedule(cfg, 50, np.random.default_rng(0))
    # barrier sawtooth: 0..c-1 per round, truncated final round
    assert list(s.staleness) == [0, 1, 2, 3, 0, 1]
    assert s.max_staleness == cfg.n_workers - 1


def test_schedule_asgd_event_queue_is_causal_and_covers_all_batches():
    cfg = PSConfig(mode="asgd", epochs=2, batch_size=8, rho=4, seed=7)
    rng = np.random.default_rng(7)
    s = extract_schedule(cfg, 64, rng)
    nb = (64 - 8) // 8 + 1
    assert s.n_steps == 2 * nb
    # staleness never reaches before step 0 and resets across epochs
    i = np.arange(s.n_steps)
    assert (s.staleness <= i).all() and (s.staleness >= 0).all()
    # every batch of each epoch applied exactly once (rows partition the perm)
    per_epoch = s.batch_rows[:nb].reshape(-1)
    assert len(np.unique(per_epoch)) == nb * 8


def test_prepare_run_mirrors_train_ps_rng_protocol(cancer):
    """Same seed -> the schedule's batches are the ones train_ps consumed
    (checked indirectly through the parity tests; directly here: W0 and the
    validation split match a hand-replay of the rng protocol)."""
    Xtr, ytr, k, _, _ = cancer
    cfg = PSConfig(mode="ssgd", epochs=1, seed=11)
    W0, (Xt, yt), (Xv, yv), sched = prepare_run(Xtr, ytr, k, cfg)
    rng = np.random.default_rng(11)
    n_val = max(8, int(cfg.verification_frac * len(Xtr)))
    vidx = rng.choice(len(Xtr), n_val, replace=False)
    np.testing.assert_array_equal(Xv, Xtr[vidx])
    mask = np.ones(len(Xtr), bool)
    mask[vidx] = False
    W0_ref = 0.01 * rng.standard_normal((Xtr.shape[1] + 1, k))
    np.testing.assert_array_equal(W0, W0_ref)
    assert sched.batch_rows.shape[1] == cfg.batch_size
    assert len(Xt) == mask.sum()


# ----------------------------------------------------------- multi-seed vmap


def test_multi_seed_vmap_equals_independent_runs(thyroid):
    """n_seeds=4 returns, leaf for leaf, exactly what four independent
    n_seeds=1 fits return (same compile or not, bitwise equal)."""
    Xtr, ytr, k, Xte, yte = thyroid
    rep4 = Trainer.from_spec(
        ExperimentSpec.for_algo("gSSGD", epochs=3, seed=5, backend="scan", n_seeds=4)
    ).fit((Xtr, ytr, k, Xte, yte))
    assert rep4.final["train_loss"].shape == (4,)
    for i in range(4):
        r1 = Trainer.from_spec(
            ExperimentSpec.for_algo("gSSGD", epochs=3, seed=5 + i, backend="scan")
        ).fit((Xtr, ytr, k, Xte, yte))
        assert float(rep4.final["train_loss"][i]) == r1.final_loss
        assert float(rep4.final["val_loss"][i]) == r1.val_loss
        assert float(rep4.final["test_accuracy"][i]) == r1.test_accuracy
        assert all(float(h4[1][i]) == h1[1]
                   for h4, h1 in zip(rep4.history, r1.history))
        np.testing.assert_array_equal(rep4.model[i].W, r1.model.W)


# -------------------------------------------------------------- topologies


@pytest.mark.parametrize("topology", ["constant", "heavy_tail", "straggler", "hetero"])
def test_event_topologies_run_and_are_causal(thyroid, topology):
    Xtr, ytr, k, Xte, yte = thyroid
    spec = ExperimentSpec(backend="scan", mode="asgd", strategy="guided_fused",
                          topology=topology, epochs=2, seed=0, rho=6)
    rep = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
    assert np.isfinite(rep.final_loss)
    from repro.engine.delaysim import TOPOLOGY_SAMPLERS

    _, _, _, sched = prepare_run(Xtr, ytr, k, spec.to_schedule_config(),
                                 TOPOLOGY_SAMPLERS[topology], topology)
    i = np.arange(sched.n_steps)
    assert (sched.staleness <= i).all() and (sched.staleness >= 0).all()
    assert sched.topology == topology


def test_constant_topology_is_round_robin(thyroid):
    """Equal compute times -> deterministic round-robin arrivals with the
    classic steady-state staleness c-1."""
    Xtr, ytr, k, _, _ = thyroid
    from repro.engine.delaysim import TOPOLOGY_SAMPLERS

    cfg = PSConfig(mode="asgd", epochs=1, rho=4, batch_size=8, seed=0)
    _, _, _, sched = prepare_run(Xtr, ytr, k, cfg,
                                 TOPOLOGY_SAMPLERS["constant"], "constant")
    c = cfg.n_workers
    # after the initial ramp (staleness 0..c-1), steady state is c-1
    steady = sched.staleness[c:]
    assert (steady == c - 1).all()
    assert list(sched.staleness[:c]) == list(range(min(c, sched.n_steps)))


def test_scan_only_strategies_run_at_paper_scale(thyroid):
    """dc_asgd and gap_aware have no numpy-sim path; through the registry
    hooks they now run on the scan backend (this is new capability)."""
    Xtr, ytr, k, Xte, yte = thyroid
    base = ExperimentSpec(backend="scan", mode="asgd", strategy="none",
                          epochs=2, seed=0)
    r0 = Trainer.from_spec(base).fit((Xtr, ytr, k, Xte, yte))
    for strat in ("dc_asgd", "gap_aware"):
        r = Trainer.from_spec(base.replace(strategy=strat)).fit((Xtr, ytr, k, Xte, yte))
        assert np.isfinite(r.final_loss)
        # compensation must actually change the trajectory
        assert r.final_loss != r0.final_loss


# ------------------------------------------------------- spec validation


def test_spec_rejects_stale_strategies_without_asgd():
    for strat in ("gap_aware", "dc_asgd", "dc_asgd_guided"):
        with pytest.raises(ValueError, match="asgd"):
            ExperimentSpec(backend="scan", mode="ssgd", strategy=strat)
        with pytest.raises(ValueError, match="asgd"):
            ExperimentSpec(backend="mesh", mode="seq", strategy=strat)


def test_spec_validates_topology():
    with pytest.raises(ValueError, match="unknown topology"):
        ExperimentSpec(backend="scan", mode="asgd", topology="wormhole")
    with pytest.raises(ValueError, match="backend knob"):
        ExperimentSpec(backend="sim", mode="asgd", topology="heavy_tail")
    with pytest.raises(ValueError, match="defined for mode"):
        ExperimentSpec(backend="scan", mode="ssgd", topology="heavy_tail")
    # canonical names pass for their modes
    ExperimentSpec(backend="scan", mode="ssgd", topology="barrier")
    ExperimentSpec(backend="scan", mode="asgd", topology="exp")
    assert ExperimentSpec(backend="scan", mode="ssgd").resolved_topology == "barrier"
    assert set(TOPOLOGIES) >= {"seq", "barrier", "exp", "constant",
                               "heavy_tail", "straggler", "hetero"}


def test_spec_validates_n_seeds():
    with pytest.raises(ValueError, match="n_seeds"):
        ExperimentSpec(backend="scan", n_seeds=0)
    with pytest.raises(ValueError, match="scan"):
        ExperimentSpec(backend="sim", mode="ssgd", n_seeds=4)
    with pytest.raises(ValueError, match="scan"):
        ExperimentSpec(backend="mesh", n_seeds=2)


def test_spec_and_registry_share_the_stale_message():
    from repro.engine.spec import needs_stale_message
    from repro.engine import get_compensator
    from repro.core.guided import GuidedConfig

    with pytest.raises(ValueError) as spec_err:
        ExperimentSpec(backend="mesh", mode="ssgd", strategy="gap_aware")
    with pytest.raises(ValueError) as reg_err:
        get_compensator("gap_aware", GuidedConfig(mode="ssgd"))
    assert str(spec_err.value) == str(reg_err.value)
    assert "stale weights" in needs_stale_message("x", "y", "ssgd")


# ------------------------------------------------------------------ report


def test_report_gains_timing_fields(thyroid):
    Xtr, ytr, k, Xte, yte = thyroid
    rep = Trainer.from_spec(
        ExperimentSpec.for_algo("SSGD", epochs=1, backend="scan")
    ).fit((Xtr, ytr, k, Xte, yte))
    assert rep.wall_time_s > 0
    assert rep.steps_per_s > 0
    sim = Trainer.from_spec(
        ExperimentSpec.for_algo("SSGD", epochs=1)
    ).fit((Xtr, ytr, k, Xte, yte))
    assert sim.wall_time_s > 0 and sim.steps_per_s > 0


def test_scan_handles_zero_batches_like_train_ps(thyroid):
    """batch_size > n_train yields zero arrivals; both backends return the
    untouched init instead of crashing."""
    Xtr, ytr, k, Xte, yte = thyroid
    X20, y20 = Xtr[:20], ytr[:20]
    spec = ExperimentSpec.for_algo("SSGD", epochs=2, seed=0, batch_size=64)
    ref = Trainer.from_spec(spec).fit((X20, y20, k, Xte, yte))
    rep = Trainer.from_spec(spec.replace(backend="scan")).fit((X20, y20, k, Xte, yte))
    assert rep.history == [] == ref.history
    assert rep.final_loss == ref.final_loss
    assert rep.test_accuracy == ref.test_accuracy


def test_scan_rejects_missing_data():
    with pytest.raises(ValueError, match="scan backend needs data"):
        Trainer.from_spec(ExperimentSpec.for_algo("SSGD", backend="scan")).fit()


def test_trainer_resolves_scan_strategy_eagerly():
    with pytest.raises(KeyError, match="registered:"):
        Trainer.from_spec(ExperimentSpec(backend="scan", strategy="nope"))


def test_runner_cache_is_a_bounded_lru(thyroid, monkeypatch):
    """The jit-runner cache must stay bounded across parameter sweeps (it used
    to pin one compile per distinct config forever) and be clearable."""
    from repro.engine import delaysim

    Xtr, ytr, k, Xte, yte = thyroid
    delaysim.clear_runners()
    assert len(delaysim._RUNNERS) == 0
    monkeypatch.setattr(delaysim, "_RUNNERS_MAX", 1)
    for rho in (2, 3):  # distinct rho -> distinct runner keys
        spec = ExperimentSpec.for_algo("gSSGD", epochs=1, seed=0,
                                       backend="scan").replace(rho=rho)
        Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
        # the bound is enforced on insert: never more than _RUNNERS_MAX pinned
        assert len(delaysim._RUNNERS) == 1
    delaysim.clear_runners()
    assert len(delaysim._RUNNERS) == 0
    # and a cleared cache still serves runs (recompiles on demand)
    spec = ExperimentSpec.for_algo("gSSGD", epochs=1, seed=0, backend="scan")
    rep = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
    assert np.isfinite(rep.final_loss)
    assert len(delaysim._RUNNERS) == 1


# ----------------------------------------- fused optimizers on the scan path


@pytest.mark.parametrize("optimizer", ["momentum", "adam"])
@pytest.mark.parametrize("strategy", ["guided_fused", "dc_asgd"])
def test_scan_fused_optimizers_train(thyroid, optimizer, strategy):
    """The scan backend routes momentum/adam through the fused whole-update
    kernels (strategy.sim_kernel, DESIGN.md §11); both compensating and
    plain-guided strategies must train to finite losses and beat init."""
    Xtr, ytr, k, Xte, yte = thyroid
    spec = ExperimentSpec(backend="scan", mode="asgd", strategy=strategy,
                          epochs=2, seed=0, rho=4, lr=0.01,
                          optimizer=optimizer)
    rep = Trainer.from_spec(spec).fit((Xtr, ytr, k, Xte, yte))
    assert np.isfinite(rep.final_loss)
    losses = [h[1] for h in rep.history]
    assert losses[-1] < losses[0]


def test_sim_and_dist_backends_reject_fused_only_optimizers():
    """The numpy event loop and the socket PS only implement
    sgd/rmsprop/adagrad; momentum/adam must fail at spec construction, not
    deep inside a worker process."""
    for backend in ("sim", "dist"):
        for optimizer in ("momentum", "adam"):
            with pytest.raises(ValueError, match="backend"):
                ExperimentSpec(backend=backend, mode="asgd", strategy="none",
                               epochs=1, seed=0, optimizer=optimizer)
