"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.guided_update.ops import guided_sgd_update, guided_rmsprop_update
from repro.kernels.guided_update.ref import guided_rmsprop_update_ref, guided_sgd_update_ref
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref

RNG = np.random.default_rng(0)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("B,S,H,K,dh", [(2, 256, 4, 2, 64), (1, 128, 8, 8, 32),
                                        (1, 256, 4, 1, 128), (2, 512, 2, 2, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 128)])
def test_flash_attention_matches_ref(B, S, H, K, dh, causal, window):
    q, k, v = randn(B, S, H, dh), randn(B, S, K, dh), randn(B, S, K, dh)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = (randn(1, 128, 2, 64, dtype=dtype) for _ in range(3))
    out = flash_attention(q, k, v, causal=True)
    assert out.dtype == dtype
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 3e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(bq, bk):
    q, k, v = randn(1, 256, 2, 32), randn(1, 256, 2, 32), randn(1, 256, 2, 32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# --------------------------------------------------------------- flash decode


@pytest.mark.parametrize("B,S,H,K,dh,bk", [(2, 512, 4, 2, 64, 256), (3, 256, 8, 1, 128, 64),
                                           (1, 1024, 2, 2, 32, 256)])
def test_flash_decode_matches_ref(B, S, H, K, dh, bk):
    q = randn(B, 1, H, dh)
    kc, vc = randn(B, S, K, dh), randn(B, S, K, dh)
    lens = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    out = flash_decode(q, kc, vc, lens, bk=bk)
    ref = decode_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_decode_full_cache_equals_attention_row():
    """Decode over a fully-valid cache == last row of causal attention."""
    B, S, H, dh = 1, 256, 2, 64
    k = randn(B, S, H, dh)
    v = randn(B, S, H, dh)
    q_full = randn(B, S, H, dh)
    full = attention_ref(q_full, k, v, causal=True)
    dec = flash_decode(q_full[:, -1:], k, v, jnp.asarray([S], jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), atol=3e-5)


# ------------------------------------------------------------- selective scan


@pytest.mark.parametrize("B,S,ed,n,Q,be", [(2, 64, 128, 16, 16, 64), (1, 32, 64, 8, 8, 64),
                                           (2, 128, 256, 16, 32, 128), (1, 64, 64, 4, 64, 32)])
def test_selective_scan_matches_ref(B, S, ed, n, Q, be):
    x = randn(B, S, ed)
    dt = jnp.abs(randn(B, S, ed)) * 0.1
    A = -jnp.abs(randn(ed, n))
    Bc, Cc = randn(B, S, n), randn(B, S, n)
    h0 = randn(B, ed, n)
    y, h = selective_scan(x, dt, A, Bc, Cc, h0, chunk=Q, block_ed=be)
    yr, hr = selective_scan_ref(x, dt, A, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_selective_scan_state_chaining():
    """Scanning two halves with carried state == one full scan."""
    B, S, ed, n = 1, 64, 32, 8
    x, dt = randn(B, S, ed), jnp.abs(randn(B, S, ed)) * 0.1
    A = -jnp.abs(randn(ed, n))
    Bc, Cc = randn(B, S, n), randn(B, S, n)
    y_full, h_full = selective_scan(x, dt, A, Bc, Cc, chunk=16, block_ed=32)
    y1, h1 = selective_scan(x[:, :32], dt[:, :32], A, Bc[:, :32], Cc[:, :32], chunk=16, block_ed=32)
    y2, h2 = selective_scan(x[:, 32:], dt[:, 32:], A, Bc[:, 32:], Cc[:, 32:], h0=h1, chunk=16, block_ed=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


# -------------------------------------------------------------- guided update


@pytest.mark.parametrize("n,block", [(1000, 256), (65536, 65536), (37 * 129, 512)])
def test_guided_sgd_update_matches_ref(n, block):
    w = randn(n)
    g = randn(n) * 0.01
    ws = w + 0.05
    out = guided_sgd_update(w, g, ws, 0.2, 0.04, block=block)
    ref = guided_sgd_update_ref(w, g, ws, 0.2, 0.04)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_guided_rmsprop_update_matches_ref():
    tree = {"a": randn(513), "b": {"c": randn(17, 65)}}
    g = jax.tree.map(lambda x: x * 0.01, tree)
    ws = jax.tree.map(lambda x: x + 0.1, tree)
    r = jax.tree.map(lambda x: jnp.abs(x) * 0.2, tree)
    nw, nr = guided_rmsprop_update(tree, g, ws, r, 0.2, 0.04, block=256)
    for k in ("a",):
        rw, rr = guided_rmsprop_update_ref(tree[k], g[k], ws[k], r[k], 0.2, 0.04, 0.9, 1e-8)
        np.testing.assert_allclose(np.asarray(nw[k]), np.asarray(rw), atol=1e-6)
        np.testing.assert_allclose(np.asarray(nr[k]), np.asarray(rr), atol=1e-6)


def test_guided_update_lam_zero_is_sgd():
    w, g, ws = randn(333), randn(333), randn(333)
    out = guided_sgd_update(w, g, ws, 0.1, 0.0, block=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w - 0.1 * g), atol=1e-6)


# ------------------------------------------- fused whole-update (DESIGN.md §11)


def _optim_composition(optimizer, w, g, ws, state, lr, lam, **hy):
    """The unfused two-phase path the fused kernels replace: DC-ASGD
    compensation materialized, then the `repro.optim` accumulator update."""
    from repro.optim import get_optimizer

    gt = g + lam * g * g * (w - ws)
    opt = get_optimizer(optimizer, **hy)
    upd, state = opt.update(gt, state, w, lr)
    return w + upd, state


@pytest.mark.parametrize("n,block", [(37 * 129, 512), (4096, 4096)])
@pytest.mark.parametrize("impl", ["kernel", "ref"])
@pytest.mark.parametrize("nesterov", [False, True])
def test_fused_momentum_matches_optimizer_composition(n, block, impl, nesterov):
    from repro.kernels.guided_update.ops import fused_update_for

    w = randn(n)
    g = randn(n) * 0.01
    ws = w + 0.05
    m = jnp.abs(randn(n)) * 0.1
    lr, lam = 0.2, 0.04
    fused = fused_update_for("momentum", beta=0.9, nesterov=nesterov, impl=impl)
    w_f, (m_f,) = fused(w, g, ws, (m,), 1, lr, lam, block=block)
    w_r, st = _optim_composition("momentum", w, g, ws, {"m": m}, lr, lam,
                                 beta=0.9, nesterov=nesterov)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_f), np.asarray(st["m"]), atol=1e-6)


@pytest.mark.parametrize("n,block", [(37 * 129, 512), (4096, 4096)])
@pytest.mark.parametrize("impl", ["kernel", "ref"])
@pytest.mark.parametrize("t", [1, 7])
def test_fused_adam_matches_optimizer_composition(n, block, impl, t):
    from repro.kernels.guided_update.ops import fused_update_for

    w = randn(n)
    g = randn(n) * 0.01
    ws = w + 0.05
    m = jnp.abs(randn(n)) * 0.1
    v = jnp.abs(randn(n)) * 0.05
    lr, lam = 0.2, 0.04
    fused = fused_update_for("adam", b1=0.9, b2=0.999, eps=1e-8, impl=impl)
    w_f, (m_f, v_f) = fused(w, g, ws, (m, v), t, lr, lam, block=block)
    state = {"m": m, "v": v, "t": jnp.asarray(t - 1, jnp.int32)}
    w_r, st = _optim_composition("adam", w, g, ws, state, lr, lam,
                                 b1=0.9, b2=0.999, eps=1e-8)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_f), np.asarray(st["m"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(st["v"]), atol=1e-6)


def test_fused_kernels_match_ref_float64():
    """The f64 regime (delay-sim parity): Pallas kernel vs the pure-jnp ref
    at the scan backend's acceptance bar, odd size exercising the pad path."""
    from jax.experimental import enable_x64

    from repro.kernels.guided_update import kernel as K
    from repro.kernels.guided_update import ref as R

    with enable_x64():
        rng = np.random.default_rng(7)
        n = 37 * 129
        w = jnp.asarray(rng.standard_normal(n), jnp.float64)
        g = w * 0.01
        ws = w + 0.05
        m = jnp.abs(w) * 0.1
        v = jnp.abs(w) * 0.05

        w_k, m_k = K.guided_momentum_update_raw(w, g, ws, m, 0.2, 0.04, 0.9,
                                                block=512)
        w_r, m_r = R.guided_momentum_update_ref(w, g, ws, m, 0.2, 0.04, 0.9)
        np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), atol=1e-12)
        np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-12)

        out_k = K.guided_adam_update_raw(w, g, ws, m, v, 5, 0.2, 0.04,
                                         0.9, 0.999, 1e-8, block=512)
        out_r = R.guided_adam_update_ref(w, g, ws, m, v, 5, 0.2, 0.04,
                                         0.9, 0.999, 1e-8)
        for a, b in zip(out_k, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)
        assert all(o.dtype == jnp.float64 for o in out_k)


def test_fused_update_for_rejects_unfused_optimizer():
    from repro.kernels.guided_update.ops import FUSED_OPTIMIZERS, fused_update_for

    assert "adagrad" not in FUSED_OPTIMIZERS
    with pytest.raises(KeyError):
        fused_update_for("adagrad")


# ------------------------------------------------------------------- autotune


def test_autotune_cache_roundtrip(tmp_path):
    """Sweep once (injected deterministic probe), persist, then re-resolve
    from the JSON with NO probe — simulating a fresh process on the same box."""
    from repro.kernels import autotune

    calls = []

    def fake_measure(kernel, dtype, block):
        calls.append(block)
        return abs(block - 32768) + 1.0  # 32k is fastest by construction

    autotune.clear_memo()
    got = autotune.tuned_block("guided_adam_update", jnp.float32,
                               dirname=str(tmp_path), measure=fake_measure)
    assert got == 32768
    assert sorted(calls) == sorted(autotune.CANDIDATES)

    path = autotune.cache_path(str(tmp_path))
    import json
    with open(path) as f:
        data = json.load(f)
    assert data["guided_adam_update.float32"] == 32768

    autotune.clear_memo()  # fresh "process": memo gone, JSON remains
    calls.clear()
    again = autotune.tuned_block("guided_adam_update", jnp.float32,
                                 dirname=str(tmp_path))
    assert again == 32768
    assert calls == []  # served from the persisted winners, no re-sweep

    # and the memo now short-circuits the file read entirely
    assert autotune.tuned_block("guided_adam_update", jnp.float32,
                                dirname=str(tmp_path)) == 32768


def test_autotune_interpret_returns_default_unswept(tmp_path, monkeypatch):
    """On interpret backends (cpu) the sweep is skipped and nothing persists:
    timing the emulator would tune the wrong thing."""
    import os

    from repro.kernels import autotune

    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.clear_memo()
    got = autotune.tuned_block("guided_sgd_update", jnp.float32,
                               dirname=str(tmp_path))
    assert got == autotune.DEFAULT_BLOCK
    assert not os.path.exists(autotune.cache_path(str(tmp_path)))


def test_autotune_tuned_block_drives_kernel_result_identical(tmp_path):
    """The tuned block is a launch parameter only: same numbers at any block."""
    from repro.kernels import autotune
    from repro.kernels.guided_update import kernel as K

    autotune.clear_memo()
    block = autotune.tuned_block(
        "guided_momentum_update", jnp.float32, dirname=str(tmp_path),
        measure=lambda k, d, b: float(b))  # smallest candidate wins
    assert block == min(autotune.CANDIDATES)

    w = randn(1000)
    g = randn(1000) * 0.01
    ws = w + 0.05
    m = jnp.abs(w) * 0.1
    a = K.guided_momentum_update_raw(w, g, ws, m, 0.2, 0.04, 0.9, block=block)
    b = K.guided_momentum_update_raw(w, g, ws, m, 0.2, 0.04, 0.9, block=256)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
