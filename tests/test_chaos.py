"""Chaos harness acceptance (`repro.chaos` + `make chaos`, DESIGN.md §14).

Every test runs a real 2-process live parameter-server fit under one seeded
`ChaosPlan` fault and asserts the run SELF-HEALS: it completes its full step
budget, trains to tolerance of the no-fault baseline, and `Report.dist`
records the remediation that did it (respawns, rejections, quarantines,
rollbacks, reset/bad-frame counts). Thresholds are store versions, not wall
times, so every plan fires deterministically mid-run.

Kept deliberately small (18 server steps per run) so the whole module stays
well under the 90s chaos-gate budget on a loaded CI box.
"""
import numpy as np
import pytest

from repro.chaos import ChaosPlan, slow_disk, truncate_newest
from repro.dist import launcher
from repro.engine import ExperimentSpec

W0_LOSS = 0.6931  # ~ln 2: near-zero initial weights on a binary task


def _toy(n=120, d=5, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    w = rng.standard_normal((d,))
    y = (X @ w > 0).astype(np.int64)
    return X, y, 2

# dist_time_scale paces worker compute (~10ms/step) so version thresholds in
# the middle of the 18-step budget fire before the run drains — same trick
# as test_dist's kill/restart test
COMMON = dict(backend="dist", dist_mode="live", mode="asgd", strategy="none",
              epochs=3, batch_size=16, rho=2, lr=0.2, seed=0, workers=2,
              dist_time_scale=0.01, dist_timeout=60.0)


def _run(spec, chaos=None):
    X, y, k = _toy()
    return launcher.run_local(spec, X, y, k, chaos=chaos)


def _assert_healed(res):
    """The common self-healing bar: full budget, genuinely trained."""
    assert res["n_steps"] == res["schedule"].n_steps > 0
    assert np.isfinite(res["val_loss"])
    assert res["val_loss"] < 0.8 * W0_LOSS


# ------------------------------------------------------------- the plan API


def test_chaos_plan_tables_and_worker_meta():
    plan = ChaosPlan(kills=((0, 6),), resets={1: 5},
                     nan_grad=((0, 4),), corrupt_frame=((1, 3),))
    assert plan.kill_events() == {0: 6}
    assert plan.reset_events() == ((1, 5),)
    assert plan.worker_meta() == {"nan_grad": {0: 4}, "corrupt_frame": {1: 3}}
    assert ChaosPlan().worker_meta() is None
    assert ChaosPlan().kill_events() == {}


def test_truncate_newest_on_empty_dir_is_none(tmp_path):
    assert truncate_newest(str(tmp_path)) is None


# -------------------------------------------------------- the fault matrix


def test_sigkill_mid_run_respawns_and_completes():
    res = _run(ExperimentSpec(**COMMON), ChaosPlan(kills=((0, 6),)))
    _assert_healed(res)
    assert res["dist"]["worker_exits"] >= 1          # the kill landed
    sup = res["dist"]["supervisor"]
    assert sup["respawns"] >= 1                      # ...and was healed
    assert sup["evicted"] == []


def test_connection_reset_recovers():
    # paced 3x slower than the other tests: the reset fires early and the
    # remaining budget must outlast death-detection + respawn backoff, so the
    # respawn demonstrably lands INSIDE the run
    spec = ExperimentSpec(**COMMON).replace(dist_time_scale=0.03)
    res = _run(spec, ChaosPlan(resets=((0, 4),)))
    _assert_healed(res)
    assert res["dist"]["resets"] == 1                # the chief dropped it
    assert res["dist"]["supervisor"]["respawns"] >= 1


def test_corrupt_frame_counted_and_tolerated():
    res = _run(ExperimentSpec(**COMMON), ChaosPlan(corrupt_frame=((1, 4),)))
    _assert_healed(res)
    assert res["dist"]["bad_frames"] >= 1            # dropped, not crashed


def test_nan_gradient_worker_screened_and_quarantined():
    spec = ExperimentSpec(**COMMON).replace(
        sentinel="finite", quarantine_steps=10_000, quarantine_after=2)
    res = _run(spec, ChaosPlan(nan_grad=((0, 4),)))
    _assert_healed(res)                              # worker 1 fills the budget
    d = res["dist"]
    assert d["rejection_reasons"].get("non-finite", 0) >= 2
    assert d["rejections"] >= 2
    assert d["quarantines"] >= 1
    # the poison NEVER reached W: rejections don't bump the version, and the
    # final weights are finite and trained
    assert np.all(np.isfinite(np.asarray(res["model"].W)))


def test_exploding_gradient_rolls_back_to_verified_checkpoint(tmp_path):
    spec = ExperimentSpec(**COMMON).replace(
        sentinel="finite", rollback=True, max_rollbacks=3, lr_backoff=0.5,
        quarantine_steps=10_000, quarantine_after=2,
        ckpt_dir=str(tmp_path), ckpt_every=2, keep_last=0)
    res = _run(spec, ChaosPlan(boom_grad=((0, 8),)))
    d = res["dist"]
    assert d["diverged"] >= 1                        # the detector tripped
    assert d["rollbacks"] >= 1                       # remediated, not fatal
    assert d["lr_scale"] < 1.0                       # lr backoff applied
    assert d["rollback_log"]
    assert d["rollback_log"][0][2] == "post-apply divergence"
    # healed: the full budget completes on finite, trained weights
    assert res["n_steps"] == res["schedule"].n_steps
    assert np.isfinite(res["val_loss"]) and res["val_loss"] < W0_LOSS
    assert np.all(np.isfinite(np.asarray(res["model"].W)))


def test_truncated_checkpoint_mid_run_and_fallback_restore(tmp_path):
    from repro.checkpoint import dist_restore

    d = str(tmp_path)
    spec = ExperimentSpec(**COMMON).replace(
        ckpt_dir=d, ckpt_every=3, keep_last=0)
    res = _run(spec, ChaosPlan(truncate_at=5))       # tear an archive mid-run
    _assert_healed(res)
    # the final snapshot is intact: restore lands on the final version
    snap = dist_restore(d)
    assert int(snap["version"]) == res["n_steps"]
    # now tear the NEWEST archive post-run: dist_restore verifies the
    # checksum, skips it, and falls back to the next intact manifest entry
    torn_step, _path = truncate_newest(d)
    assert torn_step == res["n_steps"]
    snap2 = dist_restore(d)
    assert int(snap2["version"]) < res["n_steps"]
    assert np.all(np.isfinite(snap2["W"]))


def test_slow_disk_writer_does_not_stall_training(tmp_path):
    from repro.checkpoint import read_manifest

    d = str(tmp_path)
    spec = ExperimentSpec(**COMMON).replace(ckpt_dir=d, ckpt_every=2)
    with slow_disk(0.05):
        res = _run(spec)
    _assert_healed(res)                              # async writer absorbed it
    man = read_manifest(d)
    assert man is not None and man["latest"] == res["n_steps"]


def test_compound_chaos_kill_plus_nan_worker():
    """Two faults at once: worker 0 goes NaN (quarantined), worker 1 is
    SIGKILLed (respawned) — the run still completes on the healed fleet."""
    spec = ExperimentSpec(**COMMON).replace(
        sentinel="finite", quarantine_steps=10_000, quarantine_after=2)
    res = _run(spec, ChaosPlan(nan_grad=((0, 4),), kills=((1, 8),)))
    _assert_healed(res)
    d = res["dist"]
    assert d["rejections"] >= 1
    assert d["supervisor"]["respawns"] >= 1
