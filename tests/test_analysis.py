"""repro.analysis tests (DESIGN.md §12): every lint rule catches its seeded
violation and stays quiet on the fixed shape; suppression (inline allows,
baseline) round-trips; the trace auditors (assert_traces / audit_dtypes /
audit_donation) and the dist protocol checks (verb grammar FSM, static verb
audit, ParameterStore lock discipline) each fail on a doctored input and pass
on the real tree. Plus the two retrace gates the subsystem exists to guard:
the ServeEngine decode dispatch and the chunked trainloop dispatch both trace
exactly once across a steady-state run.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    DonationReport,
    TraceCountError,
    apply_baseline,
    assert_traces,
    audit_donation,
    audit_dtypes,
    audit_lock_discipline,
    audit_verbs,
    check_sequence,
    lint_source,
    load_baseline,
    save_baseline,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def rules_of(findings, rule):
    return [f for f in findings if f.rule == rule]


def lint(src, path):
    return lint_source(textwrap.dedent(src), path)


# ------------------------------------------------------------ lint rules


class TestHostSyncRule:
    PATH = "src/repro/serve/engine.py"  # hot scopes: ServeEngine.step = "all"

    def test_sync_in_hot_scope_flagged(self):
        src = """
        class ServeEngine:
            def step(self):
                a = float(x)
                b = np.asarray(y)
                c = jax.device_get(z)
                d = w.item()
        """
        found = rules_of(lint(src, self.PATH), "host-sync-in-hot-loop")
        assert len(found) == 4
        assert {f.line for f in found} == {4, 5, 6, 7}

    def test_cold_function_not_flagged(self):
        src = """
        class ServeEngine:
            def stats(self):
                return float(x)  # setup/teardown path, not a hot scope
        """
        assert rules_of(lint(src, self.PATH), "host-sync-in-hot-loop") == []

    def test_loops_mode_only_flags_loop_bodies(self):
        path = "src/repro/engine/trainloop.py"  # fit = "loops"
        src = """
        def fit(spec):
            setup = np.asarray(w)      # one-time staging: fine
            for step in range(n):
                loss = float(m)        # per-chunk sync: flagged
            return np.asarray(loss)    # teardown: fine
        """
        found = rules_of(lint(src, path), "host-sync-in-hot-loop")
        assert [f.line for f in found] == [5]

    def test_inline_allow_suppresses(self):
        src = """
        class ServeEngine:
            def step(self):
                t = jax.device_get(x)  # lint: allow[host-sync-in-hot-loop] the one batched transfer
        """
        assert rules_of(lint(src, self.PATH), "host-sync-in-hot-loop") == []


class TestJitInLoopRule:
    PATH = "src/repro/foo.py"  # not a donate module: isolates the rule

    def test_jit_in_loop_flagged(self):
        src = """
        def run(fns):
            for fn in fns:
                g = jax.jit(fn)
                h = pl.pallas_call(kernel, out_shape=s)
        """
        found = rules_of(lint(src, self.PATH), "jit-in-loop")
        assert {f.line for f in found} == {4, 5}

    def test_hoisted_jit_clean(self):
        src = """
        def run(fn, xs):
            g = jax.jit(fn)
            for x in xs:
                y = g(x)
        """
        assert rules_of(lint(src, self.PATH), "jit-in-loop") == []


class TestTracedMutationRule:
    PATH = "src/repro/foo.py"

    def test_captured_append_in_jit_target_flagged(self):
        src = """
        acc = []

        @jax.jit
        def f(x):
            acc.append(x)
            return x
        """
        found = rules_of(lint(src, self.PATH), "traced-mutation")
        assert [f.line for f in found] == [6]

    def test_attribute_store_on_param_flagged(self):
        src = """
        @jax.jit
        def f(state, x):
            state.counter = x
            return x
        """
        assert len(rules_of(lint(src, self.PATH), "traced-mutation")) == 1

    def test_name_passed_to_jit_counts_as_traced(self):
        src = """
        def body(x):
            log.append(x)
            return x

        step = jax.jit(body)
        """
        assert len(rules_of(lint(src, self.PATH), "traced-mutation")) == 1

    def test_local_mutation_clean(self):
        src = """
        @jax.jit
        def f(x):
            parts = []
            parts.append(x)
            return parts
        """
        assert rules_of(lint(src, self.PATH), "traced-mutation") == []

    def test_untraced_function_clean(self):
        src = """
        def collect(x):
            acc.append(x)
            return x
        """
        assert rules_of(lint(src, self.PATH), "traced-mutation") == []


class TestF32InF64PathRule:
    def test_f32_literal_in_parity_module_flagged(self):
        src = """
        def widen(x):
            return x.astype(jnp.float32)
        """
        found = rules_of(lint(src, "src/repro/engine/delaysim.py"),
                         "f32-in-f64-path")
        assert len(found) == 1

    def test_f32_string_flagged(self):
        src = """
        def make(shape):
            return np.zeros(shape, dtype='float32')
        """
        assert len(rules_of(lint(src, "src/repro/dist/store.py"),
                            "f32-in-f64-path")) == 1

    def test_promote_types_idiom_allowed(self):
        src = """
        def acc_dtype(w):
            return jnp.promote_types(w.dtype, jnp.float32)
        """
        assert rules_of(lint(src, "src/repro/kernels/guided_update/kernel.py"),
                        "f32-in-f64-path") == []

    def test_non_parity_module_clean(self):
        src = """
        def make(shape):
            return np.zeros(shape, np.float32)
        """
        assert rules_of(lint(src, "src/repro/serve/engine.py"),
                        "f32-in-f64-path") == []


class TestMissingDonateRule:
    PATH = "src/repro/engine/trainloop.py"

    def test_jit_without_donate_flagged(self):
        src = """
        def build(step):
            return jax.jit(step)
        """
        assert len(rules_of(lint(src, self.PATH), "missing-donate")) == 1

    def test_jit_with_donate_clean(self):
        src = """
        def build(step):
            return jax.jit(step, donate_argnums=(0, 1))
        """
        assert rules_of(lint(src, self.PATH), "missing-donate") == []

    def test_non_carry_module_clean(self):
        src = """
        def build(step):
            return jax.jit(step)
        """
        assert rules_of(lint(src, "src/repro/foo.py"), "missing-donate") == []


class TestX64UnscopedJnpRule:
    PATH = "src/repro/dist/store.py"

    def test_unscoped_jnp_flagged(self):
        src = """
        def norm(g):
            return jnp.linalg.norm(g)
        """
        found = rules_of(lint(src, self.PATH), "x64-unscoped-jnp")
        assert len(found) >= 1

    def test_scoped_jnp_clean(self):
        src = """
        def norm(g):
            from jax.experimental import enable_x64
            with enable_x64():
                return jnp.linalg.norm(g)
        """
        assert rules_of(lint(src, self.PATH), "x64-unscoped-jnp") == []

    def test_outside_dist_clean(self):
        src = """
        def norm(g):
            return jnp.linalg.norm(g)
        """
        assert rules_of(lint(src, "src/repro/engine/trainloop.py"),
                        "x64-unscoped-jnp") == []


# ---------------------------------------------------------------- baseline


class TestBaseline:
    SRC = """
    class ServeEngine:
        def step(self):
            a = jax.device_get(x)
    """

    def test_round_trip_suppresses(self, tmp_path):
        findings = lint(self.SRC, "src/repro/serve/engine.py")
        assert findings
        p = tmp_path / "analysis-baseline.json"
        save_baseline(str(p), findings)
        entries = load_baseline(str(p))
        assert entries[0]["count"] == 1 and entries[0]["reason"]
        left, stale = apply_baseline(findings, entries)
        assert left == [] and stale == []

    def test_stale_entry_reported(self, tmp_path):
        findings = lint(self.SRC, "src/repro/serve/engine.py")
        p = tmp_path / "analysis-baseline.json"
        save_baseline(str(p), findings)
        entries = load_baseline(str(p))
        left, stale = apply_baseline([], entries)  # the code was fixed
        assert left == [] and len(stale) == 1

    def test_edited_line_breaks_the_match(self, tmp_path):
        findings = lint(self.SRC, "src/repro/serve/engine.py")
        p = tmp_path / "analysis-baseline.json"
        save_baseline(str(p), findings)
        entries = load_baseline(str(p))
        edited = lint(self.SRC.replace("(x)", "(y)"),
                      "src/repro/serve/engine.py")
        left, stale = apply_baseline(edited, entries)
        assert len(left) == 1 and len(stale) == 1

    def test_committed_baseline_matches_tree(self):
        """The repo's own baseline is live: every entry covers a finding that
        still exists (no stale debt) and the reasons are filled in."""
        entries = load_baseline(os.path.join(REPO, "analysis-baseline.json"))
        for e in entries:
            assert "TODO" not in e["reason"], e


def test_cli_clean_on_repo_tree():
    """`python -m repro.analysis src/` (the `make lint` gate) exits 0 on the
    committed tree with the committed baseline."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO, env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_fails_and_names_the_finding(tmp_path):
    bad = tmp_path / "src" / "repro" / "dist" / "hot.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(g):\n    return jnp.sum(g)\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-protocol",
         str(bad)],
        cwd=REPO, env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1
    assert "x64-unscoped-jnp" in proc.stdout
    assert "hot.py:2:" in proc.stdout  # file:line for jump-to-source


# ------------------------------------------------------------ assert_traces


class TestAssertTraces:
    def test_counts_jitted_cache_growth(self):
        f = jax.jit(lambda x: x * 2)
        with assert_traces(2, f):
            f(jnp.zeros(3))
            f(jnp.zeros(3))   # cache hit: free
            f(jnp.zeros(4))   # new shape: one more trace

    def test_mismatch_raises_with_breakdown(self):
        f = jax.jit(lambda x: x + 1)
        with pytest.raises(TraceCountError, match="expected exactly 1"):
            with assert_traces(1, f):
                f(jnp.zeros(3))
                f(jnp.zeros((2, 2)))

    def test_holder_attr_target_counts_and_restores(self):
        class Holder:
            @staticmethod
            def fwd(x):
                return x * 3

        original = Holder.fwd
        with assert_traces(1, (Holder, "fwd")):
            jax.jit(lambda x: Holder.fwd(x))(jnp.zeros(3))
        assert Holder.fwd is original

    def test_no_targets_rejected(self):
        with pytest.raises(ValueError):
            with assert_traces(1):
                pass


# ------------------------------------------------------------- audit_dtypes


class TestAuditDtypes:
    def test_seeded_demotion_found(self):
        from jax.experimental import enable_x64

        def leaky(x):
            return jnp.sum(x.astype(jnp.float32))

        with enable_x64():
            viol = audit_dtypes(leaky, jnp.zeros(4, jnp.float64))
        assert viol and viol[0].primitive == "convert_element_type"
        assert "float64" in viol[0].in_dtypes

    def test_demotion_inside_scan_found(self):
        from jax.experimental import enable_x64

        def loop(x):
            def body(c, _):
                return c.astype(jnp.float32).astype(jnp.float64), ()
            c, _ = jax.lax.scan(body, x, None, length=3)
            return c

        with enable_x64():
            viol = audit_dtypes(loop, jnp.zeros(2, jnp.float64))
        assert viol and "scan" in viol[0].path

    def test_f64_preserving_fn_clean(self):
        from jax.experimental import enable_x64

        with enable_x64():
            viol = audit_dtypes(lambda x: jnp.sum(x * 2.0),
                                jnp.zeros(4, jnp.float64))
        assert viol == []

    def test_guided_update_refs_preserve_f64(self):
        """The paper's update rules stay float64 end to end — the runtime
        twin of the f32-in-f64-path lint rule."""
        from jax.experimental import enable_x64

        from repro.kernels.guided_update import ref as R

        with enable_x64():
            w = jnp.ones((8, 4), jnp.float64)
            g = jnp.full((8, 4), .5, jnp.float64)
            assert audit_dtypes(R.guided_sgd_update_ref,
                                w, g, w * .9, 1e-2, .5) == []
            assert audit_dtypes(R.guided_adam_update_ref, w, g, w * .9,
                                w * 0, w * 0, 3, 1e-2, .5, .9, .999, 1e-8) == []


# ----------------------------------------------------------- audit_donation


class TestAuditDonation:
    def test_reports_large_non_donated_args(self):
        params = {"w": np.zeros((256, 256), np.float32)}   # 256 KiB
        gstate = (np.zeros((128, 256), np.float32),)       # 128 KiB
        batch = np.zeros((128, 128), np.float32)           #  64 KiB
        reports = audit_donation([params, gstate, batch], donate_argnums=(0, 1),
                                 names=["params", "gstate", "batch"])
        assert [r.name for r in reports] == ["batch"]  # consumed, not carried

    def test_forgotten_donation_names_the_carry(self):
        params = {"w": np.zeros((256, 256), np.float32)}
        reports = audit_donation([params], donate_argnums=())
        assert reports == [DonationReport(argnum=0, name="arg0",
                                          nbytes=256 * 256 * 4)]
        assert "not donated" in reports[0].format()

    def test_small_args_below_threshold_ignored(self):
        assert audit_donation([np.zeros(4, np.float32)]) == []


# ----------------------------------------------------------- verb grammar


LEGAL_REPLAY = ["hello", "welcome", "pull", "work", "push", "applied",
                "pull", "done", "bye"]
LEGAL_LIVE = ["hello", "welcome", "step", "work", "step", "done", "bye"]


class TestCheckSequence:
    def test_legal_replay_and_live(self):
        assert check_sequence(LEGAL_REPLAY, "replay") == []
        assert check_sequence(LEGAL_LIVE, "live") == []

    def test_push_before_pull_illegal(self):
        viol = check_sequence(["hello", "welcome", "push"], "replay",
                              require_closed=False)
        assert len(viol) == 1
        assert viol[0].verb == "push" and viol[0].state == "ready"
        assert "pull" in viol[0].allowed

    def test_unknown_verb_illegal(self):
        viol = check_sequence(["hello", "poke"], "replay",
                              require_closed=False)
        assert viol and viol[0].verb == "poke"

    def test_unclosed_conversation_flagged(self):
        viol = check_sequence(["hello", "welcome", "pull"], "replay")
        assert viol and viol[-1].verb == "<end>"

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError):
            check_sequence([], mode="chaos")


GOOD_WORKER = """
def run(sock):
    sock.send(("hello", 0))
    sock.send(("pull",))
    sock.send(("push", g, v))
    sock.send(("step", g, v, rows))
    sock.send(("bye",))
"""
GOOD_CHIEF = """
def serve(conn):
    verb = conn.recv()[0]
    if verb == "hello":
        conn.send(("welcome", cfg))
    elif verb == "pull":
        conn.send(("work", t) if t is not None else ("done",))
    elif verb == "push":
        conn.send(("applied", s))
    elif verb == "step":
        conn.send(("work", t) if t is not None else ("done",))
    elif verb == "bye":
        pass
"""


class TestAuditVerbs:
    def test_real_dist_sources_conform(self):
        assert audit_verbs(root=SRC) == []

    def test_fixture_sources_conform(self):
        assert audit_verbs(sources={"worker": GOOD_WORKER,
                                    "chief": GOOD_CHIEF}) == []

    def test_typoed_wire_verb_caught(self):
        doctored = GOOD_WORKER.replace('("pull",)', '("pulll",)')
        msgs = audit_verbs(sources={"worker": doctored, "chief": GOOD_CHIEF})
        assert any("pulll" in m for m in msgs)            # novel verb sent
        assert any("never sends 'pull'" in m for m in msgs)

    def test_unhandled_worker_verb_caught(self):
        deaf = GOOD_CHIEF.replace('elif verb == "push":', 'elif _ == 0:')
        msgs = audit_verbs(sources={"worker": GOOD_WORKER, "chief": deaf})
        assert any("never dispatches on worker verb 'push'" in m for m in msgs)


# -------------------------------------------------------- lock discipline


BAD_STORE = """
class ParameterStore:
    def __init__(self):
        self.cond = threading.Condition()
        self.version = 0
        self.staleness = []

    def push(self, s):
        self.staleness.append(s)    # lock-free container mutation
        self.version += 1

    def locked_push(self, s):
        with self.cond:
            self.staleness.append(s)
            self.version += 1

    def _helper_no_callers(self):
        self.version += 1
"""


class TestLockDiscipline:
    def test_real_store_conforms(self):
        assert audit_lock_discipline(root=SRC) == []

    def test_lock_free_public_mutation_caught(self):
        viol = audit_lock_discipline(source=BAD_STORE)
        by_method = {v.method: v for v in viol}
        assert "push" in by_method
        assert by_method["push"].attr in ("staleness", "version")
        assert "locked_push" not in by_method

    def test_orphan_helper_caught(self):
        viol = audit_lock_discipline(source=BAD_STORE)
        assert any(v.method == "_helper_no_callers" for v in viol)

    def test_helper_with_locked_callers_accepted(self):
        src = BAD_STORE.replace(
            "    def push(self, s):\n"
            "        self.staleness.append(s)    # lock-free container mutation\n"
            "        self.version += 1\n",
            "    def push(self, s):\n"
            "        with self.cond:\n"
            "            self._helper_no_callers()\n")
        viol = audit_lock_discipline(source=src)
        assert viol == []


# ------------------------------------------------------------ retrace gates


def test_serve_decode_traces_once():
    """Steady-state decode is ONE program: a full mixed-length run may grow
    the prefill caches but must trace the decode dispatch exactly once."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.module import split_params
    from repro.serve import Request, ServeEngine

    cfg = get_config("minicpm-2b").reduced()
    params = split_params(T.model_init(jax.random.PRNGKey(0), cfg))[0]
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, (L,)).tolist(),
                    max_new_tokens=4, request_id=i)
            for i, L in enumerate([5, 9, 12, 7])]
    engine = ServeEngine(params, cfg, max_batch=2, max_len=32)
    with assert_traces(1, engine._step):
        engine.run(reqs)


def test_chunked_dispatch_traces_once_per_shape():
    """Same-shape chunk blocks reuse one compiled program; only a new chunk
    size (the uneven tail) may add a trace."""
    from repro.engine.trainloop import build_chunk_step

    def step_fn(params, gstate, batch):
        loss = jnp.sum((params - batch) ** 2)
        return params - 0.1 * batch, gstate + 1, {"loss": loss}

    dispatch = jax.jit(build_chunk_step(step_fn), donate_argnums=(0, 1))
    params, gstate = jnp.zeros(8), jnp.zeros(())
    with assert_traces(1, dispatch):
        for seed in range(3):  # three same-shape (4, 8) blocks
            block = jnp.full((4, 8), float(seed))
            params, gstate, m = dispatch(params, gstate, block)
    with assert_traces(1, dispatch):  # the (2, 8) tail compiles once more
        params, gstate, m = dispatch(params, gstate, jnp.ones((2, 8)))


# ------------------------------------------------- lockset pass (DESIGN §13)


def locks(src, path="src/repro/x.py"):
    from repro.analysis.locks import analyze_source

    return analyze_source(textwrap.dedent(src), path)


class TestLocksPass:
    def test_unlocked_shared_write_flagged(self):
        findings, models = locks("""
        import threading

        class Buf:
            def __init__(self):
                self.lock = threading.Lock()
                self.items = []
                self.n = 0
                threading.Thread(target=self._work).start()

            def _work(self):
                self.items.append(1)   # worker thread, no lock

            def take(self):
                with self.lock:
                    return self.items.pop()
        """)
        assert [m.name for m in models] == ["Buf"]
        hits = rules_of(findings, "lock-shared-unlocked")
        assert len(hits) == 1
        assert "Buf.items" in hits[0].message and "_work" in hits[0].message

    def test_inconsistent_locks_flagged(self):
        findings, _ = locks("""
        import threading

        class Split:
            def __init__(self):
                self.a = threading.Lock()
                self.b = threading.Lock()
                self.n = 0
                threading.Thread(target=self.grow).start()

            def grow(self):
                with self.a:
                    self.n += 1

            def read(self):
                with self.b:
                    return self.n
        """)
        hits = rules_of(findings, "lock-inconsistent")
        assert len(hits) == 1
        assert "no common member" in hits[0].message

    def test_lock_order_cycle_flagged(self):
        findings, _ = locks("""
        import threading

        class AB:
            def __init__(self):
                self.a = threading.Lock()
                self.b = threading.Lock()
                threading.Thread(target=self.fwd).start()

            def fwd(self):
                with self.a:
                    with self.b:
                        pass

            def rev(self):
                with self.b:
                    with self.a:
                        pass
        """)
        hits = rules_of(findings, "lock-order-cycle")
        assert len(hits) == 1
        assert "AB.a" in hits[0].message and "AB.b" in hits[0].message

    def test_disciplined_class_clean(self):
        findings, models = locks("""
        import threading

        class Clean:
            def __init__(self):
                self.cond = threading.Condition()
                self.n = 0
                threading.Thread(target=self._work).start()

            def _work(self):
                with self.cond:
                    self.n += 1
                    self.cond.notify_all()

            def wait_done(self):
                with self.cond:
                    self.cond.wait_for(lambda: self.n > 0)
                    return self.n
        """)
        assert findings == []
        assert models[0].lock_attrs == {"cond"}

    def test_helper_inherits_callers_lock(self):
        # _bump is only ever called with the lock held: entry-lockset
        # propagation proves the unlocked-looking write safe
        findings, _ = locks("""
        import threading

        class Via:
            def __init__(self):
                self.lock = threading.Lock()
                self.n = 0
                threading.Thread(target=self.work).start()

            def work(self):
                with self.lock:
                    self._bump()

            def _bump(self):
                self.n += 1
        """)
        assert findings == []

    def test_single_threaded_class_ignored(self):
        _, models = locks("""
        class Plain:
            def __init__(self):
                self.n = 0

            def bump(self):
                self.n += 1
        """)
        assert models == []

    def test_real_tree_is_clean(self):
        from repro.analysis.locks import run_locks

        findings, models = run_locks([SRC])
        assert findings == [], [f.format() for f in findings]
        # the four concurrent classes the repo actually has are discovered
        names = {m.name for m in models}
        assert {"ParameterStore", "Chief", "ChunkPrefetcher",
                "AsyncCheckpointer"} <= names

    def test_cross_class_order_is_acyclic_on_real_tree(self):
        from repro.analysis.locks import find_cycles, lock_order_graph, run_locks

        _, models = run_locks([SRC])
        assert find_cycles(lock_order_graph(models)) == []


class TestLockNotWithRule:
    PATH = "src/repro/data/prefetch.py"

    def test_bare_acquire_release_flagged(self):
        src = """
        class P:
            def step(self):
                self.lock.acquire()
                self.n += 1
                self.lock.release()
        """
        hits = rules_of(lint(src, self.PATH), "lock-not-with")
        assert len(hits) == 2

    def test_with_statement_clean(self):
        src = """
        class P:
            def step(self):
                with self.lock:
                    self.n += 1
        """
        assert rules_of(lint(src, self.PATH), "lock-not-with") == []

    def test_inline_allow(self):
        src = """
        class P:
            def step(self):
                self.lock.acquire()  # lint: allow[lock-not-with] handoff
        """
        assert rules_of(lint(src, self.PATH), "lock-not-with") == []
