"""Data pipeline tests: UCI analogs, IQR filter, token stream."""
import numpy as np
import pytest
from hypcompat import given, settings, st  # hypothesis, or skip-stubs when absent

from repro.data import DATASETS, iqr_filter, load_dataset, train_test_split
from repro.data.tokens import synthetic_lm_batches, make_batch_for
from repro.data.uci_analogs import SPECS


@pytest.mark.parametrize("name", DATASETS)
def test_dataset_shapes_and_balance(name):
    X, y, k = load_dataset(name, seed=0)
    base = name.removesuffix("_filtered")
    spec = SPECS[base]
    assert X.shape[1] == spec.d
    assert k == spec.classes
    assert set(np.unique(y)) <= set(range(k))
    if not name.endswith("_filtered"):
        assert len(X) == spec.n
        # class balance within 12% of spec priors (flips move a few labels)
        fr = np.bincount(y, minlength=k) / len(y)
        np.testing.assert_allclose(fr, spec.priors, atol=0.12)


def test_determinism():
    X1, y1, _ = load_dataset("pima", seed=0)
    X2, y2, _ = load_dataset("pima", seed=0)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)
    X3, _, _ = load_dataset("pima", seed=1)
    assert not np.array_equal(X1, X3)


def test_iqr_filter_removes_only_outliers():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((500, 4))
    X[:10] += 100.0  # gross outliers
    y = rng.integers(0, 2, 500)
    Xf, yf = iqr_filter(X, y)
    assert len(Xf) < len(X)
    assert np.max(np.abs(Xf)) < 50.0
    # filtered output is a subset of rows
    assert len(Xf) == len(yf)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_split_is_stratified_and_disjoint(seed):
    X, y, k = load_dataset("new_thyroid", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=seed)
    assert len(Xtr) + len(Xte) == len(X)
    # every class appears in the test fold
    assert set(np.unique(yte)) == set(np.unique(y))


def test_token_stream_is_learnable_markov():
    it = synthetic_lm_batches(vocab=64, seq_len=32, global_batch=4, seed=0, n_corpora=2)
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # successor entropy is lower than uniform (structure exists)
    toks = np.concatenate([next(it)["tokens"].ravel() for _ in range(5)])
    assert len(np.unique(toks)) > 8


def test_make_batch_for_every_family():
    from repro.configs import all_configs

    for name, cfg in all_configs().items():
        r = cfg.reduced()
        b = make_batch_for(r, 16, 2, seed=0)
        if r.audio_frontend:
            assert b["frames"].shape == (2, 16, r.d_model)
        else:
            assert b["tokens"].shape == (2, 16)
            assert b["tokens"].max() < r.vocab_size
        if r.arch_type == "vlm":
            assert b["patches"].shape == (2, r.n_patches, r.d_model)
