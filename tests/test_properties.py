"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # hypothesis, or skip-stubs when absent

from repro.configs import get_config
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.module import split_params


# ------------------------------------------------------------- attention


@given(st.integers(0, 62), st.integers(1, 4))
@settings(max_examples=12, deadline=None)
def test_causality_future_perturbation_invariance(pos, head_mult):
    """Perturbing token t+1.. must not change causal-attention outputs at <=t."""
    rng = np.random.default_rng(0)
    B, S, H, dh = 1, 64, 2 * head_mult, 16
    K = H
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    out1 = L.attention(q, k, v, n_kv_heads=K, causal=True)
    k2 = k.at[:, pos + 1 :].add(3.0)
    v2 = v.at[:, pos + 1 :].add(-2.0)
    out2 = L.attention(q, k2, v2, n_kv_heads=K, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, : pos + 1]), np.asarray(out2[:, : pos + 1]),
                               atol=1e-5)


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_attention_rows_are_convex_combinations(seed):
    """Each attention output is a convex combination of V rows: max bound."""
    rng = np.random.default_rng(seed)
    B, S, H, dh = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    out = np.asarray(L.attention(q, k, v, n_kv_heads=H, causal=False))
    vmax = np.asarray(v).max()
    vmin = np.asarray(v).min()
    assert out.max() <= vmax + 1e-5 and out.min() >= vmin - 1e-5


# ------------------------------------------------------------------- moe


@given(st.integers(0, 1000), st.sampled_from([1.0, 1.25, 4.0]))
@settings(max_examples=15, deadline=None)
def test_moe_token_conservation(seed, cf):
    """Every (token, expert) assignment within capacity contributes exactly
    once; with identity experts and unit weights the output equals the input
    scaled by the number of surviving assignments."""
    cfg = get_config("qwen3_moe_235b_a22b").reduced()
    E, k = cfg.moe.n_experts, cfg.moe.topk
    d = cfg.d_model
    rng = np.random.default_rng(seed)
    N = 32
    x = jnp.asarray(rng.standard_normal((N, d)), jnp.float32)
    params, _ = split_params(MOE.moe_init(jax.random.PRNGKey(seed % 7), cfg))
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)

    y, aux = MOE.moe_apply_local(params, x, cfg, capacity_factor=cf)
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) >= 0.0
    # aux loss lower bound: E * sum(f_e/k * P_e) >= 1 at perfect balance is
    # aux_weight; it can't be below aux_weight * (something >= 1/E * E...) --
    # just check the Switch bound aux >= aux_weight * 1.0 * (1/E) * E * ... >= 0
    # and upper bound when everything routes to one expert:
    assert float(aux) <= cfg.moe.router_aux_weight * E + 1e-6


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_moe_no_drop_when_capacity_full(seed):
    """With capacity_factor=E no assignment can be dropped: the combine
    weights per token must sum to ~1 (router weights are renormalized)."""
    cfg = get_config("grok_1_314b").reduced()
    d = cfg.d_model
    rng = np.random.default_rng(seed)
    N = 16
    x = jnp.asarray(rng.standard_normal((N, d)), jnp.float32)
    params, _ = split_params(MOE.moe_init(jax.random.PRNGKey(1), cfg))
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)

    # identity-like probe: replace expert weights so each expert computes
    # SiLU(x*0 + 1)*1 ... simpler: verify via the dispatch internals
    gate_logits = x @ params["router"]
    w, eid, probs = MOE.route(gate_logits, cfg.moe.topk)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)


# ---------------------------------------------------------------- guided


@given(st.lists(st.floats(0, 100), min_size=2, max_size=32), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_correction_weights_scale_invariance(scores, k):
    """Weights depend only on score ranking/ratios: scaling all scores by a
    positive constant leaves them unchanged."""
    from repro.core.guided import GuidedConfig, correction_weights

    gcfg = GuidedConfig(max_consistent=k)
    s = jnp.asarray(scores, jnp.float32)
    w1 = np.asarray(correction_weights(s, gcfg))
    w2 = np.asarray(correction_weights(s * 7.3, gcfg))
    np.testing.assert_allclose(w1, w2, atol=1e-6)


@given(st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_microbatch_split_partitions_batch(c):
    from repro.train.steps import _microbatches

    B = c * 4
    x = jnp.arange(B * 3).reshape(B, 3)
    mbs = _microbatches({"x": x}, n_micro=2, c=c)["x"]
    # all rows present exactly once across microbatches
    got = np.sort(np.asarray(mbs).reshape(-1, 3)[:, 0])
    np.testing.assert_array_equal(got, np.sort(np.asarray(x)[:, 0]))
    # each microbatch holds an equal share of each worker's rows
    per_worker = np.asarray(mbs[0])[:, 0].reshape(c, -1)
    assert per_worker.shape[1] == 2


# ------------------------------------------------- dist protocol (DESIGN §13)

from repro.analysis import LIVE_FSM, REPLAY_FSM, check_sequence

_ALPHABET = sorted({v for fsm in (REPLAY_FSM, LIVE_FSM) for _s, v in fsm})


def _legal_trace(rng, mode, cap=40):
    """Random walk over the mode's FSM from init to closed: legal by
    construction. Past `cap` verbs the walk prefers the draining branch so
    it always terminates."""
    fsm = REPLAY_FSM if mode == "replay" else LIVE_FSM
    state, verbs = "init", []
    while state != "closed":
        allowed = sorted(v for (s, v) in fsm if s == state)
        if len(verbs) >= cap and "done" in allowed:
            verb = "done"
        else:
            verb = allowed[rng.integers(len(allowed))]
        verbs.append(verb)
        state = fsm[(state, verb)]
    return verbs


def _mutate_one_verb(rng, verbs, mode):
    """Replace verbs[i] with a verb illegal in the state reached at i.
    Returns (mutated, i, bad_verb)."""
    fsm = REPLAY_FSM if mode == "replay" else LIVE_FSM
    i = int(rng.integers(len(verbs)))
    state = "init"
    for v in verbs[:i]:
        state = fsm[(state, v)]
    illegal = [v for v in _ALPHABET if (state, v) not in fsm]
    bad = illegal[rng.integers(len(illegal))]
    return verbs[:i] + [bad] + verbs[i + 1:], i, bad


@given(st.integers(0, 10**6), st.sampled_from(["replay", "live"]))
@settings(max_examples=60, deadline=None)
def test_generated_legal_traces_always_pass(seed, mode):
    rng = np.random.default_rng(seed)
    assert check_sequence(_legal_trace(rng, mode), mode) == []


@given(st.integers(0, 10**6), st.sampled_from(["replay", "live"]))
@settings(max_examples=60, deadline=None)
def test_single_verb_mutation_is_rejected_at_its_index(seed, mode):
    rng = np.random.default_rng(seed)
    trace = _legal_trace(rng, mode)
    mutated, i, bad = _mutate_one_verb(rng, trace, mode)
    viol = check_sequence(mutated, mode, require_closed=False)
    assert viol, f"mutation {bad!r}@{i} not rejected: {mutated}"
    assert viol[0].index == i and viol[0].verb == bad


# seeded twins: the same properties on a fixed sweep, so the contract stays
# exercised when hypothesis is absent (it is not on the image)


@pytest.mark.parametrize("mode", ["replay", "live"])
def test_seeded_legal_traces_always_pass(mode):
    for seed in range(50):
        rng = np.random.default_rng(seed)
        trace = _legal_trace(rng, mode)
        assert check_sequence(trace, mode) == [], (seed, trace)


@pytest.mark.parametrize("mode", ["replay", "live"])
def test_seeded_single_verb_mutations_rejected(mode):
    for seed in range(50):
        rng = np.random.default_rng(seed)
        trace = _legal_trace(rng, mode)
        mutated, i, bad = _mutate_one_verb(rng, trace, mode)
        viol = check_sequence(mutated, mode, require_closed=False)
        assert viol and viol[0].index == i and viol[0].verb == bad, (
            seed, mode, i, bad, mutated)
