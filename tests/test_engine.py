"""Tests for the unified repro.engine API: spec round-trips, the
DelayCompensator registry, and step-for-step parity of the Trainer mesh path
with the legacy build_train_step loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.guided import GuidedConfig
from repro.core.parameter_server import ALGO_NAMES, PSConfig
from repro.engine import (
    ALGOS,
    DelayCompensator,
    ExperimentSpec,
    Trainer,
    compensator_names,
    get_compensator,
    register_compensator,
    strategy_name_for,
)


# ------------------------------------------------------------- spec round-trip


@pytest.mark.parametrize("mode", ["seq", "ssgd", "asgd"])
@pytest.mark.parametrize("guided", [False, True])
@pytest.mark.parametrize("optimizer", ["sgd", "rmsprop", "adagrad"])
def test_ps_config_roundtrip(mode, guided, optimizer):
    cfg = PSConfig(mode=mode, guided=guided, optimizer=optimizer,
                   lr=0.11, epochs=7, rho=5, batch_size=8, max_consistent=3, seed=9)
    spec = ExperimentSpec.from_ps_config(cfg)
    assert spec.backend == "sim"
    assert spec.to_ps_config() == cfg


@pytest.mark.parametrize("mode,guided,correction", [
    ("seq", False, "fused"),
    ("ssgd", True, "fused"),
    ("ssgd", True, "two_pass"),
    ("asgd", False, "fused"),
    ("asgd", True, "fused"),
    ("dc_asgd", False, "fused"),
    ("dc_asgd", True, "fused"),
])
def test_guided_config_roundtrip(mode, guided, correction):
    gcfg = GuidedConfig(mode=mode, guided=guided, correction=correction,
                        rho=7, max_consistent=2, staleness=3, dc_lambda=0.1)
    spec = ExperimentSpec.from_guided_config(gcfg)
    assert spec.backend == "mesh"
    back = spec.to_guided_config()
    # guided=False leaves correction at its default; compare semantic fields
    assert back.mode == gcfg.mode
    assert back.guided == gcfg.guided
    assert back.rho == gcfg.rho
    assert back.max_consistent == gcfg.max_consistent
    assert back.staleness == gcfg.staleness
    assert back.dc_lambda == gcfg.dc_lambda
    if gcfg.guided:
        assert back.correction == gcfg.correction


def test_algo_table_matches_parameter_server():
    """Spec's algorithm table lowers to the exact PSConfig of every paper name."""
    inv = {v: k for k, v in ALGO_NAMES.items()}
    for name, (mode, guided, opt) in inv.items():
        spec = ExperimentSpec.for_algo(name)
        cfg = spec.to_ps_config()
        assert (cfg.mode, cfg.guided, cfg.optimizer) == (mode, guided, opt), name
    assert set(inv) <= set(ALGOS)


def test_sim_rejects_mesh_only_strategy():
    with pytest.raises(ValueError, match="parameter-server"):
        ExperimentSpec(backend="sim", mode="asgd", strategy="dc_asgd").to_ps_config()


def test_for_algo_defaults_every_name_to_a_runnable_backend():
    for name in ALGOS:
        spec = ExperimentSpec.for_algo(name)
        Trainer.from_spec(spec)  # must validate, whatever backend it picked
    assert ExperimentSpec.for_algo("DC-ASGD").backend == "mesh"
    assert ExperimentSpec.for_algo("gSSGD").backend == "sim"


def test_strategy_name_is_authoritative_over_gcfg_flags():
    """Explicitly selecting guided_fused must correct even when the
    GuidedConfig flags would say otherwise (no silent no-op)."""
    import jax.numpy as jnp

    from repro.engine import get_compensator

    gcfg = GuidedConfig(mode="ssgd", guided=False, rho=1, correction="two_pass")
    strat = get_compensator("guided_fused", gcfg)
    state_like = type("S", (), {})()
    state_like.step = jnp.asarray(0)
    state_like.score = jnp.asarray([3.0, 1.0])
    w = np.asarray(strat.correction_weights(state_like, 2))
    assert w.sum() > 0  # rho=1: every step is a window end


# ----------------------------------------------------------------- registry


def test_registry_lookup_and_unknown_name():
    gcfg = GuidedConfig()
    stale_gcfg = GuidedConfig(mode="asgd")  # gap_aware requires stale weights
    for name in ("none", "guided_fused", "guided_two_pass", "dc_asgd",
                 "dc_asgd_guided", "gap_aware"):
        assert name in compensator_names()
        got = get_compensator(name, stale_gcfg if name == "gap_aware" else gcfg)
        assert got.name == name
    with pytest.raises(KeyError, match="registered:"):
        get_compensator("does_not_exist", gcfg)


def test_strategy_name_for_legacy_flags():
    assert strategy_name_for(GuidedConfig(guided=False)) == "none"
    assert strategy_name_for(GuidedConfig(guided=True, correction="fused")) == "guided_fused"
    assert strategy_name_for(GuidedConfig(guided=True, correction="two_pass")) == "guided_two_pass"
    assert strategy_name_for(GuidedConfig(mode="dc_asgd", guided=False)) == "dc_asgd"
    assert strategy_name_for(GuidedConfig(mode="dc_asgd", guided=True)) == "dc_asgd_guided"


def test_gap_aware_rejects_modes_without_stale_weights():
    with pytest.raises(ValueError, match="asgd"):
        get_compensator("gap_aware", GuidedConfig(mode="ssgd"))
    with pytest.raises(ValueError, match="asgd"):
        Trainer.from_spec(ExperimentSpec(backend="mesh", mode="ssgd", strategy="gap_aware"))


def test_engine_import_stays_numpy_light():
    """Sim-only scripts must not pay the jax import cost (lazy re-exports)."""
    import subprocess, sys
    code = (
        "import sys\n"
        "from repro.engine import ExperimentSpec, Trainer\n"
        "spec = ExperimentSpec.for_algo('gSSGD')\n"
        "Trainer.from_spec(spec)\n"
        "assert 'jax' not in sys.modules, 'jax imported on the sim-only path'\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]


def test_cli_dc_asgd_guided_combo_keeps_guided_hooks():
    """--mode dc_asgd --guided must lower to the composed strategy (the legacy
    flags applied BOTH the Taylor compensation and the fused replay)."""
    import argparse
    from repro.launch.train import spec_from_args, main as train_main  # noqa: F401

    ns = argparse.Namespace(
        arch="yi_9b", reduced=True, layers=0, d_model=0, d_ff=0, steps=4, seq=16,
        batch=4, mode="dc_asgd", guided=True, strategy="", rho=2, optimizer="sgd",
        lr=0.01, schedule="constant", mesh="local", workers=2, micro=1,
        chunk_steps=1, prefetch=False, seed=0,
        ckpt_dir="", ckpt_every=0, keep_last=3,
    )
    spec = spec_from_args(ns)
    assert spec.strategy == "dc_asgd_guided" and spec.mode == "asgd"
    gcfg = spec.to_guided_config()
    assert gcfg.mode == "dc_asgd" and gcfg.guided and gcfg.correction == "fused"


def test_register_custom_strategy_selectable_by_name():
    @register_compensator("test_half_grads")
    class HalfGrads(DelayCompensator):
        def compensate_grads(self, grads, params, state):
            return jax.tree.map(lambda g: g * 0.5, grads)

    gcfg = GuidedConfig(mode="ssgd", guided=False)
    got = get_compensator("test_half_grads", gcfg)
    assert isinstance(got, HalfGrads)
    g = got.compensate_grads({"w": jnp.ones(2)}, None, None)
    np.testing.assert_allclose(np.asarray(g["w"]), 0.5)


def test_custom_strategy_with_array_extra_state():
    """A plugin whose init() returns a bare array (not a tuple) must train:
    the extra state threads through GuidedState across steps."""

    @register_compensator("test_grad_norm_ema")
    class GradNormEma(DelayCompensator):
        def init(self, params, n_workers):
            return jnp.zeros(())

        def update_extra(self, state, grads):
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            return 0.9 * state.extra + 0.1 * gn

    spec = ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode="ssgd",
        strategy="test_grad_norm_ema", rho=2, lr=1e-2, seed=0, steps=3,
        seq_len=16, global_batch=4, workers=2,
    )
    report = Trainer.from_spec(spec).fit()
    assert float(report.state.extra) > 0.0  # EMA accumulated across steps
    assert all(np.isfinite(h["loss"]) for h in report.history)


# --------------------------------------------------------------- mesh parity


def _legacy_losses(cfg, gcfg, n_steps, batches):
    from repro.optim import constant, get_optimizer
    from repro.train import steps as S
    from repro.sharding.rules import LOCAL_CTX

    opt = get_optimizer("sgd")
    params, _, gstate = S.make_train_state(jax.random.PRNGKey(3), cfg, gcfg, opt, n_workers=2)
    step = jax.jit(S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(1e-2), n_workers=2))
    losses = []
    for b in batches:
        params, gstate, m = step(params, gstate, b)
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.parametrize("strategy,mode", [
    ("guided_fused", "ssgd"),
    ("dc_asgd", "asgd"),
    ("dc_asgd_guided", "asgd"),
])
def test_trainer_matches_legacy_step_for_step(strategy, mode):
    """Trainer.from_spec on the mesh path reproduces build_train_step losses."""
    from repro.data import make_batch_for

    spec = ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode=mode, strategy=strategy,
        rho=2, lr=1e-2, seed=3, steps=5, seq_len=16, global_batch=4, workers=2,
        optimizer="sgd", schedule="constant",
    )
    cfg = spec.model_config()
    batches = [
        {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 16, 4, seed=i).items()}
        for i in range(5)
    ]
    legacy = _legacy_losses(cfg, spec.to_guided_config(), 5, batches)
    report = Trainer.from_spec(spec).fit(data=[dict(b) for b in batches])
    got = [h["loss"] for h in report.history]
    np.testing.assert_allclose(got, legacy, rtol=0, atol=0)
    assert report.backend == "mesh"
    assert report.final_loss == got[-1]
    assert report.state is not None


def test_gap_aware_runs_and_dampens():
    """The plugin strategy runs end-to-end and differs from plain ASGD."""
    base = ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode="asgd", strategy="none",
        rho=2, staleness=2, lr=5e-2, seed=0, steps=6, seq_len=16, global_batch=4,
        workers=2, optimizer="sgd", schedule="constant",
    )
    r_plain = Trainer.from_spec(base).fit()
    r_gap = Trainer.from_spec(base.replace(strategy="gap_aware")).fit()
    a = [h["loss"] for h in r_plain.history]
    b = [h["loss"] for h in r_gap.history]
    assert a[0] == b[0]  # first step: w_stale == params, no gap yet
    assert a[2:] != b[2:]  # dampening changes the trajectory once a gap exists
    assert all(np.isfinite(b))


def test_trainer_sim_backend_matches_train_ps():
    from repro.core.parameter_server import algo_config, train_ps
    from repro.data import load_dataset, train_test_split

    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=2)
    legacy = train_ps(Xtr[:200], ytr[:200], k, algo_config("gSSGD", epochs=2, seed=2), Xte, yte)
    rep = Trainer.from_spec(ExperimentSpec.for_algo("gSSGD", epochs=2, seed=2)).fit(
        (Xtr[:200], ytr[:200], k, Xte, yte))
    assert rep.test_accuracy == legacy["test_accuracy"]
    assert rep.val_loss == legacy["val_loss"]
    assert rep.history == legacy["history"]


def test_mesh_global_batch_divisibility_is_a_real_exception():
    """global_batch % workers != 0 must raise a ValueError naming the spec
    fields (it was an assert, which vanishes under `python -O`)."""
    spec = ExperimentSpec(backend="mesh", arch="minicpm_2b", reduced=True,
                          steps=1, seq_len=8, global_batch=7, workers=2)
    with pytest.raises(ValueError, match=r"global_batch=7.*c=2"):
        Trainer.from_spec(spec).fit()


# ------------------------------------- fused whole-update on the mesh path


@pytest.mark.parametrize("optname,strategy,mode", [
    ("momentum", "guided_fused", "ssgd"),
    ("adam", "dc_asgd", "asgd"),
    ("sgd", "dc_asgd_guided", "asgd"),
])
def test_mesh_fused_update_matches_two_phase(optname, strategy, mode):
    """The fused whole-update dispatch (DESIGN.md §11) must reproduce the
    two-phase compensate_grads + opt.update + tree_add path step for step.
    Forcing hypers=None disables fused selection, giving the control arm."""
    from repro.data import make_batch_for
    from repro.engine import mesh as M
    from repro.optim import constant, get_optimizer

    spec = ExperimentSpec(
        backend="mesh", arch="yi_9b", reduced=True, mode=mode, strategy=strategy,
        rho=2, lr=1e-2, seed=3, steps=4, seq_len=16, global_batch=4, workers=2,
        optimizer=optname, schedule="constant",
    )
    cfg = spec.model_config()
    gcfg = spec.to_guided_config()
    batches = [
        {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 16, 4, seed=i).items()}
        for i in range(4)
    ]

    def losses(opt):
        params, _, gstate = M.init_train_state(
            jax.random.PRNGKey(3), cfg, gcfg, opt, n_workers=2,
            strategy=strategy)
        step = jax.jit(M.build_train_step(
            cfg, gcfg, opt, M.build_ctx("local"), constant(1e-2),
            n_workers=2, strategy=strategy))
        out = []
        for b in batches:
            params, gstate, m = step(params, gstate, b)
            out.append(float(m["loss"]))
        return out

    opt = get_optimizer(optname)
    assert opt.hypers is not None  # fused arm actually selectable
    fused = losses(opt)
    two_phase = losses(opt._replace(hypers=None))  # forces the legacy path
    np.testing.assert_allclose(fused, two_phase, rtol=1e-6, atol=2e-6)
