"""End-to-end behaviour tests: training runs for every algorithm mode, decode
serving, the CLI drivers, and guided-vs-plain integration behaviour."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.guided import GuidedConfig
from repro.data import make_batch_for
from repro.optim import constant, get_optimizer
from repro.sharding.rules import LOCAL_CTX
from repro.train import steps as S


def _train(arch="yi_9b", mode="ssgd", guided=True, steps=8, opt_name="sgd",
           correction="fused", n_micro=1, seed=0, lr=None):
    cfg = get_config(arch).reduced()
    gcfg = GuidedConfig(mode=mode, guided=guided, rho=3, correction=correction)
    opt = get_optimizer(opt_name)
    if lr is None:
        # adaptive optimizers take ~unit-normalized steps: much smaller lr
        lr = 1e-2 if opt_name in ("sgd", "momentum") else 1e-3
    params, _, gstate = S.make_train_state(jax.random.PRNGKey(seed), cfg, gcfg, opt, n_workers=4)
    step = jax.jit(S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(lr),
                                      n_micro=n_micro, n_workers=4))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 32, 8, seed=seed).items()}
    losses = []
    for _ in range(steps):
        params, gstate, m = step(params, gstate, batch)
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.parametrize("mode,guided", [("seq", False), ("ssgd", False), ("ssgd", True),
                                         ("asgd", True), ("dc_asgd", False)])
def test_all_modes_train(mode, guided):
    losses = _train(mode=mode, guided=guided)
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("opt_name", ["sgd", "rmsprop", "adagrad", "adam"])
def test_all_optimizers_train(opt_name):
    losses = _train(opt_name=opt_name)
    assert np.all(np.isfinite(losses)) and losses[-1] < losses[0]


def test_two_pass_close_to_fused():
    """The paper's literal two-pass replay and the fused weighted-loss form:
    identical before the first window end; afterwards both must keep
    descending. (They are not numerically identical by design: fused applies
    the correction inside the round update at the effective step eta*c, the
    literal replay uses eta — both readings of Fig. 7; `correction_scale`
    interpolates between them.)"""
    a = _train(correction="fused", steps=7, lr=1e-3)
    b = _train(correction="two_pass", steps=7, lr=1e-3)
    np.testing.assert_allclose(a[:3], b[:3], rtol=1e-5)  # identical pre-window
    assert np.all(np.isfinite(b)) and b[-1] < b[0]
    assert np.all(np.isfinite(a)) and a[-1] < a[0]


def test_microbatching_matches_full_batch():
    """Gradient accumulation is loss-equivalent to the full-batch step."""
    a = _train(n_micro=1, steps=4)
    b = _train(n_micro=2, steps=4)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_train_cli(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "minicpm-2b", "--reduced",
         "--steps", "6", "--batch", "4", "--workers", "2", "--mode", "ssgd", "--guided",
         "--seq", "32", "--log-every", "5", "--metrics-out", str(tmp_path / "m.json")],
        capture_output=True, text=True, timeout=400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: final loss" in out.stdout


def test_serve_cli():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "xlstm-350m", "--reduced",
         "--batch", "2", "--prompt-len", "16", "--gen", "4"],
        capture_output=True, text=True, timeout=400,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode:" in out.stdout


def test_guided_state_is_pytree_roundtrippable(tmp_path):
    from repro.checkpoint import restore, save

    cfg = get_config("xlstm_350m").reduced()
    gcfg = GuidedConfig(mode="dc_asgd")
    opt = get_optimizer("rmsprop")
    params, _, gstate = S.make_train_state(jax.random.PRNGKey(0), cfg, gcfg, opt, n_workers=2)
    save(str(tmp_path), 0, {"params": params, "gstate": gstate})
    out = restore(str(tmp_path), 0, {"params": params, "gstate": gstate})
    n1 = jax.tree.leaves(out["gstate"])
    n2 = jax.tree.leaves(gstate)
    assert len(n1) == len(n2)
