"""repro.analysis.modelcheck tests (DESIGN.md §13): the interleaving
explorer visits every schedule of a known toy model, sleep-set pruning
drops only redundant orderings, both protocol models hold every invariant
on the stock suite, and each seeded-bug fixture is caught by exactly the
invariant it was built to violate — including the counterexample schedule.
"""
import subprocess
import sys

import pytest

from repro.analysis.modelcheck import (
    BUGS,
    SUITE,
    Action,
    LiveModel,
    ReplayModel,
    _independent,
    _schedule,
    explore,
    run_selfcheck,
    run_suite,
)


# ------------------------------------------------------------- the explorer


class _CounterModel:
    """Two workers each do `n` local increments: the full interleaving tree
    has C(2n, n) maximal executions; with sleep sets, local-vs-local pruning
    collapses it to one representative order."""

    def __init__(self, n, local=True):
        self.n = n
        self.local = local

    def initial(self):
        return (0, 0)

    def actions(self, state):
        return [Action("compute" if self.local else "push", w, local=self.local)
                for w in range(2) if state[w] < self.n]

    def apply(self, state, a):
        s = list(state)
        s[a.wid] += 1
        return tuple(s)

    def invariant(self, state):
        return None

    def is_final(self, state):
        return state == (self.n, self.n)

    def at_end(self, state):
        return None

    def at_stuck(self, state, truncated=False):
        return None


def test_explore_counts_all_interleavings_without_pruning():
    # dependent actions (shared "push"): every one of C(6,3)=20 orders runs
    stats = explore(_CounterModel(3, local=False))
    assert stats.paths == 20
    assert stats.completed == 20
    assert stats.pruned == 0
    assert not stats.violations


def test_sleep_sets_prune_commuting_orders_to_one():
    # independent actions (local "compute"): one representative survives
    stats = explore(_CounterModel(3, local=True))
    assert stats.completed == 1
    assert stats.pruned > 0


def test_depth_bound_truncates():
    stats = explore(_CounterModel(5, local=False), max_depth=4)
    assert stats.truncated == stats.paths > 0
    assert stats.completed == 0


def test_independence_relation():
    assert _independent(("compute", 0), ("push", 1), frozenset({"compute"}))
    assert not _independent(("push", 0), ("push", 1), frozenset({"compute"}))
    assert not _independent(("compute", 0), ("compute", 0),
                            frozenset({"compute"}))  # same worker: ordered


class _BadModel(_CounterModel):
    def invariant(self, state):
        if state[0] >= 2:
            return ("cap", f"worker 0 reached {state[0]}")
        return None


def test_violation_carries_the_counterexample_schedule():
    stats = explore(_BadModel(3, local=False))
    assert stats.violations
    v = stats.violations[0]
    assert v.invariant == "cap"
    # the schedule replays to the violating state: two worker-0 actions
    assert sum(1 for _l, w in v.path if w == 0) == 2
    assert "cap" in v.format() and "schedule:" in v.format()


# ------------------------------------------------------------ the two models


def test_replay_model_clean_on_stock_schedules():
    for name, model in SUITE:
        if not name.startswith("replay/"):
            continue
        stats = explore(model, max_depth=80)
        assert not stats.violations, f"{name}: {stats.violations[0].format()}"
        assert stats.completed > 0
        # replay never legally sticks: every maximal path drains the schedule
        assert stats.stuck == 0, name


def test_live_model_clean_on_stock_configs():
    for name, model in SUITE:
        if not name.startswith("live/"):
            continue
        stats = explore(model, max_depth=80)
        assert not stats.violations, f"{name}: {stats.violations[0].format()}"
        assert stats.completed > 0


def test_recovery_model_clean_on_stock_configs():
    """DESIGN.md §14: the self-healing semantics (sentinel rejection without
    a version bump, quarantine, bounded rollback, capped respawn) hold every
    invariant across every interleaving of the stock recovery configs."""
    for name, model in SUITE:
        if not name.startswith("recovery/"):
            continue
        stats = explore(model, max_depth=80)
        assert not stats.violations, f"{name}: {stats.violations[0].format()}"
        assert stats.completed > 0


def test_recovery_model_rejections_never_bump_version():
    """The exactly-once core of the rollback design, checked directly: a
    run where EVERY push from the bad worker is rejected ends with
    version == applies and a nonzero rejection count on some path."""
    from repro.analysis.modelcheck import RecoveryModel

    model = RecoveryModel(total=3, n_workers=2, bad=(1,), quarantine_after=2)
    stats = explore(model, max_depth=80)
    assert not stats.violations
    assert stats.completed > 0


def test_schedule_helper_builds_fetch_versions():
    rows = _schedule([(0, 0), (1, 2), (0, 1)])
    assert rows == [(0, 0, 0), (1, 1, 0), (2, 0, 1)]


def test_replay_rejects_future_fetch_version():
    with pytest.raises(ValueError):
        ReplayModel([(0, 0, 1)])  # fetch_v=1 before any apply


def test_suite_clears_the_acceptance_floor():
    total = sum(s.paths for s in run_suite(max_depth=80).values())
    assert total >= 10_000, f"only {total} interleavings explored"


# ------------------------------------------------------- seeded-bug fixtures


def test_every_invariant_has_a_catchable_seeded_bug():
    results = run_selfcheck(max_depth=80)
    missed = [(bug, inv, detail) for bug, inv, caught, detail in results
              if not caught]
    assert not missed, f"fixtures not caught: {missed}"
    # the fixtures between them cover the full invariant catalogue
    assert {inv for _b, inv, _m in BUGS} == {
        "version-monotone", "applied-exactly-once", "staleness-observed",
        "schedule-order", "watchdog-termination", "trace-legal",
        "rollback-bounded", "respawn-capped"}


@pytest.mark.parametrize("bug,inv", [(b, i) for b, i, _m in BUGS])
def test_seeded_bug_violates_only_its_own_invariant_first(bug, inv):
    model = next(m for b, _i, m in BUGS if b == bug)
    stats = explore(model, max_depth=80, max_paths=50_000)
    assert any(v.invariant == inv for v in stats.violations), (
        f"{bug}: expected a {inv} violation, got "
        f"{[v.invariant for v in stats.violations]}")


def test_clean_models_unaffected_by_bug_flag_default():
    # sanity: the same shapes with bug=None hold every invariant
    stats = explore(ReplayModel(_schedule([(0, 0), (1, 1), (0, 1), (1, 1)])))
    assert not stats.violations
    stats = explore(LiveModel(total=3, n_workers=2))
    assert not stats.violations


# ------------------------------------------------------------------- the CLI


def test_cli_green_on_the_stock_suite():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.modelcheck",
         "--min-paths", "10000"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "total interleavings explored" in out.stdout
    assert "MISSED" not in out.stdout


def test_cli_fails_below_min_paths():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.modelcheck",
         "--min-paths", "10000000", "--no-selfcheck"],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "FAIL" in out.stdout
