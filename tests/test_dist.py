"""The async parameter-server backend (repro.dist) — acceptance gates.

Locks in the three properties ISSUE'd for the dist subsystem:
  (a) a 2-worker replay-mode run reproduces the backend="scan" trajectory
      under the equivalent delay distribution (same seed -> same schedule),
  (b) a live-mode run survives killing+restarting a worker mid-run and still
      trains to within tolerance of the scan reference,
  (c) the Report carries a nonempty OBSERVED staleness histogram,
plus the sim<->real parity oracle: the staleness sequence the chief RECORDS
(applied_version - read_version per update) equals the DelaySchedule the same
seed produces via core.parameter_server.extract_schedule.
"""
import time

import numpy as np
import pytest

from repro.core.parameter_server import prepare_run
from repro.engine import ExperimentSpec, Trainer


def _toy(n=120, d=5, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    w = rng.standard_normal((d,))
    y = (X @ w > 0).astype(np.int64)
    return X, y, 2


# rho=2 -> c=2 worker processes; 3 epochs keeps the whole module a few seconds
COMMON = dict(mode="asgd", epochs=3, batch_size=16, rho=2, lr=0.2, seed=0)


@pytest.fixture(scope="module")
def replay_run(tmp_path_factory):
    """One 2-worker replay run (guided strategy, chief-side checkpoints on),
    shared by the parity/staleness/checkpoint asserts below."""
    X, y, k = _toy()
    ckpt_dir = str(tmp_path_factory.mktemp("dist_ckpt"))
    spec = ExperimentSpec(backend="dist", dist_mode="replay",
                          strategy="guided_fused", ckpt_dir=ckpt_dir,
                          ckpt_every=10, **COMMON)
    report = Trainer.from_spec(spec).fit((X, y, k))
    return spec, report, (X, y, k), ckpt_dir


def test_replay_matches_scan_backend(replay_run):
    """(a): real worker processes, scheduled interleaving -> the scan
    trajectory, to float64 round-off (the delaysim parity bar, 1e-5)."""
    spec, report, data, _ = replay_run
    ref = Trainer.from_spec(ExperimentSpec(backend="scan", strategy="guided_fused",
                                           **COMMON)).fit(data)
    assert report.n_steps == ref.n_steps > 0
    assert abs(report.final["train_loss"] - ref.final["train_loss"]) < 1e-5
    assert abs(report.val_loss - ref.val_loss) < 1e-5
    hist_d = np.asarray([v for _, v in report.history])
    hist_s = np.asarray([v for _, v in ref.history])
    np.testing.assert_allclose(hist_d, hist_s, atol=1e-7)


def test_observed_staleness_equals_extracted_schedule(replay_run):
    """The parity oracle: the chief's RECORDED staleness sequence (an
    observation of real process interleaving under replay grants) is exactly
    the DelaySchedule the same seed yields from extract_schedule."""
    spec, report, (X, y, k), _ = replay_run
    _, _, _, schedule = prepare_run(X, y, k, spec.to_schedule_config())
    seq = np.asarray(sorted(  # arrivals are recorded in apply order already
        range(report.n_steps)), np.int64)  # sanity: one record per version
    assert len(report.history) == schedule.n_steps == len(seq)
    trainer_seq = np.array([t for t, _ in report.history])
    np.testing.assert_array_equal(trainer_seq, np.arange(1, schedule.n_steps + 1))
    # the observed histogram aggregates exactly the scheduled staleness column
    expect = {int(s): int(n) for s, n in
              zip(*np.unique(schedule.staleness, return_counts=True))}
    assert report.staleness_hist == expect


def test_chief_checkpoints_written(replay_run):
    """Chief-side snapshots: the manifest retains dist_snapshot archives and
    dist_restore returns the final store state."""
    from repro.checkpoint import dist_restore, latest_step

    _, report, _, ckpt_dir = replay_run
    assert latest_step(ckpt_dir) == report.n_steps
    snap = dist_restore(ckpt_dir)
    assert int(snap["version"]) == report.n_steps
    assert len(snap["staleness"]) == report.n_steps
    assert snap["W"].shape == np.asarray(report.model.W).shape


def test_live_survives_kill_restart():
    """(b)+(c): free-running async run with a worker killed and restarted
    mid-run completes its step budget, stays within tolerance of the scan
    reference, and reports a nonempty observed-staleness histogram."""
    X, y, k = _toy()
    ref = Trainer.from_spec(ExperimentSpec(backend="scan", strategy="none",
                                           **COMMON)).fit((X, y, k))
    # time_scale paces worker compute (~10ms/step draws from the exp
    # topology sampler) so the run cannot race past version 8 between two
    # 10ms monitor polls before the restart event fires — without it the
    # whole toy run can finish inside one poll window on a loaded host
    spec = ExperimentSpec(backend="dist", dist_mode="live", strategy="none",
                          workers=2, dist_events=(("restart", 0, 8),),
                          dist_time_scale=0.01, dist_timeout=60.0, **COMMON)
    report = Trainer.from_spec(spec).fit((X, y, k))
    assert report.n_steps == ref.n_steps          # full step budget despite the kill
    assert report.dist["worker_exits"] >= 1       # the kill really happened
    assert sum(report.staleness_hist.values()) == report.n_steps
    assert report.staleness_hist                  # nonempty observed histogram
    # live interleaving differs from the scheduled one, so trajectories
    # diverge — but the run must genuinely train to the reference's ballpark
    w0_loss = 0.6931  # ~ln 2: the initial near-zero weights on a binary task
    assert report.val_loss < 0.8 * w0_loss
    assert abs(report.val_loss - ref.val_loss) < 0.25


def test_live_delayed_averaging_trains():
    """DaSGD-style overlap: pushes carry per-gradient read versions, the
    observed staleness grows accordingly, and the run still trains."""
    X, y, k = _toy()
    spec = ExperimentSpec(backend="dist", dist_mode="live", strategy="dc_asgd",
                          workers=2, delayed_avg=True, dist_timeout=60.0,
                          **COMMON)
    report = Trainer.from_spec(spec).fit((X, y, k))
    assert report.n_steps > 0
    assert sum(report.staleness_hist.values()) == report.n_steps
    # the overlap means gradients are at least one merge behind on average
    mean_stale = (sum(s * n for s, n in report.staleness_hist.items())
                  / report.n_steps)
    assert mean_stale > 0.5
    assert report.val_loss < 0.6


def test_spec_validation():
    with pytest.raises(ValueError, match="dist_mode"):
        ExperimentSpec(backend="dist", dist_mode="nope")
    with pytest.raises(ValueError, match="asgd"):
        ExperimentSpec(backend="dist", dist_mode="live", mode="ssgd")
    with pytest.raises(ValueError, match="live"):
        ExperimentSpec(backend="dist", dist_mode="replay", mode="asgd",
                       dist_events=(("kill", 0, 5),))
    with pytest.raises(ValueError, match="dist event"):
        ExperimentSpec(backend="dist", dist_mode="live", mode="asgd",
                       dist_events=(("explode", 0, 5),))
    with pytest.raises(ValueError, match="dist-backend"):
        ExperimentSpec(backend="scan", mode="asgd", delayed_avg=True)
    with pytest.raises(ValueError, match="drop_rate"):
        ExperimentSpec(backend="dist", dist_mode="live", mode="asgd",
                       dist_drop_rate=1.5)


def test_no_leaked_threads(replay_run):
    """run_local joins everything it started — chief accept + connection
    threads and the async checkpoint writer. A leak here would otherwise
    surface as a confusing failure in a later module (test_trainloop asserts
    active_count()==1 after its prefetch runs)."""
    import threading

    for _ in range(100):  # close() joins with timeouts; allow a beat
        if threading.active_count() == 1:
            break
        time.sleep(0.05)
    assert [t.name for t in threading.enumerate()] == ["MainThread"]


def test_topologies_single_source():
    """Satellite: TOPOLOGY_SAMPLERS lives in repro.common.topologies; the
    delaysim name is a re-export of the same dict, and the dist workers'
    compute-time sampler resolves from it."""
    from repro.common.topologies import TOPOLOGY_SAMPLERS, compute_time_sampler
    from repro.engine import delaysim

    assert delaysim.TOPOLOGY_SAMPLERS is TOPOLOGY_SAMPLERS
    assert compute_time_sampler("straggler") is TOPOLOGY_SAMPLERS["straggler"]
    rng = np.random.default_rng(0)
    assert compute_time_sampler("exp")(0, rng) > 0  # deterministic-topology fallback
    with pytest.raises(KeyError, match="unknown topology"):
        compute_time_sampler("warp")
