"""Convergence-analysis sanity checks (paper Section 3).

The paper derives SSGD convergence O(1/(cT) + sigma^2): more workers speed up
the *early* optimization per wall-clock round (c gradients applied per round)
but converge to a sigma^2 noise floor. We verify both behaviours on a convex
logistic-regression problem where they are measurable.
"""
import numpy as np
import pytest

from repro.core.parameter_server import LogisticRegression, PSConfig, train_ps
from repro.data import load_dataset, train_test_split


def _loss_after_rounds(c: int, n_rounds: int, lr=0.05, seed=0):
    """Train SSGD with c workers for a fixed number of ROUNDS; return loss."""
    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=seed)
    rng = np.random.default_rng(seed)
    model = LogisticRegression(Xtr.shape[1], k, rng)
    bs = 16
    idx = rng.permutation(len(Xtr))
    Xs, ys = Xtr[idx], ytr[idx]
    batches = [(Xs[i : i + bs], ys[i : i + bs]) for i in range(0, len(Xs) - bs, bs)]
    bi = 0
    for _ in range(n_rounds):
        W = model.W.copy()
        grads = []
        for _ in range(c):
            Xb, yb = batches[bi % len(batches)]
            bi += 1
            grads.append(model.grad(Xb, yb, W))
        for g in grads:
            model.W -= lr * g
    return model.loss(Xtr, ytr)


def test_more_workers_faster_early_convergence():
    """O(1/(cT)): after the same number of rounds, larger c => lower loss."""
    l1 = _loss_after_rounds(c=1, n_rounds=10)
    l4 = _loss_after_rounds(c=4, n_rounds=10)
    assert l4 < l1, (l1, l4)


def test_noise_floor_grows_with_lr():
    """The eta*sigma^2 term of Eq. (3): after convergence, the stationary loss
    scales with the step size — the small-lr long run ends below the large-lr
    long run even though the large-lr run had every advantage early."""
    hi = _loss_after_rounds(c=4, n_rounds=400, lr=0.5)
    lo = _loss_after_rounds(c=4, n_rounds=400, lr=0.02)
    assert np.isfinite(hi) and np.isfinite(lo)
    assert lo < hi, (lo, hi)


def test_seq_equals_ssgd_c1_trajectory():
    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    a = train_ps(Xtr, ytr, k, PSConfig(mode="seq", epochs=1, seed=5, rho=1), Xte, yte)
    b = train_ps(Xtr, ytr, k, PSConfig(mode="ssgd", epochs=1, seed=5, rho=1), Xte, yte)
    np.testing.assert_allclose(a["model"].W, b["model"].W, atol=1e-12)
