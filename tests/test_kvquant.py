"""int8 KV-cache quantization: roundtrip error and end-to-end decode parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_batch_for
from repro.models import transformer as T
from repro.models.kvquant import dequantize_kv, quantize_kv
from repro.models.module import split_params


def test_quant_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 64, 4, 32)), jnp.float32)
    q, s = quantize_kv(x)
    xr = dequantize_kv(q, s, jnp.float32)
    # absmax int8: max error <= scale/2 = absmax/254 per row
    err = np.max(np.abs(np.asarray(xr - x)))
    bound = float(jnp.max(jnp.abs(x))) / 254 + 1e-6
    assert err <= bound * 1.2, (err, bound)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32


def test_int8_cache_decode_parity():
    """Decode logits with the int8 cache track the fp cache closely.

    Teacher-forced: both variants consume the fp run's greedy tokens, so the
    comparison measures cache-quantization error rather than compounding
    trajectory divergence. Greedy argmax must agree at every step where the fp
    top-2 margin is decisive (above the int8 noise floor); a random-init model
    produces near-ties (gaps ~1e-3) that no lossy cache can preserve.
    """
    cfg_fp = get_config("yi_9b").reduced()
    cfg_q = cfg_fp.replace(kv_cache_dtype="int8")
    params, _ = split_params(T.model_init(jax.random.PRNGKey(0), cfg_fp))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg_fp, 24, 1, seed=1).items()}

    # fp reference pass drives token selection for both variants
    last, caches = T.prefill(params, batch, cfg_fp, total_len=32)
    fp_logits = [np.asarray(last)]
    toks = [jnp.argmax(last, -1)[:, None].astype(jnp.int32)]
    for t in range(24, 28):
        lg, caches = T.decode_step(params, caches, toks[-1], jnp.asarray(t, jnp.int32), cfg_fp)
        fp_logits.append(np.asarray(lg))
        toks.append(jnp.argmax(lg, -1)[:, None].astype(jnp.int32))
    fp = np.stack(fp_logits)

    last, caches = T.prefill(params, batch, cfg_q, total_len=32)
    q_logits = [np.asarray(last)]
    for i, t in enumerate(range(24, 28)):
        lg, caches = T.decode_step(params, caches, toks[i], jnp.asarray(t, jnp.int32), cfg_q)
        q_logits.append(np.asarray(lg))
    q = np.stack(q_logits)

    rel = np.abs(fp - q).max() / (np.abs(fp).max() + 1e-9)
    assert rel < 0.05, rel
    top2 = np.sort(fp.reshape(fp.shape[0], -1), axis=-1)
    margin = top2[:, -1] - top2[:, -2]
    decisive = margin > 2 * np.abs(fp - q).reshape(fp.shape[0], -1).max(-1)
    assert decisive.any()  # the check must actually bite
    assert np.array_equal(fp.argmax(-1)[decisive], q.argmax(-1)[decisive]), (
        margin, fp.argmax(-1).ravel(), q.argmax(-1).ravel())


def test_int8_cache_halves_bytes():
    cfg = get_config("yi_9b")
    c_fp = T.init_caches(cfg, 2, 1024)
    c_q = T.init_caches(cfg.replace(kv_cache_dtype="int8"), 2, 1024)
    bytes_fp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_fp))
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_q))
    assert bytes_q < 0.56 * bytes_fp, (bytes_q, bytes_fp)
