"""Per-architecture smoke tests (reduced configs) + model-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.data import make_batch_for
from repro.models import transformer as T
from repro.models import layers as L
from repro.models.module import split_params, param_count
from repro.sharding.rules import LOCAL_CTX

ARCHS = [a for a in ARCH_IDS if a != "paper_logreg"]


def _setup(arch, B=2, S=64):
    cfg = get_config(arch).reduced()
    params, _ = split_params(T.model_init(jax.random.PRNGKey(0), cfg))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, S, B, seed=1).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train(arch):
    """Reduced variant: one forward/train step, output shapes + no NaNs."""
    cfg, params, batch = _setup(arch)
    per_ex, aux, logits = jax.jit(lambda p, b: T.forward_train(p, b, cfg))(params, batch)
    assert per_ex.shape == (2,)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(per_ex))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    from repro.core.guided import GuidedConfig
    from repro.optim import constant, get_optimizer
    from repro.train import steps as S

    cfg = get_config(arch).reduced()
    gcfg = GuidedConfig(mode="ssgd", guided=True, rho=3)
    opt = get_optimizer("sgd")
    params, _, gstate = S.make_train_state(jax.random.PRNGKey(0), cfg, gcfg, opt, n_workers=2)
    step = jax.jit(S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(1e-2), n_workers=2))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 32, 4, seed=0).items()}
    losses = []
    for _ in range(5):
        params, gstate, m = step(params, gstate, batch)
        losses.append(float(m["loss"]))
    assert np.all(np.isfinite(losses)), arch
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", [a for a in ARCHS if get_config(a).supports_decode])
def test_decode_consistent_with_forward(arch):
    """Greedy decode logits == teacher-forced forward logits at each position.
    MoE archs: capacity clipped at no-drop so prefill/forward see identical
    routing (token-drop patterns legitimately differ with sequence length)."""
    cfg, params, batch = _setup(arch, B=1, S=32)
    if cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    per_ex, aux, logits_tf = T.forward_train(params, batch, cfg)

    # prompt must cover the VLM patch block (positions 1..1+n_patches)
    PL = 24
    prompt = {k: (v[:, :PL] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    last, caches = T.prefill(params, prompt, cfg, total_len=32)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits_tf[:, PL - 1]),
                               atol=2e-2, rtol=2e-2)
    # feed the TRUE next tokens and compare against teacher-forced logits
    for t in range(PL, PL + 4):
        tok = batch["tokens"][:, t : t + 1]
        logits, caches = T.decode_step(params, caches, tok, jnp.asarray(t, jnp.int32), cfg)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_tf[:, t]),
                                   atol=2e-2, rtol=2e-2)


def test_sliding_window_blocked_equals_masked():
    """Block-local SWA path == masked dense SWA (exactness of the banding)."""
    rng = np.random.default_rng(0)
    B, S, H, K, dh, W = 1, 256, 4, 2, 32, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    blocked = L.attention(q, k, v, n_kv_heads=K, causal=True, window=W)  # S > 2W: blocked
    qg = q.reshape(B, S, K, H // K, dh)
    qi, kj = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (qi >= kj) & (qi - kj < W)
    dense = L._sdpa(qg, k, v, mask[None, None, None], 1.0 / np.sqrt(dh)).reshape(B, S, H, dh)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense), atol=1e-5)


def test_vlm_patch_scatter_changes_prefix_only():
    cfg, params, batch = _setup("llava_next_mistral_7b", B=1, S=64)
    p2 = dict(batch)
    p2["patches"] = batch["patches"] + 1.0
    _, _, l1 = T.forward_train(params, batch, cfg)
    _, _, l2 = T.forward_train(params, p2, cfg)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert_xlarge").reduced()
    params, _ = split_params(T.model_init(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError):
        T.decode_step(params, {}, jnp.zeros((1, 1), jnp.int32), 0, cfg)


def test_param_counts_full_configs():
    """Full (non-reduced) configs should be in the advertised parameter range."""
    expected = {  # rough total-param targets (B = 1e9), generous tolerance
        "yi_9b": (7, 11),
        "granite_20b": (15, 25),
        "mistral_large_123b": (100, 140),
        "grok_1_314b": (250, 370),
        "qwen3_moe_235b_a22b": (180, 280),
        "jamba_1_5_large_398b": (330, 470),
        "minicpm_2b": (1.5, 3.5),
        "llava_next_mistral_7b": (6, 9),
        "hubert_xlarge": (0.7, 1.3),
        "xlstm_350m": (0.25, 0.6),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        boxed = jax.eval_shape(lambda c=cfg: T.model_init(jax.random.PRNGKey(0), c))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(split_params(boxed)[0]))
        assert lo * 1e9 <= n <= hi * 1e9, (arch, n / 1e9)
