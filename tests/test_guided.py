"""Tests for the paper's core: consistency statistics, guided correction,
staleness/DC-ASGD, and the literal parameter-server simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import given, settings, st  # hypothesis, or skip-stubs when absent

from repro.core import guided as G
from repro.core.consistency import consistency_increment
from repro.core.parameter_server import (
    ALGO_NAMES,
    LogisticRegression,
    PSConfig,
    algo_config,
    train_ps,
)
from repro.data import load_dataset, train_test_split


# ------------------------------------------------------------- consistency


@given(
    st.lists(st.floats(0.1, 10.0), min_size=4, max_size=4),
    st.lists(st.floats(0.1, 10.0), min_size=4, max_size=4),
    st.floats(0.1, 10.0),
    st.floats(0.1, 10.0),
)
@settings(max_examples=50, deadline=None)
def test_consistency_increment_bounds(wl, pwl, al, pal):
    inc = consistency_increment(jnp.asarray(wl), jnp.asarray(pwl), jnp.asarray(al), jnp.asarray(pal))
    inc = np.asarray(inc)
    assert np.all(inc >= 0) and np.all(inc <= 1.1 + 1e-6)
    # increments are positive only where both deltas are negative
    both = (np.asarray(wl) < np.asarray(pwl)) & (al < pal)
    assert np.all((inc > 0) == both)


@given(st.lists(st.floats(0.0, 5.0), min_size=3, max_size=16))
@settings(max_examples=50, deadline=None)
def test_correction_weights_properties(scores):
    gcfg = G.GuidedConfig(max_consistent=4)
    w = np.asarray(G.correction_weights(jnp.asarray(scores, jnp.float32), gcfg))
    assert np.all(w >= -1e-6)
    s = w.sum()
    assert abs(s - 1.0) < 1e-5 or abs(s) < 1e-6  # normalized or all-zero
    assert (w > 0).sum() <= 4  # paper: at most 4 replayed batches
    if s > 0:  # the top scorer is always selected
        assert w[int(np.argmax(scores))] > 0


def test_correction_weights_zero_scores():
    gcfg = G.GuidedConfig()
    w = G.correction_weights(jnp.zeros(8), gcfg)
    assert float(jnp.sum(w)) == 0.0


def test_dc_asgd_compensation_formula():
    g = {"w": jnp.asarray([1.0, -2.0])}
    p = {"w": jnp.asarray([0.5, 0.5])}
    pb = {"w": jnp.asarray([0.0, 1.0])}
    out = G.compensate_dc_asgd(g, p, pb, lam=0.1)
    expect = np.array([1.0 + 0.1 * 1.0 * 0.5, -2.0 + 0.1 * 4.0 * (-0.5)])
    np.testing.assert_allclose(np.asarray(out["w"]), expect, atol=1e-6)


def test_stale_refresh_period():
    gcfg = G.GuidedConfig(mode="asgd", staleness=3)
    params = {"w": jnp.ones(2)}
    from repro.optim import sgd

    state = G.guided_init(gcfg, params, sgd(), 4)
    for step in range(7):
        state = state._replace(step=jnp.asarray(step))
        new_params = {"w": jnp.full(2, float(step + 10))}
        ws = G.refresh_stale(state, gcfg, new_params)
        if step % 3 == 0:
            np.testing.assert_allclose(np.asarray(ws["w"]), step + 10)
        state = state._replace(w_stale=ws)


def test_window_end_every_rho():
    gcfg = G.GuidedConfig(rho=5)
    ends = [bool(G.is_window_end(jnp.asarray(s), gcfg)) for s in range(11)]
    assert ends == [False, False, False, False, True] * 2 + [False]


# ------------------------------------------------- literal parameter server


def test_logreg_gradient_matches_finite_difference():
    rng = np.random.default_rng(0)
    m = LogisticRegression(4, 3, rng)
    X = rng.standard_normal((16, 4))
    y = rng.integers(0, 3, 16)
    g = m.grad(X, y)
    eps = 1e-6
    for idx in [(0, 0), (2, 1), (4, 2)]:
        W2 = m.W.copy()
        W2[idx] += eps
        fd = (m.loss(X, y, W2) - m.loss(X, y)) / eps
        assert abs(fd - g[idx]) < 1e-4


def test_ssgd_with_one_worker_equals_seq():
    """c=1 synchronous == sequential SGD (identical update sequence)."""
    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    a = train_ps(Xtr, ytr, k, PSConfig(mode="seq", epochs=2, seed=3, rho=1), Xte, yte)
    b = train_ps(Xtr, ytr, k, PSConfig(mode="ssgd", epochs=2, seed=3, rho=1), Xte, yte)
    np.testing.assert_allclose(a["model"].W, b["model"].W, atol=1e-10)


def test_guided_replay_changes_trajectory_only_at_windows():
    """With rho larger than total updates, g-variants == plain variants."""
    X, y, k = load_dataset("new_thyroid", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    big_rho = 10 ** 6
    a = train_ps(Xtr, ytr, k, PSConfig(mode="seq", guided=False, epochs=1, seed=1, rho=big_rho), Xte, yte)
    b = train_ps(Xtr, ytr, k, PSConfig(mode="seq", guided=True, epochs=1, seed=1, rho=big_rho), Xte, yte)
    np.testing.assert_allclose(a["model"].W, b["model"].W, atol=1e-12)


def test_asgd_applies_every_gradient_once():
    X, y, k = load_dataset("haberman", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    out = train_ps(Xtr, ytr, k, PSConfig(mode="asgd", epochs=1, seed=0), Xte, yte)
    n_batches = (len(Xtr) - max(8, int(0.2 * len(Xtr)))) // 16
    assert len(out["history"]) == n_batches


def test_all_algo_names_run():
    X, y, k = load_dataset("cancer", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=0)
    for name in ALGO_NAMES.values():
        out = train_ps(Xtr[:200], ytr[:200], k, algo_config(name, epochs=1, seed=0), Xte, yte)
        assert 0.0 <= out["test_accuracy"] <= 1.0, name
        assert np.isfinite(out["val_loss"]), name


# ------------------------------------------------------ distributed (fused)


def test_fused_correction_equals_manual_weighted_gradient():
    """grad(mean + sum w_i L_i) == mean-grad + sum w_i grad(L_i)."""
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.module import split_params
    from repro.data import make_batch_for

    cfg = get_config("yi_9b").reduced()
    params, _ = split_params(T.model_init(jax.random.PRNGKey(0), cfg))
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 16, 4, seed=0).items()}
    c = 4
    w = jnp.asarray([0.0, 0.7, 0.3, 0.0])

    def total(p):
        per_ex, aux, _ = T.forward_train(p, batch, cfg)
        E = per_ex.reshape(c, -1).mean(1)
        return E.mean() + (w * E).sum()

    def worker_loss(p, i):
        per_ex, aux, _ = T.forward_train(p, batch, cfg)
        return per_ex.reshape(c, -1).mean(1)[i]

    g_total = jax.grad(total)(params)
    g_mean = jax.grad(lambda p: jax.tree.map(lambda x: x, total(p)) * 0 + sum(
        worker_loss(p, i) for i in range(c)) / c)(params)
    g1 = jax.grad(lambda p: worker_loss(p, 1))(params)
    g2 = jax.grad(lambda p: worker_loss(p, 2))(params)
    leaf = lambda t: np.asarray(jax.tree.leaves(t)[0], np.float32)
    np.testing.assert_allclose(
        leaf(g_total), leaf(g_mean) + 0.7 * leaf(g1) + 0.3 * leaf(g2), atol=1e-4, rtol=1e-3
    )


def test_train_step_guided_correction_fires_at_window_end():
    from repro.configs import get_config
    from repro.optim import constant, get_optimizer
    from repro.train import steps as S
    from repro.data import make_batch_for
    from repro.sharding.rules import LOCAL_CTX

    cfg = get_config("yi_9b").reduced()
    gcfg = G.GuidedConfig(mode="ssgd", guided=True, rho=3)
    opt = get_optimizer("sgd")
    params, _, gstate = S.make_train_state(jax.random.PRNGKey(0), cfg, gcfg, opt, n_workers=2)
    step = jax.jit(S.build_train_step(cfg, gcfg, opt, LOCAL_CTX, constant(1e-2), n_workers=2))
    corr = []
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 16, 4, seed=0).items()}
    for i in range(7):
        params, gstate, m = step(params, gstate, batch)
        corr.append(float(m["corr_weight_sum"]))
    # correction fires exactly when (step % rho == rho-1), after warmup window
    assert corr[0] == 0.0 and corr[1] == 0.0
    fired = [i for i, c in enumerate(corr) if c > 0]
    assert all((i + 1) % 3 == 0 for i in fired)
    assert len(fired) >= 1  # scores accumulate -> correction actually fires
