"""Serving-engine tests (repro.serve, DESIGN.md §7):

  * token-for-token parity of the continuous engine vs. the lockstep loop for
    equal-length requests (greedy AND seeded stochastic sampling — the two
    paths share the key-split protocol);
  * completion / slot-recycling with staggered prompt lengths, max-token
    limits and EOS;
  * per-slot position decode equals per-request sequential decode (pool of
    heterogeneous-depth requests vs. each request run alone);
  * the sampling layer (greedy = temperature 0 = top-k 1 argmax; top-k draws
    stay inside the top-k set; determinism; parameter validation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.module import split_params
from repro.serve import (
    Request,
    SamplingParams,
    ServeEngine,
    lockstep_generate,
    sample_tokens,
)


@pytest.fixture(scope="module")
def dense():
    """Small dense arch: row-independent layers, padded-prefill eligible."""
    cfg = get_config("minicpm-2b").reduced()
    params = split_params(T.model_init(jax.random.PRNGKey(0), cfg))[0]
    return cfg, params


@pytest.fixture(scope="module")
def xlstm():
    """Recurrent arch: exercises the exact-length prefill path."""
    cfg = get_config("xlstm-350m").reduced()
    params = split_params(T.model_init(jax.random.PRNGKey(1), cfg))[0]
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,)).tolist() for L in lens]


def _by_id(comps):
    return {c.request_id: c for c in comps}


# ------------------------------------------------- (a) lockstep parity


@pytest.mark.parametrize("sampling", [
    SamplingParams(),  # greedy
    SamplingParams(method="topk", top_k=20, temperature=0.8),
])
def test_continuous_matches_lockstep_equal_lengths(dense, sampling):
    """With equal prompt lengths the barriered loop has no padding flaw, so
    the continuous engine must reproduce it token for token — including
    stochastic sampling, which shares the per-request key-split protocol."""
    cfg, params = dense
    prompts = _prompts(cfg, [12, 12, 12, 12])

    def reqs():
        return [Request(list(p), max_new_tokens=6,
                        sampling=SamplingParams(**{**sampling.__dict__, "seed": i}),
                        request_id=i)
                for i, p in enumerate(prompts)]

    engine = ServeEngine(params, cfg, max_batch=4, max_len=32)
    cont = _by_id(engine.run(reqs()))
    lock = _by_id(lockstep_generate(engine, reqs())[0])
    assert set(cont) == set(lock) == {0, 1, 2, 3}
    for i in cont:
        assert cont[i].tokens == lock[i].tokens, i


# --------------------------------- (b) staggered completion / recycling


def test_slot_recycling_staggered_lengths(dense):
    cfg, params = dense
    lens = [5, 9, 12, 7, 16, 3]
    gens = [6, 4, 8, 3, 5, 7]
    reqs = [Request(p, max_new_tokens=g, request_id=i)
            for i, (p, g) in enumerate(zip(_prompts(cfg, lens), gens))]
    engine = ServeEngine(params, cfg, max_batch=2, max_len=32)
    comps = engine.run(reqs)

    assert len(comps) == len(reqs)
    by_id = _by_id(comps)
    for i, g in enumerate(gens):
        assert by_id[i].finish_reason == "length"
        assert by_id[i].new_tokens == g
        assert by_id[i].prompt_len == lens[i]
    # 6 requests through 2 slots: both slots recycled
    slots = [c.slot for c in comps]
    assert set(slots) <= {0, 1}
    assert min(slots.count(0), slots.count(1)) >= 2
    st = engine.stats()
    assert st["n_completed"] == 6
    assert st["new_tokens"] == sum(gens)
    assert 0 < st["occupancy"] <= 1
    assert not engine.has_work


def test_eos_frees_slot_early(dense):
    cfg, params = dense
    (prompt,) = _prompts(cfg, [10])
    engine = ServeEngine(params, cfg, max_batch=1, max_len=64)
    (full,) = engine.run([Request(list(prompt), max_new_tokens=8)])
    assert full.finish_reason == "length"
    # rerun with EOS set to the 4th generated token: must stop there
    eos = full.tokens[3]
    engine2 = ServeEngine(params, cfg, max_batch=1, max_len=64, eos_id=eos)
    (cut,) = engine2.run([Request(list(prompt), max_new_tokens=8)])
    assert cut.finish_reason == "eos"
    assert cut.tokens == full.tokens[:4]


def test_streaming_callback_matches_completion(xlstm):
    cfg, params = xlstm
    streams = {}
    reqs = [Request(p, max_new_tokens=4, request_id=i,
                    on_token=lambda rid, tok: streams.setdefault(rid, []).append(tok))
            for i, p in enumerate(_prompts(cfg, [6, 11, 8]))]
    engine = ServeEngine(params, cfg, max_batch=2, max_len=32)
    comps = engine.run(reqs)
    assert len(comps) == 3
    for c in comps:
        assert streams[c.request_id] == c.tokens


# -------------------------- (c) per-slot decode == sequential decode


@pytest.mark.parametrize("arch_fixture", ["dense", "xlstm"])
def test_per_slot_decode_matches_sequential(request, arch_fixture):
    """A pool of requests at heterogeneous depths (per-slot position vector)
    must produce exactly the tokens each request gets when decoded alone
    (pool of 1): cross-slot isolation of the batched decode."""
    cfg, params = request.getfixturevalue(arch_fixture)
    lens = [5, 9, 12, 7, 16]
    gens = [6, 4, 8, 3, 5]
    reqs = [Request(p, max_new_tokens=g, request_id=i)
            for i, (p, g) in enumerate(zip(_prompts(cfg, lens), gens))]
    pool = ServeEngine(params, cfg, max_batch=3, max_len=32)
    pooled = _by_id(pool.run(reqs))

    solo_engine = ServeEngine(params, cfg, max_batch=1, max_len=32)
    for i, (p, g) in enumerate(zip(_prompts(cfg, lens), gens)):
        (solo,) = solo_engine.run([Request(p, max_new_tokens=g, request_id=i)])
        assert pooled[i].tokens == solo.tokens, i


def test_decode_step_accepts_scalar_and_vector_t(dense):
    """Back-compat: scalar t must equal a constant (B,) position vector."""
    cfg, params = dense
    B, L = 2, 8
    toks = np.asarray(_prompts(cfg, [L, L], seed=3), np.int32)
    _, caches = T.prefill(params, {"tokens": jnp.asarray(toks)}, cfg, total_len=16)
    nxt = jnp.asarray([[1], [2]], jnp.int32)
    lo_s, c_s = T.decode_step(params, caches, nxt, jnp.asarray(L, jnp.int32), cfg)
    lo_v, c_v = T.decode_step(params, caches, nxt, jnp.full((B,), L, jnp.int32), cfg)
    np.testing.assert_array_equal(np.asarray(lo_s), np.asarray(lo_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- sampling layer


def test_sampling_greedy_paths_agree():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    keys = jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(s)) for s in range(3)]),
                       jnp.uint32)
    argmax = np.argmax(np.asarray(logits), axis=-1)
    # temperature 0 (greedy), and top_k=1 at temperature 1: both == argmax
    t0, _ = sample_tokens(logits, keys, jnp.zeros((3,)), jnp.zeros((3,), jnp.int32))
    k1, _ = sample_tokens(logits, keys, jnp.ones((3,)), jnp.ones((3,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(t0), argmax)
    np.testing.assert_array_equal(np.asarray(k1), argmax)


def test_sampling_topk_stays_in_topk_and_is_deterministic():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    keys = jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(s)) for s in range(4)]),
                       jnp.uint32)
    temp = jnp.full((4,), 1.3)
    topk = jnp.full((4,), 5, jnp.int32)
    tok_a, keys_a = sample_tokens(logits, keys, temp, topk)
    tok_b, keys_b = sample_tokens(logits, keys, temp, topk)
    np.testing.assert_array_equal(np.asarray(tok_a), np.asarray(tok_b))
    np.testing.assert_array_equal(np.asarray(keys_a), np.asarray(keys_b))
    top5 = np.argsort(np.asarray(logits), axis=-1)[:, -5:]
    for i, t in enumerate(np.asarray(tok_a)):
        assert t in top5[i]
    # the returned keys advance the chain: they differ from the inputs
    assert not np.array_equal(np.asarray(keys_a), np.asarray(keys))


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="method"):
        SamplingParams(method="nucleus")
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(method="topk", top_k=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    assert SamplingParams().eff_temperature == 0.0
    assert SamplingParams(method="temperature", temperature=0.7).eff_temperature == 0.7
    assert SamplingParams(method="temperature", top_k=9).eff_top_k == 0


# ------------------------------------------------------- engine guards


def test_engine_rejects_bad_requests(dense):
    cfg, params = dense
    engine = ServeEngine(params, cfg, max_batch=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(Request(list(range(10)), max_new_tokens=10))
    with pytest.raises(ValueError, match="prompt"):
        engine.submit(Request([], max_new_tokens=2))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request([1, 2], max_new_tokens=0))


def test_engine_rejects_encoder_only():
    cfg = get_config("hubert-xlarge").reduced()
    with pytest.raises(ValueError, match="encoder-only"):
        ServeEngine({}, cfg, max_batch=1, max_len=16)


def test_vlm_patches_reach_the_prompt_and_are_validated(dense):
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = split_params(T.model_init(jax.random.PRNGKey(2), cfg))[0]
    engine = ServeEngine(params, cfg, max_batch=1, max_len=48)
    rng = np.random.default_rng(5)
    P = cfg.n_patches
    prompt = rng.integers(0, cfg.vocab_size, (P + 6,)).tolist()
    patches_a = rng.standard_normal((P, cfg.d_model)).astype(np.float32)
    patches_b = rng.standard_normal((P, cfg.d_model)).astype(np.float32)
    (a,) = engine.run([Request(list(prompt), max_new_tokens=5, patches=patches_a)])
    (b,) = engine.run([Request(list(prompt), max_new_tokens=5, patches=patches_b)])
    assert a.tokens != b.tokens  # the spliced embeddings steer the stream
    with pytest.raises(ValueError, match="splice"):  # prompt shorter than patches
        engine.submit(Request(list(prompt[:P]), max_new_tokens=2, patches=patches_a))
    dense_cfg, dense_params = dense
    with pytest.raises(ValueError, match="vlm"):  # patches on a non-vlm arch
        ServeEngine(dense_params, dense_cfg, max_batch=1, max_len=48).submit(
            Request(list(prompt), max_new_tokens=2, patches=patches_a))
    with pytest.raises(ValueError, match="token-only"):  # lockstep can't take them
        lockstep_generate(engine, [Request(list(prompt), max_new_tokens=2,
                                           patches=patches_a)])
