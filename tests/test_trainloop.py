"""The chunked/prefetched mesh fit pipeline (repro.engine.trainloop, DESIGN.md §9).

The headline contract: chunked multi-step dispatch (K train steps fused into
one jitted lax.scan) + async double-buffered prefetch is BIT-EXACT with the
per-step legacy loop — params, GuidedState and per-step history, leaf for
leaf, for every registered strategy — while checkpoint cadence, bit-exact
resume (including resume points between natural chunk boundaries), SIGTERM
drain and the on_step contract all survive the regrouping. Plus the
satellites: the chunk schedule, the prefetcher, the chunk-aware synthetic
stream, and needs_correction skipping the second weighted forward+backward.
"""
import os
import signal
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import ExperimentSpec, Trainer
from repro.engine.trainloop import build_chunk_step, chunk_schedule

# tiny operating point: per-step compute is trivial, so the 6-strategy parity
# matrix stays compile-bound rather than step-bound
TINY = (("n_layers", 1), ("d_model", 16), ("d_ff", 32), ("vocab_size", 128),
        ("n_heads", 2), ("n_kv_heads", 2))


def _spec(strategy="guided_fused", mode="ssgd", **kw):
    base = dict(backend="mesh", arch="yi_9b", reduced=True, mode=mode,
                strategy=strategy, rho=3, staleness=2, lr=5e-2, seed=0, steps=6,
                seq_len=8, global_batch=4, workers=2, model_overrides=TINY)
    base.update(kw)
    return ExperimentSpec(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ chunk schedule


def test_chunk_schedule_partitions_and_tail():
    assert chunk_schedule(0, 10, 4) == [4, 4, 2]
    assert chunk_schedule(0, 6, 1) == [1] * 6
    assert chunk_schedule(0, 0, 4) == []
    assert chunk_schedule(0, 3, 64) == [3]


def test_chunk_schedule_splits_at_ckpt_multiples():
    # every multiple of ckpt_every lands on a chunk boundary (split, not shifted)
    assert chunk_schedule(0, 10, 4, ckpt_every=5) == [4, 1, 4, 1]
    assert chunk_schedule(0, 8, 2, ckpt_every=3) == [2, 1, 2, 1, 2]
    # resume mid-cadence re-aligns at the next multiple
    assert chunk_schedule(3, 10, 4, ckpt_every=5) == [2, 4, 1]
    for start, stop, k, every in [(0, 23, 8, 5), (7, 40, 16, 6), (3, 9, 2, 4)]:
        sizes = chunk_schedule(start, stop, k, every)
        assert sum(sizes) == stop - start and all(1 <= s <= k for s in sizes)
        done = start
        boundaries = set()
        for s in sizes:
            done += s
            boundaries.add(done)
        for mult in range(start + 1, stop):
            if mult % every == 0:
                assert mult in boundaries, (start, stop, k, every, mult)


def test_chunk_schedule_rejects_bad_chunk_steps():
    with pytest.raises(ValueError, match="chunk_steps"):
        chunk_schedule(0, 4, 0)
    with pytest.raises(ValueError, match="chunk_steps must be >= 1"):
        ExperimentSpec(backend="mesh", chunk_steps=0)


# ----------------------------------------------------- the bit-exact headline

# every registered strategy under its natural execution mode
STRATEGIES = [
    ("none", "ssgd"),
    ("guided_fused", "ssgd"),
    ("guided_two_pass", "ssgd"),
    ("dc_asgd", "asgd"),
    ("dc_asgd_guided", "asgd"),
    ("gap_aware", "asgd"),
]


@pytest.mark.parametrize("strategy,mode", STRATEGIES)
def test_chunked_matches_stepwise_bit_exact(strategy, mode):
    """fit(6) with chunk_steps=4 (sizes [4, 2]: a full chunk AND an uneven
    tail) reproduces the per-step loop leaf for leaf — params, the whole
    GuidedState, and the per-step history."""
    stepwise = Trainer.from_spec(_spec(strategy, mode)).fit()
    chunked = Trainer.from_spec(_spec(strategy, mode, chunk_steps=4)).fit()
    _assert_trees_equal(stepwise.model, chunked.model)
    _assert_trees_equal(stepwise.state, chunked.state)
    assert stepwise.history == chunked.history  # per-step records, bit-equal
    assert chunked.n_steps == 6


def test_prefetch_is_bit_exact_chunked_and_stepwise():
    """The async double buffer changes staging, never values: prefetched runs
    equal their synchronous twins on both the chunked and per-step paths."""
    stepwise = Trainer.from_spec(_spec()).fit()
    for kw in (dict(chunk_steps=4, prefetch=True), dict(prefetch=True)):
        got = Trainer.from_spec(_spec(**kw)).fit()
        _assert_trees_equal(stepwise.model, got.model)
        _assert_trees_equal(stepwise.state, got.state)
        assert stepwise.history == got.history
    assert threading.active_count() == 1  # prefetch workers joined


def test_chunked_with_explicit_data_stream():
    """Caller-provided batch iterables stack into blocks identically."""
    from repro.data import make_batch_for

    spec = _spec()
    cfg = spec.model_config()
    batches = [make_batch_for(cfg, 8, 4, seed=i) for i in range(6)]
    a = Trainer.from_spec(spec).fit(data=[dict(b) for b in batches])
    b = Trainer.from_spec(_spec(chunk_steps=3, prefetch=True)).fit(
        data=[dict(bb) for bb in batches])
    _assert_trees_equal(a.model, b.model)
    _assert_trees_equal(a.state, b.state)
    assert a.history == b.history


def test_chunked_short_data_stream_raises():
    with pytest.raises(ValueError, match="exhausted mid-chunk"):
        from repro.data import make_batch_for

        spec = _spec(chunk_steps=4)
        cfg = spec.model_config()
        Trainer.from_spec(spec).fit(
            data=[make_batch_for(cfg, 8, 4, seed=i) for i in range(3)])


# -------------------------------------------------------- cadence interaction


def test_chunked_checkpoints_land_on_stepwise_cadence(tmp_path):
    """ckpt_every=3 misaligned with chunk_steps=2: chunks split so snapshots
    land at exactly the steps the per-step loop would write (3, 6, then the
    final 6-dedupe)."""
    from repro.checkpoint import read_manifest

    da, db = str(tmp_path / "step"), str(tmp_path / "chunk")
    Trainer.from_spec(_spec(ckpt_dir=da, ckpt_every=3, keep_last=0)).fit()
    Trainer.from_spec(_spec(ckpt_dir=db, ckpt_every=3, keep_last=0,
                            chunk_steps=2, prefetch=True)).fit()
    steps_a = [c["step"] for c in read_manifest(da)["ckpts"]]
    steps_b = [c["step"] for c in read_manifest(db)["ckpts"]]
    assert steps_a == steps_b == [3, 6]
    A = np.load(os.path.join(da, "step_00000003.npz"))
    B = np.load(os.path.join(db, "step_00000003.npz"))
    assert sorted(A.files) == sorted(B.files)
    for k in A.files:
        np.testing.assert_array_equal(A[k], B[k], err_msg=k)


@pytest.mark.parametrize("cut", [3, 4])
def test_chunked_resume_bit_exact_on_and_between_boundaries(cut, tmp_path):
    """Resume from a snapshot at step 3 (BETWEEN chunk_steps=2 boundaries of
    the original schedule — only a ckpt-split put a boundary there) and at
    step 4 (ON a natural boundary): both complete bit-exactly."""
    d = str(tmp_path)
    full = Trainer.from_spec(_spec()).fit()  # stepwise reference
    Trainer.from_spec(_spec(chunk_steps=2, steps=cut, ckpt_dir=d)).fit()
    resumed = Trainer.from_spec(_spec(chunk_steps=2, ckpt_dir=d,
                                      prefetch=True)).fit(resume=True)
    assert resumed.start_step == cut and resumed.n_steps == 6 - cut
    _assert_trees_equal(full.model, resumed.model)
    _assert_trees_equal(full.state, resumed.state)
    assert int(resumed.state.step) == 6


def test_sigterm_mid_chunk_drains_and_resumes(tmp_path):
    """SIGTERM while a chunk is in flight: the chunk drains, the snapshot
    holds a consistent (chunk-boundary) step count, resume is bit-exact."""
    from repro.checkpoint import latest_step

    d = str(tmp_path)
    full = Trainer.from_spec(_spec()).fit()

    def kill_in_first_chunk(step, m, params):
        if step <= 3:  # fires at the first chunk's END (step=3 for k=4)
            os.kill(os.getpid(), signal.SIGTERM)

    part = Trainer.from_spec(_spec(chunk_steps=4, prefetch=True, ckpt_dir=d)).fit(
        on_step=kill_in_first_chunk)
    assert part.interrupted
    assert part.n_steps == 4          # the in-flight chunk completed, whole
    assert latest_step(d) == 4        # snapshot at its boundary
    resumed = Trainer.from_spec(_spec(chunk_steps=4, ckpt_dir=d)).fit(resume=True)
    assert resumed.start_step == 4 and not resumed.interrupted
    _assert_trees_equal(full.model, resumed.model)
    _assert_trees_equal(full.state, resumed.state)
    assert threading.active_count() == 1


# ------------------------------------------------------------ on_step contract


def test_on_step_fires_per_chunk_with_stacked_metrics():
    seen = []

    def cb(step, m, params):
        seen.append((step, tuple(getattr(m["loss"], "shape", ()))))

    Trainer.from_spec(_spec(chunk_steps=4)).fit(on_step=cb)
    # one call per chunk, step = LAST step of the chunk, metrics stacked (k,)
    assert seen == [(3, (4,)), (5, (2,))]


def test_on_step_chunk_steps_1_keeps_legacy_scalar_contract():
    seen = []

    def cb(step, m, params):
        seen.append((step, tuple(getattr(m["loss"], "shape", ()))))

    Trainer.from_spec(_spec()).fit(on_step=cb)
    assert seen == [(i, ()) for i in range(6)]  # per step, scalar metrics


def test_launcher_chunked_run_logs_per_step_history(capsys):
    """--chunk-steps/--prefetch thread through the CLI; the launcher's
    log-cadence history is identical to a stepwise run's."""
    from repro.launch.train import main as train_main

    common = ["--arch", "yi_9b", "--reduced", "--steps", "6", "--seq", "8",
              "--batch", "4", "--workers", "2", "--rho", "3",
              "--log-every", "2"]
    h_step = train_main(common)
    h_chunk = train_main(common + ["--chunk-steps", "4", "--prefetch"])
    assert [r["step"] for r in h_chunk] == [0, 2, 4, 5]
    assert h_chunk == h_step


# ------------------------------------------------- chunk-aware batch stream


def test_stack_blocks_preserves_the_per_step_stream():
    """Chunk-aware synthetic generation: stacked (K, ...) blocks unstack to
    exactly the per-step stream (same seed protocol, same draws)."""
    from repro.data import stack_blocks, synthetic_lm_batches

    ref = synthetic_lm_batches(64, 8, 4, seed=3, n_corpora=2)
    chunked = synthetic_lm_batches(64, 8, 4, seed=3, n_corpora=2)
    blocks = list(stack_blocks(chunked, [3, 2, 1]))
    assert [b["tokens"].shape for b in blocks] == [(3, 4, 8), (2, 4, 8), (1, 4, 8)]
    i = 0
    for blk in blocks:
        for j in range(blk["tokens"].shape[0]):
            step = next(ref)
            for key in step:
                np.testing.assert_array_equal(blk[key][j], step[key])
            i += 1
    assert i == 6


def test_stack_blocks_exhaustion_names_the_shortfall():
    from repro.data import stack_blocks

    it = iter([{"x": np.zeros(2)}] * 2)
    with pytest.raises(ValueError, match=r"got 0 of 3"):
        list(stack_blocks(it, [2, 3]))


# ------------------------------------------------------------- the prefetcher


def test_prefetcher_yields_in_order_and_joins():
    from repro.data.prefetch import ChunkPrefetcher

    src = [{"x": np.full((2,), i)} for i in range(7)]
    pf = ChunkPrefetcher(iter(src), put=lambda t: t, depth=2)
    got = [int(item["x"][0]) for item in pf]
    assert got == list(range(7))
    pf.close()
    assert threading.active_count() == 1


def test_prefetcher_propagates_source_errors():
    from repro.data.prefetch import ChunkPrefetcher

    def bad():
        yield {"x": np.zeros(1)}
        raise RuntimeError("generator blew up")

    pf = ChunkPrefetcher(bad(), put=lambda t: t)
    assert int(pf.__next__()["x"][0]) == 0
    with pytest.raises(RuntimeError, match="blew up"):
        next(pf)
    pf.close()


def test_prefetcher_close_mid_stream_unblocks_worker():
    from repro.data.prefetch import ChunkPrefetcher

    def endless():
        i = 0
        while True:
            yield {"x": np.full((1,), i)}
            i += 1

    pf = ChunkPrefetcher(endless(), put=lambda t: t, depth=2)
    next(pf)
    pf.close()  # worker blocked on a full queue must exit
    assert threading.active_count() == 1


def test_prefetcher_worker_death_propagates_transfer_errors():
    """The device-put can die too (OOM, bad dtype), not just the source
    generator: the consumer must see that error, never a silent hang."""
    from repro.data.prefetch import ChunkPrefetcher

    def put(tree):
        if int(tree["x"][0]) == 2:
            raise ValueError("transfer exploded")
        return tree

    src = [{"x": np.full((1,), i)} for i in range(5)]
    pf = ChunkPrefetcher(iter(src), put=put)
    assert [int(next(pf)["x"][0]) for _ in range(2)] == [0, 1]
    with pytest.raises(ValueError, match="transfer exploded"):
        for _ in range(3):
            next(pf)
    pf.close()
    assert threading.active_count() == 1


def test_prefetcher_worker_death_drains_staged_items_first():
    """Items committed before the death still arrive, in order — the error
    surfaces exactly where the stream broke, not earlier."""
    from repro.data.prefetch import ChunkPrefetcher

    def dying():
        for i in range(3):
            yield {"x": np.full((1,), i)}
        raise OSError("source died")

    pf = ChunkPrefetcher(dying(), put=lambda t: t, depth=2)
    assert [int(next(pf)["x"][0]) for _ in range(3)] == [0, 1, 2]
    with pytest.raises(OSError, match="source died"):
        next(pf)
    # the error is consumed: the stream is over, not stuck raising forever
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()
    assert threading.active_count() == 1


def test_prefetcher_worker_death_does_not_hang_consumer():
    """A consumer polling a dead worker gets end-of-stream promptly (the
    is_alive fallback), bounded well under the watchdog horizon."""
    import time

    from repro.data.prefetch import ChunkPrefetcher

    pf = ChunkPrefetcher(iter(()), put=lambda t: t)
    pf._thread.join(timeout=10.0)
    t0 = time.monotonic()
    with pytest.raises(StopIteration):
        next(pf)
    assert time.monotonic() - t0 < 5.0
    pf.close()


def test_batch_put_local_matches_asarray():
    from repro.data.prefetch import batch_put
    from repro.sharding.rules import LOCAL_CTX

    put = batch_put(LOCAL_CTX, stacked=True)
    out = put({"tokens": np.arange(12).reshape(2, 3, 2)})
    assert isinstance(out["tokens"], jax.Array)
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  np.arange(12).reshape(2, 3, 2))


# --------------------------------------------- needs_correction (satellite)


def test_needs_correction_flags():
    from repro.core.guided import GuidedConfig
    from repro.engine import get_compensator

    gs = GuidedConfig(mode="ssgd")
    ga = GuidedConfig(mode="asgd")
    assert not get_compensator("none", gs).needs_correction
    assert not get_compensator("guided_fused", gs).needs_correction
    assert get_compensator("guided_two_pass", gs).needs_correction
    assert not get_compensator("dc_asgd", ga).needs_correction
    assert not get_compensator("gap_aware", ga).needs_correction
    # composed strategy: only its two_pass flavour runs the second update
    fused = GuidedConfig(mode="dc_asgd", guided=True, correction="fused")
    twop = GuidedConfig(mode="dc_asgd", guided=True, correction="two_pass")
    assert not get_compensator("dc_asgd_guided", fused).needs_correction
    assert get_compensator("dc_asgd_guided", twop).needs_correction


@pytest.mark.parametrize("strategy,n_forwards", [
    ("guided_fused", 1),     # replay folded into THIS backward: one forward
    ("guided_two_pass", 2),  # the literal second update traces a second one
])
def test_fused_step_compiles_without_second_forward(strategy, n_forwards):
    """The jitted step of a non-correcting strategy must not trace
    weighted_grad_fn's second forward+backward at all (HLO size / compile
    time), while two_pass still gets its lax.cond'd replay."""
    import repro.models.transformer as T
    from repro.analysis import assert_traces
    from repro.data import make_batch_for
    from repro.engine import mesh as M
    from repro.optim import constant, get_optimizer

    spec = _spec(strategy, "ssgd")
    cfg, gcfg = spec.model_config(), spec.to_guided_config()
    opt = get_optimizer("sgd")
    strat = Trainer.from_spec(spec).strategy
    step = M.build_train_step(cfg, gcfg, opt, M.build_ctx("local"),
                              constant(1e-2), n_workers=2, strategy=strat)
    params, _, gstate = M.init_train_state(
        jax.random.PRNGKey(0), cfg, gcfg, opt, n_workers=2, strategy=strat)
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 8, 4, seed=0).items()}
    with assert_traces(n_forwards, (T, "forward_train")):
        jax.make_jaxpr(step)(params, gstate, batch)


# --------------------------------------------- compile/warm split (satellite)


def test_report_splits_compile_from_warm_throughput():
    r = Trainer.from_spec(_spec(chunk_steps=3)).fit()  # sizes [3, 3]
    assert r.compile_time_s > 0
    assert r.warm_steps == 3  # 6 steps minus the first (compiling) dispatch
    # warm time covers the warm dispatches alone: no compile windows, no
    # out-of-loop setup/teardown
    assert 0 < r.warm_time_s < r.wall_time_s - r.compile_time_s
    assert r.steps_per_s == pytest.approx(r.warm_steps / r.warm_time_s)

    # an uneven tail compiles its OWN program: both dispatches of sizes
    # [4, 2] count as compile, warm_steps drops to 0 and steps_per_s falls
    # back to the whole-run average instead of mislabeling a compile as warm
    r2 = Trainer.from_spec(_spec(chunk_steps=4)).fit()
    assert r2.warm_steps == 0
    assert r2.steps_per_s == pytest.approx(r2.n_steps / r2.wall_time_s)


def test_build_chunk_step_shapes():
    """build_chunk_step is usable standalone: (K, ...) stacked batch in,
    (K,)-stacked metrics out, carry threaded through."""

    def toy_step(p, g, batch):
        p = {"w": p["w"] + batch["x"].sum()}
        return p, g + 1, {"loss": batch["x"].mean()}

    chunk = build_chunk_step(toy_step)
    p, g, m = chunk({"w": jnp.zeros(())}, jnp.asarray(0),
                    {"x": jnp.arange(6.0).reshape(3, 2)})
    assert float(p["w"]) == 15.0 and int(g) == 3
    assert m["loss"].shape == (3,)
