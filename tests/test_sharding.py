"""Sharding-rule resolution invariants (no mesh devices needed for specs)."""
import jax
import numpy as np
import pytest
from hypcompat import given, settings, st  # hypothesis, or skip-stubs when absent
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    MULTIPOD_RULES,
    SERVE_TP_ONLY_RULES,
    logical_to_spec,
)


class FakeMesh:
    """Duck-typed mesh: logical_to_spec only reads .shape (a dict)."""

    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def test_basic_resolution():
    assert logical_to_spec(("fsdp", "tp"), DEFAULT_RULES, MESH, (4096, 4096)) == P("data", "model")
    assert logical_to_spec((None, "tp"), DEFAULT_RULES, MESH, (10, 64)) == P(None, "model")


def test_divisibility_fallback_replicates():
    # 8 experts cannot shard over 16-way data: dim is left replicated
    spec = logical_to_spec(("expert", "fsdp", "tp"), DEFAULT_RULES, MESH, (8, 4096, 32768))
    assert spec == P(None, "data", "model")
    # 128 experts CAN shard; then fsdp's data axis is taken -> d replicated
    spec = logical_to_spec(("expert", "fsdp", "tp"), DEFAULT_RULES, MESH, (128, 4096, 1536))
    assert spec == P("data", None, "model")


def test_no_axis_reuse():
    spec = logical_to_spec(("fsdp", "fsdp"), DEFAULT_RULES, MESH, (64, 64))
    assert spec == P("data", None)


def test_multipod_batch_axes():
    spec = logical_to_spec(("batch", None), MULTIPOD_RULES, MESH3, (256, 128))
    assert spec == P(("pod", "data"), None)
    # batch=1 divides nothing: replicated
    spec = logical_to_spec(("batch", None), MULTIPOD_RULES, MESH3, (1, 128))
    assert spec == P(None, None)


def test_partial_axis_prefix():
    # batch 32 divides pod*data=32 fully
    spec = logical_to_spec(("batch",), MULTIPOD_RULES, MESH3, (32,))
    assert spec == P(("pod", "data"))
    # batch 2 divides pod=2 but not pod*data: falls back to prefix (pod,)
    spec = logical_to_spec(("batch",), MULTIPOD_RULES, MESH3, (2,))
    assert spec == P("pod")


def test_serve_tp_rules_disable_fsdp():
    spec = logical_to_spec(("fsdp", "tp"), SERVE_TP_ONLY_RULES, MESH, (4096, 4096))
    assert spec == P(None, "model")


@given(
    st.lists(st.sampled_from(["fsdp", "tp", "batch", "expert", None]), min_size=1, max_size=4),
    st.lists(st.sampled_from([1, 2, 8, 16, 64, 4096]), min_size=1, max_size=4),
)
@settings(max_examples=100, deadline=None)
def test_spec_always_valid(logical, dims):
    n = min(len(logical), len(dims))
    logical, dims = tuple(logical[:n]), tuple(dims[:n])
    spec = logical_to_spec(logical, DEFAULT_RULES, MESH, dims)
    # 1) every sharded dim divides evenly
    used = []
    for d, s in zip(dims, spec):
        axes = (s,) if isinstance(s, str) else (s or ())
        size = int(np.prod([MESH.shape[a] for a in axes])) if axes else 1
        assert d % size == 0
        used.extend(axes)
    # 2) no mesh axis used twice
    assert len(used) == len(set(used))
