"""Checkpoint subsystem: v1 npz roundtrips, mismatch diagnostics, the v2
manifest/async writer, and subtree (params-only) restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    manifest_meta,
    read_manifest,
    restore,
    restore_subtree,
    save,
    save_train_state,
    snapshot,
)


def test_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.asarray(7)}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"]))
    assert out["params"]["b"].dtype == jnp.bfloat16


def test_multiple_steps(tmp_path):
    tree = {"w": jnp.zeros(4)}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, {"w": jnp.full(4, 5.0)})
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), 5.0)


def test_bf16_roundtrip_is_exact(tmp_path):
    """bf16 leaves archive as f32 (numpy has no bf16) but the round-trip is
    bit-preserving: every bf16 value is exactly representable in f32."""
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal((64,)).astype(np.float32)).astype(jnp.bfloat16)
    tree = {"w": vals, "scale": jnp.asarray(3.14159, jnp.bfloat16)}
    save(str(tmp_path), 1, tree)
    out = restore(str(tmp_path), 1, tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(vals, np.float32))
    np.testing.assert_array_equal(np.asarray(out["scale"], np.float32),
                                  np.asarray(tree["scale"], np.float32))


def test_mismatch_raises_valueerror_naming_keys(tmp_path):
    """restore into a different tree names the missing AND unexpected keys in
    a ValueError (it used to die with a bare KeyError on the first lookup)."""
    save(str(tmp_path), 3, {"params": {"w": jnp.zeros(2)}, "extra": jnp.ones(1)})
    wrong = {"params": {"w": jnp.zeros(2), "b": jnp.zeros(3)}}
    with pytest.raises(ValueError) as ei:
        restore(str(tmp_path), 3, wrong)
    msg = str(ei.value)
    assert "missing from archive" in msg and "'b'" in msg
    assert "unexpected in archive" in msg and "extra" in msg
    assert "KeyError" not in msg


def test_shape_mismatch_raises_valueerror(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match=r"\(2, 3\).*\(3, 2\)"):
        restore(str(tmp_path), 1, {"w": jnp.zeros((3, 2))})


def test_missing_archive_is_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), 9, {"w": jnp.zeros(1)})


# ------------------------------------------------------------ v2: manifest


def _tree(v):
    return {"params": {"w": jnp.full((4,), float(v))}, "step": jnp.asarray(v)}


def test_sync_save_writes_manifest_and_retains(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        save_train_state(d, s, _tree(s), meta={"strategy": "guided_fused"},
                         keep_last=2)
    man = read_manifest(d)
    assert man["latest"] == 4
    steps = [c["step"] for c in man["ckpts"]]
    assert steps == [3, 4]  # keep_last=2 pruned 1 and 2
    files = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert files == ["step_00000003.npz", "step_00000004.npz"]
    assert latest_step(d) == 4
    assert manifest_meta(d)["strategy"] == "guided_fused"
    out = restore(d, 4, _tree(0))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 4.0)


def test_manifest_is_valid_json_and_atomic_layout(tmp_path):
    d = str(tmp_path)
    save_train_state(d, 7, _tree(7))
    with open(os.path.join(d, "MANIFEST.json")) as f:
        man = json.load(f)
    assert man["version"] == 2
    assert man["ckpts"][0]["file"] == "step_00000007.npz"
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]  # no droppings


def test_async_writer_roundtrip_and_retention(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep_last=3, meta={"arch": "yi_9b"})
    for s in range(1, 7):
        assert ck.save(s, _tree(s))
    assert not ck.save(6, _tree(6))  # dedupe: same step as last save
    ck.close()
    man = read_manifest(d)
    assert man["latest"] == 6
    assert [c["step"] for c in man["ckpts"]] == [4, 5, 6]
    assert len([f for f in os.listdir(d) if f.endswith(".npz")]) == 3
    out = restore(d, 5, _tree(0))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 5.0)
    assert manifest_meta(d, 5)["arch"] == "yi_9b"


def test_async_writer_snapshot_is_immune_to_donation(tmp_path):
    """save() copies device->host on the caller thread: deleting the source
    buffer right after save (what jit donation does to the live arrays) must
    not corrupt the snapshot that lands on disk."""
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep_last=0)
    w = jnp.arange(8, dtype=jnp.float32)
    ck.save(1, {"w": w})
    w.delete()  # simulate the next step's donation
    ck.close()
    out = restore(d, 1, {"w": jnp.zeros(8)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8, dtype=np.float32))


def test_async_writer_surfaces_errors(tmp_path):
    import shutil

    d = os.path.join(str(tmp_path), "sub")
    ck = AsyncCheckpointer(d, keep_last=0)
    shutil.rmtree(d)
    with open(d, "w") as f:  # the ckpt "dir" is now a file: writes must fail
        f.write("in the way")
    try:
        ck.save(1, _tree(1))
        with pytest.raises(RuntimeError, match="checkpoint writer failed"):
            ck.wait()
    finally:
        os.unlink(d)
        ck.close()


def test_restore_subtree_params_only(tmp_path):
    d = str(tmp_path)
    full = snapshot({"w": jnp.full((2, 2), 9.0), "b": jnp.ones(2, jnp.bfloat16)},
                    {"score": jnp.zeros(4)}, cursor=12)
    save_train_state(d, 12, full)
    out = restore_subtree(d, 12, "params", {"w": jnp.zeros((2, 2)),
                                            "b": jnp.zeros(2, jnp.bfloat16)})
    np.testing.assert_array_equal(np.asarray(out["w"]), 9.0)
    assert out["b"].dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="no 'params' subtree matching"):
        restore_subtree(d, 12, "params", {"nope": jnp.zeros(1)})


def test_latest_step_falls_back_to_v1_latest(tmp_path):
    d = str(tmp_path)
    save(d, 11, {"w": jnp.zeros(2)})  # v1 API: writes LATEST, no manifest
    assert read_manifest(d) is None
    assert latest_step(d) == 11


# ------------------------------------------- verified checkpoints (DESIGN §14)


def test_manifest_entries_record_sha256(tmp_path):
    """Both write paths — sync save_train_state and the async writer — record
    each archive's SHA-256 in its manifest entry, matching the file."""
    from repro.checkpoint.npz import file_sha256, manifest_entries

    d = str(tmp_path)
    save_train_state(d, 1, _tree(1))
    ck = AsyncCheckpointer(d, keep_last=0)
    ck.save(2, _tree(2))
    ck.close()
    entries = manifest_entries(d)
    assert [e["step"] for e in entries] == [2, 1]
    for e in entries:
        assert len(e["sha256"]) == 64
        assert e["sha256"] == file_sha256(os.path.join(d, e["file"]))


def test_truncated_archive_fails_verification_naming_step_and_path(tmp_path):
    from repro.checkpoint.npz import (
        CorruptCheckpointError,
        manifest_entries,
        verify_entry,
    )

    d = str(tmp_path)
    save_train_state(d, 5, _tree(5))
    path = os.path.join(d, "step_00000005.npz")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(CorruptCheckpointError) as ei:
        verify_entry(d, manifest_entries(d)[0])
    assert "step 5" in str(ei.value) and path in str(ei.value)


def test_restore_latest_falls_back_past_a_corrupt_newest(tmp_path):
    """One flipped byte in the newest archive costs one retention interval,
    never the run: restore_latest verifies, skips it, restores the next-older
    intact entry."""
    d = str(tmp_path)
    save_train_state(d, 1, _tree(1), keep_last=0)
    save_train_state(d, 2, _tree(2), keep_last=0)
    path = os.path.join(d, "step_00000002.npz")
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    from repro.checkpoint import restore_latest

    step, out = restore_latest(d, _tree(0))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 1.0)


def test_restore_latest_raises_when_every_entry_is_corrupt(tmp_path):
    from repro.checkpoint import restore_latest
    from repro.checkpoint.npz import CorruptCheckpointError

    d = str(tmp_path)
    for s in (1, 2):
        save_train_state(d, s, _tree(s), keep_last=0)
        p = os.path.join(d, f"step_0000000{s}.npz")
        with open(p, "r+b") as f:
            f.truncate(3)
    with pytest.raises(CorruptCheckpointError, match="no intact checkpoint"):
        restore_latest(d, _tree(0))


def test_undecodable_archive_is_corrupt_not_zipfile_internals(tmp_path):
    """A torn archive read directly (explicit step, no manifest fallback)
    surfaces as CorruptCheckpointError naming the step — not a raw
    zipfile/zlib exception."""
    from repro.checkpoint.npz import CorruptCheckpointError

    d = str(tmp_path)
    save(d, 3, {"w": jnp.zeros(4)})
    p = os.path.join(d, "step_00000003.npz")
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.raises(CorruptCheckpointError, match="step 3"):
        restore(d, 3, {"w": jnp.zeros(4)})


def test_template_mismatch_does_not_fall_back_to_older_steps(tmp_path):
    """A wrong restore template is a config error, not corruption: the plain
    ValueError propagates from the NEWEST step — restoring an older snapshot
    of the wrong config would not be a recovery."""
    from repro.checkpoint import restore_latest
    from repro.checkpoint.npz import CorruptCheckpointError

    d = str(tmp_path)
    save_train_state(d, 1, _tree(1), keep_last=0)
    save_train_state(d, 2, _tree(2), keep_last=0)
    with pytest.raises(ValueError) as ei:
        restore_latest(d, {"something": {"else": jnp.zeros(7)}})
    assert not isinstance(ei.value, CorruptCheckpointError)
    assert "step_00000002.npz" in str(ei.value)   # newest, no fallback


def test_dist_restore_falls_back_past_corrupt_newest(tmp_path):
    from repro.checkpoint import dist_restore, dist_snapshot

    d = str(tmp_path)
    save_train_state(d, 1, dist_snapshot([1.0], 1, [0]), keep_last=0)
    save_train_state(d, 2, dist_snapshot([2.0], 2, [0, 1]), keep_last=0)
    p = os.path.join(d, "step_00000002.npz")
    with open(p, "r+b") as f:
        f.truncate(3)
    out = dist_restore(d)
    assert int(out["version"]) == 1
    np.testing.assert_array_equal(np.asarray(out["W"]), 1.0)


# --------------------------------------- restore-during-retention (DESIGN §13)


def test_manifest_never_names_a_pruned_archive(tmp_path):
    """The writer-side half of the retention race fix: at EVERY point in a
    long retention run, each step the manifest lists has its archive on disk
    (manifest update strictly before unlink)."""
    d = str(tmp_path)
    for s in range(1, 12):
        save_train_state(d, s, _tree(s), keep_last=2)
        for c in read_manifest(d)["ckpts"]:
            assert os.path.exists(os.path.join(d, c["file"])), (
                f"manifest names pruned archive {c['file']} after step {s}")


def test_restore_latest_retries_a_pruned_step(tmp_path, monkeypatch):
    """The reader-side half: a manifest read that went stale (its step pruned
    before the load) retries against the fresh manifest instead of failing."""
    import repro.checkpoint.npz as N
    from repro.checkpoint import restore_latest

    d = str(tmp_path)
    save_train_state(d, 1, _tree(1), keep_last=2)
    save_train_state(d, 2, _tree(2), keep_last=2)

    real = N.manifest_entries
    calls = {"n": 0}

    def racing_entries(ckpt_dir):
        calls["n"] += 1
        if calls["n"] == 1:
            # simulate: we read entries naming step 2, then retention pruned it
            entries = real(ckpt_dir)
            save_train_state(ckpt_dir, 3, _tree(3), keep_last=1)
            return entries
        return real(ckpt_dir)

    monkeypatch.setattr(N, "manifest_entries", racing_entries)
    step, out = restore_latest(d, _tree(0))
    assert step == 3 and calls["n"] == 2
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), 3.0)


def test_restore_latest_gives_up_on_a_vanishing_dir(tmp_path, monkeypatch):
    import repro.checkpoint.npz as N
    from repro.checkpoint import restore_latest

    d = str(tmp_path)
    save_train_state(d, 1, _tree(1))
    os.unlink(os.path.join(d, "step_00000001.npz"))  # manifest now dangles
    with pytest.raises(FileNotFoundError, match="kept vanishing"):
        restore_latest(d, _tree(0), attempts=3)
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        restore_latest(str(tmp_path / "empty"), _tree(0))


def test_restore_races_live_retention(tmp_path):
    """Concurrent stress: a writer cycling keep_last=2 snapshots while a
    reader restore_latest()s in a loop — every restore must succeed and
    return an internally consistent snapshot (w matches its step)."""
    import threading

    d = str(tmp_path)
    save_train_state(d, 0, _tree(0), keep_last=2)
    stop = threading.Event()
    errs = []

    def writer():
        ck = AsyncCheckpointer(d, keep_last=2)
        try:
            for s in range(1, 60):
                ck.save(s, _tree(s))
        finally:
            ck.close()
        stop.set()

    def reader():
        from repro.checkpoint import restore_latest

        try:
            while not stop.is_set():
                step, out = restore_latest(d, _tree(0))
                w = np.asarray(out["params"]["w"])
                if not (w == float(step)).all():
                    errs.append(f"step {step} restored w={w[0]}")
        except BaseException as e:  # surfaced below, not swallowed
            errs.append(repr(e))

    threads = [threading.Thread(target=writer),
               threading.Thread(target=reader)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
        assert not t.is_alive()
    assert errs == []


def test_dist_restore_retries_latest_like_restore_latest(tmp_path, monkeypatch):
    import repro.checkpoint.npz as N
    from repro.checkpoint import dist_restore, dist_snapshot

    d = str(tmp_path)
    save_train_state(d, 1, dist_snapshot([1.0], 1, [0]), keep_last=2)
    save_train_state(d, 2, dist_snapshot([2.0], 2, [0, 1]), keep_last=2)

    real = N.manifest_entries
    calls = {"n": 0}

    def racing_entries(ckpt_dir):
        calls["n"] += 1
        if calls["n"] == 1:
            entries = real(ckpt_dir)
            save_train_state(ckpt_dir, 3, dist_snapshot([3.0], 3, [0, 1, 1]),
                             keep_last=1)
            return entries
        return real(ckpt_dir)

    monkeypatch.setattr(N, "manifest_entries", racing_entries)
    out = dist_restore(d)
    assert int(out["version"]) == 3 and calls["n"] == 2
