"""Checkpoint save/restore roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save


def test_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.asarray(7)}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"]))
    assert out["params"]["b"].dtype == jnp.bfloat16


def test_multiple_steps(tmp_path):
    tree = {"w": jnp.zeros(4)}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, {"w": jnp.full(4, 5.0)})
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), 5.0)
