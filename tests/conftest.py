import os

# Keep the default test process single-device (the dry-run sets its own flags
# in a separate process; forcing 512 devices here would slow every test).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
