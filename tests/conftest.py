import os

# Keep the default test process single-device (the dry-run sets its own flags
# in a separate process; forcing 512 devices here would slow every test).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session", autouse=True)
def _repro_tsan():
    """REPRO_TSAN=1 runs the whole session under the runtime race sanitizer
    (repro.analysis.sanitize): every Lock/Condition/Thread the dist, prefetch
    and checkpoint classes create is instrumented, and any lock-order
    inversion or unlocked shared write observed across the run fails the
    session at teardown. Off by default — zero overhead for plain runs."""
    from repro.analysis import sanitize

    if not sanitize.enabled():
        yield
        return
    sanitize.install()
    yield
    reports = sanitize.report()
    sanitize.uninstall()
    if reports:
        pytest.fail("race sanitizer found issues:\n" + "\n".join(reports),
                    pytrace=False)
